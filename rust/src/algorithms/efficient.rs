//! Efficient-Adam baseline [28]: two-way s-level uniform quantization with
//! two-way error feedback.
//!
//! Workers keep their Adam state local (never aggregated — moments drift
//! apart across devices, the degradation §II-B describes); only the model
//! update ΔW travels, uniformly quantized: device→server with per-device
//! EF, and server→devices re-quantized with a server-side EF.

use anyhow::{ensure, Result};

use super::residual_store::ResidualStore;
use super::wire::{WireBody, WireUpload};
use super::{Aggregate, Algorithm, LocalDelta, MomentumPolicy, Recon, Upload};
use crate::quant::{uniform_compress, uniform_decompress, ErrorFeedback, UniformPacket};
use crate::sparse::codec::cost;
use crate::util::bytes::{ByteReader, ByteWriter};

pub struct EfficientAdam {
    dim: usize,
    levels: u32,
    /// Device-side EF residuals, one `dim`-wide entry per *touched*
    /// device (see [`ResidualStore`]).
    ef_up: ResidualStore,
    /// Server-side EF memory for the broadcast direction (a single dense
    /// vector — the server always participates, no point spilling it).
    ef_down: ErrorFeedback,
}

impl EfficientAdam {
    pub fn new(dim: usize, levels: u32, resident_cap: usize, spill_dir: &str) -> Self {
        assert!(levels >= 2);
        EfficientAdam {
            dim,
            levels,
            ef_up: ResidualStore::new(dim, resident_cap, spill_dir),
            ef_down: ErrorFeedback::new(dim),
        }
    }

    /// Shared core of [`Algorithm::compress`] and
    /// [`Algorithm::compress_wire`] — the per-device EF memory mutates
    /// exactly once per call.
    fn compress_inner(&mut self, device: usize, delta: &LocalDelta) -> (UniformPacket, Upload) {
        // Round-trip the store entry through a scratch `ErrorFeedback`
        // (plain f32 copies — bit-exact) to reuse the quantizer's EF ops.
        let entry = self.ef_up.get_mut(device as u64);
        let mut ef = ErrorFeedback::new(entry.len());
        ef.residual.copy_from_slice(entry);
        let compensated = ef.compensate(&delta.dw);
        let packet = uniform_compress(&compensated, self.levels);
        let deq = uniform_decompress(&packet);
        ef.update(&compensated, &deq);
        entry.copy_from_slice(&ef.residual);
        let bits = packet.wire_bits();
        debug_assert_eq!(bits, cost::uniform(self.dim, self.levels as usize));
        let up = Upload {
            dw: Recon::Dense(deq),
            dm: None,
            dv: None,
            weight: delta.weight,
            bits,
        };
        (packet, up)
    }
}

impl Algorithm for EfficientAdam {
    fn name(&self) -> &'static str {
        "efficient-adam"
    }

    fn momentum_policy(&self, _round: usize) -> MomentumPolicy {
        MomentumPolicy::DeviceLocal
    }

    fn compress(&mut self, _round: usize, device: usize, delta: LocalDelta) -> Upload {
        self.compress_inner(device, &delta).1
    }

    fn compress_wire(
        &mut self,
        _round: usize,
        device: usize,
        delta: LocalDelta,
    ) -> Result<WireUpload> {
        let (packet, up) = self.compress_inner(device, &delta);
        Ok(WireUpload {
            body: WireBody::UniformQ(packet),
            weight: up.weight,
            bits: up.bits,
        })
    }

    fn downlink_bits(&self, _agg: &Aggregate) -> u64 {
        cost::uniform(self.dim, self.levels as usize)
    }

    fn postprocess(&mut self, agg: &mut Aggregate) {
        // Two-way quantization: the broadcast is itself quantized, with a
        // server-side error-feedback memory absorbing the residual.
        let compensated = self.ef_down.compensate(&agg.dw);
        let packet = uniform_compress(&compensated, self.levels);
        let deq = uniform_decompress(&packet);
        self.ef_down.update(&compensated, &deq);
        agg.dw = deq;
    }

    fn save_state(&self, out: &mut ByteWriter) {
        self.ef_up.save_state(out);
        out.put_f32s(&self.ef_down.residual);
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        self.ef_up.load_state(input)?;
        self.ef_down.residual = input.take_f32s()?;
        ensure!(self.ef_down.residual.len() == self.dim, "EF residual dim mismatch");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(dim: usize) -> LocalDelta {
        LocalDelta {
            dw: (0..dim).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.01).collect(),
            dm: vec![0.0; dim],
            dv: vec![0.0; dim],
            weight: 1.0,
        }
    }

    #[test]
    fn wire_cost_scales_with_levels() {
        let mut a4 = EfficientAdam::new(64, 4, 0, ""); // 2 bits/lane
        let mut a16 = EfficientAdam::new(64, 16, 0, ""); // 4 bits/lane
        let b4 = a4.compress(0, 0, delta(64)).bits;
        let b16 = a16.compress(0, 0, delta(64)).bits;
        assert_eq!(b4, 64 * 2 + 32);
        assert_eq!(b16, 64 * 4 + 32);
    }

    #[test]
    fn moments_never_uploaded() {
        let mut a = EfficientAdam::new(16, 16, 0, "");
        let up = a.compress(0, 0, delta(16));
        assert!(up.dm.is_none() && up.dv.is_none());
        assert_eq!(a.momentum_policy(0), MomentumPolicy::DeviceLocal);
    }

    #[test]
    fn two_way_ef_converges_on_repeat() {
        // Sending the same aggregate repeatedly: cumulative broadcast
        // should converge to the true value thanks to server EF.
        let mut a = EfficientAdam::new(32, 4, 0, "");
        let truth: Vec<f32> = (0..32).map(|i| (i as f32) * 0.01).collect();
        let mut sent = vec![0.0f32; 32];
        let rounds = 100;
        for _ in 0..rounds {
            let mut agg = Aggregate {
                dw: truth.clone(),
                dm: None,
                dv: None,
                dw_support: 32,
                dm_support: 0,
                dv_support: 0,
            };
            a.postprocess(&mut agg);
            for (s, v) in sent.iter_mut().zip(&agg.dw) {
                *s += v;
            }
        }
        for (s, t) in sent.iter().zip(&truth) {
            assert!((s / rounds as f32 - t).abs() < 0.02, "{s} vs {t}");
        }
    }
}
