//! Transport loopback bench: how fast the wire layer moves one round's
//! uplinks through a real localhost socket.
//!
//! Per case, a [`TransportServer`] (1 agent slot) is paired with an echo
//! client thread that answers every `RoundStart` with one pre-encoded
//! uplink per assignment slot — so the timed region is exactly the
//! transport stack: framing + CRC, socket writes, the server's
//! non-blocking pump, and the full untrusted-byte validation path
//! (`Msg::decode` → echo checks → framed-byte accounting →
//! `WireBody::try_decode` → `try_into_upload`).  Three wire formats are
//! measured — dense f32 triples, the shared-sparse-mask body, and the
//! quantized SSM packet — plus an in-memory frame-codec case that
//! isolates the CPU cost from the socket.
//!
//! Run: `cargo bench --bench transport_loopback`.
//!
//! **JSON mode** (`-- --json`) — the CI perf pin: emits median
//! round-trip wall-clock, messages/sec and bytes-on-wire per message as
//! `BENCH_transport_loopback.json` (`--json-out PATH` to redirect).
//! With `--baseline PATH` fresh medians are compared against a
//! checked-in file; a >10% regression prints a `WARN:` line
//! (informational — absolute numbers are host-dependent, so the
//! comparison never fails the build).

use std::collections::BTreeMap;
use std::io::Write as _;

use fedadam_ssm::algorithms::{self, LocalDelta};
use fedadam_ssm::benchlib::{black_box, from_env, Bench};
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::runtime::{reference_meta, reference_pool};
use fedadam_ssm::transport::frame::{read_frame, write_frame, FrameBuffer};
use fedadam_ssm::transport::msg::{Assignment, Msg, Uplink, PROTOCOL_VERSION};
use fedadam_ssm::transport::net::Stream;
use fedadam_ssm::transport::{run_agent, TransportServer};
use fedadam_ssm::util::json::{self, Value};

const DIM: usize = 4096;
const SLOTS: usize = 8;
const FINGERPRINT: u64 = 0xBEEF;
const WEIGHT: f64 = 64.0;

/// One pre-encoded uplink body the echo client replays for every slot.
#[derive(Clone)]
struct Template {
    kind: u8,
    k: u64,
    levels: u32,
    bits: u64,
    body: Vec<u8>,
}

/// Deterministic pseudo-random delta (no rand crate in the offline build).
fn synth_delta(seed: &mut u64, dim: usize) -> LocalDelta {
    let mut next = || {
        *seed = seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((*seed >> 40) as u32) as f32 / (1u32 << 24) as f32 - 0.5
    };
    LocalDelta {
        dw: (0..dim).map(|_| next()).collect(),
        dm: (0..dim).map(|_| next() * 0.1).collect(),
        dv: (0..dim).map(|_| (next() * 0.01).abs()).collect(),
        weight: WEIGHT,
    }
}

/// Build a valid wire message for `algo` by running its real compressor
/// once — the body bytes are exactly what a device agent would frame.
fn template_for(algo: &str) -> Template {
    let mut cfg = ExperimentConfig::default();
    cfg.algorithm = algo.into();
    cfg.devices = 1;
    cfg.sparsity = 0.05;
    cfg.quant_levels = 16;
    let mut a = algorithms::build(&cfg, DIM).expect("algorithm");
    let mut seed = 0x10AD_BA5E_u64;
    let wire = a
        .compress_wire(0, 0, synth_delta(&mut seed, DIM))
        .expect("compress_wire");
    let body = wire.encode_body().expect("encode_body");
    Template {
        kind: wire.body.kind(),
        k: wire.body.k() as u64,
        levels: wire.body.levels(),
        bits: wire.bits,
        body,
    }
}

fn uplink_msg(t: &Template, round: u64, a: &Assignment) -> Msg {
    Msg::Uplink(Uplink {
        round,
        slot: a.slot,
        device: a.device,
        mean_loss: 1.0,
        weight: a.weight,
        kind: t.kind,
        k: t.k,
        levels: t.levels,
        bits: t.bits,
        body: t.body.clone(),
    })
}

/// Echo client: register as agent 0, answer each RoundStart with one
/// templated uplink per slot, exit on Shutdown (or a dead socket).
fn spawn_echo(addr: String, t: Template) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut s = Stream::connect(&addr).expect("echo connect");
        write_frame(
            &mut s,
            &Msg::Hello {
                version: PROTOCOL_VERSION,
                fingerprint: FINGERPRINT,
                agent: 0,
            }
            .encode(),
        )
        .expect("echo hello");
        let _ack = read_frame(&mut s).expect("echo ack");
        loop {
            let payload = match read_frame(&mut s) {
                Ok(p) => p,
                Err(_) => return,
            };
            match Msg::decode(&payload) {
                Ok(Msg::RoundStart { round, assignments, .. }) => {
                    let mut out = Vec::new();
                    for a in &assignments {
                        write_frame(&mut out, &uplink_msg(&t, round, a).encode())
                            .expect("Vec<u8> writes cannot fail");
                    }
                    s.write_all(&out).expect("echo uplinks");
                    s.flush().expect("echo flush");
                }
                Ok(Msg::Shutdown) | Err(_) => return,
                Ok(_) => return,
            }
        }
    })
}

fn assignments() -> Vec<Assignment> {
    (0..SLOTS as u32)
        .map(|i| Assignment { slot: i, device: i, weight: WEIGHT })
        .collect()
}

/// One benched case: (case name, algorithm id whose wire format it uses).
const CASES: [(&str, &str); 3] = [
    ("dense3", "fedadam"),
    ("shared-mask", "fedadam-ssm"),
    ("ssm-q", "fedadam-ssm-q"),
];

struct CaseResult {
    name: String,
    median_round_ns: f64,
    bits_per_msg: u64,
    body_bytes: usize,
}

fn run_cases(bench: &mut fedadam_ssm::benchlib::Bench) -> Vec<CaseResult> {
    let mut out = Vec::new();
    for (name, algo) in CASES {
        let t = template_for(algo);
        let bits_per_msg = t.bits;
        let body_bytes = t.body.len();
        let mut server =
            TransportServer::bind("127.0.0.1:0", 1, 10.0, FINGERPRINT, DIM).expect("bind");
        let echo = spawn_echo(server.addr().to_string(), t);
        let asn = assignments();
        let w = vec![0.5f32; DIM];
        let mut round = 0u64;
        let result = bench.run(
            format!("loopback: {name} ({SLOTS} msgs of {body_bytes} B, dim {DIM})"),
            || {
                let mut got = 0usize;
                server
                    .run_round(round, &w, None, None, &asn, |_, _, _, upload| {
                        got += black_box(1);
                        black_box(upload.bits);
                        Ok(())
                    })
                    .expect("run_round");
                assert_eq!(got, SLOTS);
                round += 1;
            },
        );
        server.shutdown();
        drop(server);
        echo.join().expect("echo thread");
        out.push(CaseResult {
            name: name.into(),
            median_round_ns: result.p50_ns,
            bits_per_msg,
            body_bytes,
        });
    }
    out
}

/// In-memory frame-codec case: frame + CRC + reassembly + decode, no
/// socket — the pure CPU floor of the loopback numbers.
fn run_codec_case(bench: &mut fedadam_ssm::benchlib::Bench) -> f64 {
    let t = template_for("fedadam-ssm");
    let asn = assignments();
    let msgs: Vec<Vec<u8>> = asn.iter().map(|a| uplink_msg(&t, 0, a).encode()).collect();
    let result = bench.run(
        format!("frame codec: {SLOTS} msgs in memory (no socket)"),
        || {
            let mut wire = Vec::new();
            for m in &msgs {
                write_frame(&mut wire, m).expect("Vec<u8> writes cannot fail");
            }
            let mut buf = FrameBuffer::new();
            buf.extend(&wire);
            let mut n = 0usize;
            while let Some(payload) = buf.pop().expect("clean frames") {
                black_box(Msg::decode(&payload).expect("clean decode"));
                n += 1;
            }
            assert_eq!(n, SLOTS);
        },
    );
    result.p50_ns
}

// ---------------------------------------------------------------------------
// agent fleet cases: a REAL device agent serving rounds, RSS flat in
// fleet size (the durable-agent tentpole's memory contract)
// ---------------------------------------------------------------------------

/// Slots per agent round (same per-round workload at every fleet size).
const AGENT_COHORT: usize = 8;
/// Rotating device window — the touched set stays fleet-independent.
const AGENT_TOUCHED: usize = 64;
const AGENT_INPUT: [usize; 3] = [4, 4, 1]; // row 16; dim = 10 * 17 = 170
const AGENT_CLASSES: usize = 10;
/// RSS growth at 10^5 must stay within this ratio of growth at 10^3...
const AGENT_FLAT_RATIO: f64 = 1.25;
/// ...or under this floor (KiB).  The floor is sized to admit the
/// agent's one *legitimate* O(fleet) allocation — the shared synthetic
/// corpus (10^5 samples x 16 f32 ≈ 7 MiB) plus the shard-plan index —
/// while still failing the old dense per-device state layout
/// (2 · dim · fleet f32 ≈ 136 MiB at 10^5).
const AGENT_RSS_FLOOR_KB: f64 = 32_768.0;

/// One sample per device: registration is O(fleet), every round is
/// O(cohort).  The agent steps rounds the loopback driver hands it.
fn agent_fleet_cfg(fleet: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("loopback-agent-{fleet}");
    cfg.model = "reference-linear".into();
    cfg.algorithm = "fedadam-ssm-ef".into(); // per-device EF residuals
    cfg.rounds = 1; // the driver below broadcasts rounds manually
    cfg.devices = fleet;
    cfg.train_samples = fleet;
    cfg.test_samples = 16;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 1;
    cfg.lr = 0.02;
    cfg.seed = 41;
    cfg.num_workers = 1;
    cfg
}

/// Resident set size in KiB (`None` off Linux / unreadable procfs).
fn rss_kb() -> Option<f64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = text.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse::<f64>().ok()
}

struct AgentCase {
    name: String,
    fleet: usize,
    median_round_ns: f64,
    rss_growth_kb: Option<f64>,
}

/// Bench one fleet size against a REAL [`run_agent`] (reference
/// backend, real socket, real training + EF compression), `state_dir`
/// turning the per-round durable snapshot on.  RSS growth is metered
/// from just before the agent builds its world to after the timed
/// rounds — it contains everything the agent holds, corpus included.
fn run_agent_fleet_case(
    bench: &mut Bench,
    fleet: usize,
    state_dir: Option<&std::path::Path>,
) -> AgentCase {
    let mut cfg = agent_fleet_cfg(fleet);
    let name = match state_dir {
        Some(dir) => {
            let _ = std::fs::remove_dir_all(dir);
            cfg.agent_state_dir = dir.to_string_lossy().into_owned();
            format!("agent-round-fleet-{fleet}-snap")
        }
        None => format!("agent-round-fleet-{fleet}"),
    };
    let meta = reference_meta(&AGENT_INPUT, AGENT_CLASSES, 4, 8, 1);
    let dim = meta.dim;
    let mut server = TransportServer::bind("127.0.0.1:0", 1, 30.0, cfg.fingerprint(), dim)
        .expect("bind");
    let addr = server.addr().to_string();
    let rss_before = rss_kb();
    let agent = {
        let cfg = cfg.clone();
        std::thread::spawn(move || {
            let pool = reference_pool(meta, 1).expect("reference pool");
            run_agent(&cfg, &pool, &addr, 0).expect("agent");
        })
    };
    // fedadam-ssm-ef is Aggregated-policy: the downlink carries (m, v).
    let w = vec![0.1f32; dim];
    let m = vec![0.0f32; dim];
    let v = vec![0.0f32; dim];
    let window = AGENT_TOUCHED.min(fleet);
    let mut round = 0u64;
    let result = bench.run(name.clone(), || {
        let asn: Vec<Assignment> = (0..AGENT_COHORT as u32)
            .map(|i| Assignment {
                slot: i,
                device: ((round as usize * AGENT_COHORT + i as usize) % window) as u32,
                weight: 1.0,
            })
            .collect();
        let mut got = 0usize;
        server
            .run_round(round, &w, Some(&m), Some(&v), &asn, |_, _, _, upload| {
                got += black_box(1);
                black_box(upload.bits);
                Ok(())
            })
            .expect("agent round");
        assert_eq!(got, AGENT_COHORT);
        round += 1;
    });
    let rss_after = rss_kb();
    server.shutdown();
    drop(server);
    agent.join().expect("agent thread");
    if let Some(dir) = state_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    let rss_growth_kb = match (rss_before, rss_after) {
        (Some(a), Some(b)) => Some((b - a).max(0.0)),
        _ => None,
    };
    AgentCase {
        name,
        fleet,
        median_round_ns: result.p50_ns,
        rss_growth_kb,
    }
}

/// The three agent cases: RSS flatness pinned hard at {10^3, 10^5}, plus
/// a snapshot-on case at 10^3 so the pin isolates durability overhead.
fn run_agent_cases(bench: &mut Bench) -> Vec<AgentCase> {
    let small = run_agent_fleet_case(bench, 1_000, None);
    let large = run_agent_fleet_case(bench, 100_000, None);
    if let (Some(g0), Some(g)) = (small.rss_growth_kb, large.rss_growth_kb) {
        let bound = (g0 * AGENT_FLAT_RATIO).max(AGENT_RSS_FLOOR_KB);
        assert!(
            g <= bound,
            "agent resident memory is not flat in fleet size: grew {g:.0} KiB at \
             fleet {} vs {g0:.0} KiB at fleet {} (bound {bound:.0} KiB) — O(fleet) \
             state is back on the agent",
            large.fleet,
            small.fleet,
        );
    }
    let snap_dir = std::env::temp_dir().join(format!(
        "fedadam-loopback-agentstate-{}",
        std::process::id()
    ));
    let snap = run_agent_fleet_case(bench, 1_000, Some(&snap_dir));
    vec![small, large, snap]
}

/// `--json` mode: the machine-readable perf pin (see the module docs).
fn json_mode(args: &[String]) {
    let opt = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let out_path = opt("--json-out").unwrap_or_else(|| "BENCH_transport_loopback.json".into());
    let baseline = opt("--baseline");

    let mut bench = from_env();
    bench.max_iters = 30;
    let results = run_cases(&mut bench);
    let agent_cases = run_agent_cases(&mut bench);

    let mut medians: BTreeMap<String, f64> = BTreeMap::new();
    let mut cases: Vec<Value> = Vec::new();
    for r in &results {
        medians.insert(r.name.clone(), r.median_round_ns);
        let msgs_per_sec = SLOTS as f64 / (r.median_round_ns / 1e9).max(1e-12);
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Value::Str(r.name.clone()));
        obj.insert("median_round_ns".into(), Value::Num(r.median_round_ns));
        obj.insert("msgs_per_round".into(), Value::Num(SLOTS as f64));
        obj.insert("msgs_per_sec".into(), Value::Num(msgs_per_sec));
        obj.insert("bits_per_msg".into(), Value::Num(r.bits_per_msg as f64));
        obj.insert("framed_bytes_per_msg".into(), Value::Num(r.body_bytes as f64));
        cases.push(Value::Obj(obj));
    }
    for c in &agent_cases {
        medians.insert(c.name.clone(), c.median_round_ns);
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Value::Str(c.name.clone()));
        obj.insert("median_round_ns".into(), Value::Num(c.median_round_ns));
        obj.insert("msgs_per_round".into(), Value::Num(AGENT_COHORT as f64));
        obj.insert("fleet_devices".into(), Value::Num(c.fleet as f64));
        obj.insert(
            "rss_growth_kb".into(),
            match c.rss_growth_kb {
                Some(g) => Value::Num(g),
                None => Value::Null,
            },
        );
        cases.push(Value::Obj(obj));
    }

    let mut root = BTreeMap::new();
    root.insert("bench".into(), Value::Str("transport_loopback".into()));
    root.insert("dim".into(), Value::Num(DIM as f64));
    root.insert("agents".into(), Value::Num(1.0));
    root.insert("cases".into(), Value::Arr(cases));
    let doc = Value::Obj(root);
    std::fs::write(&out_path, doc.render() + "\n").expect("writing bench json");
    println!("wrote {out_path}");

    if let Some(bp) = baseline {
        compare_with_baseline(&bp, &medians);
    }
}

/// Warn (never fail) when a fresh median regresses >10% vs `path`.
fn compare_with_baseline(path: &str, medians: &BTreeMap<String, f64>) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("no baseline at {path}: {e}");
            return;
        }
    };
    let base = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("unparseable baseline {path}: {e}");
            return;
        }
    };
    let Some(base_cases) = base.get("cases").and_then(|c| c.as_arr()) else {
        eprintln!("baseline {path} has no cases array");
        return;
    };
    let mut warned = false;
    for c in base_cases {
        let name = c.get("name").and_then(|v| v.as_str());
        let old = c.get("median_round_ns").and_then(|v| v.as_f64());
        let (Some(name), Some(old)) = (name, old) else {
            continue;
        };
        let Some(&new) = medians.get(name) else {
            continue;
        };
        let ratio = new / old.max(1.0);
        if ratio > 1.10 {
            warned = true;
            println!(
                "WARN: {name}: median loopback round {:.2} ms vs baseline {:.2} ms (+{:.0}%)",
                new / 1e6,
                old / 1e6,
                (ratio - 1.0) * 100.0
            );
        } else {
            println!("ok: {name}: {ratio:.2}x baseline");
        }
    }
    if !warned {
        println!("no >10% wall-clock regressions vs {path}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--json") {
        json_mode(&args);
        return;
    }
    let mut bench = from_env();
    bench.max_iters = 50;
    let codec_ns = run_codec_case(&mut bench);
    let results = run_cases(&mut bench);
    let agent_cases = run_agent_cases(&mut bench);
    bench.report("transport loopback");
    println!("\n-- socket overhead over the in-memory codec --");
    for r in &results {
        println!(
            "{:>12}: {:.2} ms/round, {:.0} msgs/s, {:.1}x the codec-only cost, {} B framed/msg",
            r.name,
            r.median_round_ns / 1e6,
            SLOTS as f64 / (r.median_round_ns / 1e9).max(1e-12),
            r.median_round_ns / codec_ns.max(1.0),
            r.body_bytes
        );
    }
    println!("\n-- device agent: real training rounds, RSS flat in fleet --");
    for c in &agent_cases {
        println!(
            "{:>28}: {:.2} ms/round, RSS growth {}",
            c.name,
            c.median_round_ns / 1e6,
            match c.rss_growth_kb {
                Some(g) => format!("{g:.0} KiB"),
                None => "n/a".into(),
            }
        );
    }
    if let [base, _, snap] = &agent_cases[..] {
        println!(
            "durable-snapshot overhead at fleet 1000: {:.2} ms vs {:.2} ms per round ({:+.0}%)",
            snap.median_round_ns / 1e6,
            base.median_round_ns / 1e6,
            (snap.median_round_ns / base.median_round_ns.max(1.0) - 1.0) * 100.0
        );
    }
    println!("\n{}", bench.to_csv());
}
