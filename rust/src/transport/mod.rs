//! A real wire under the federated round loop: server and device-agent
//! processes exchanging the compressed uplink codec over TCP or Unix
//! sockets.
//!
//! Layers, bottom up:
//!
//! - [`net`] — one [`net::Stream`]/[`net::Listener`] pair over TCP and
//!   Unix-domain sockets (`transport_listen` prefix convention).
//! - [`frame`] — `[len u32 le][crc32 u32 le][payload]` message framing,
//!   the journal's on-disk record layout put on a socket.  Any torn or
//!   bit-flipped frame is a typed error, never a desynchronized stream.
//! - [`msg`] — the protocol vocabulary: `Hello`/`HelloAck` registration
//!   (protocol version + config-fingerprint check), `RoundStart`
//!   downlink, `Uplink` (the wire-codec header + body bytes), and
//!   `Shutdown`.  Decoding is hardened against untrusted bytes.
//! - [`server`] — the coordinator's single-threaded poll loop:
//!   registration, downlink broadcast, out-of-order uplink collection
//!   with full validation (echo fields, framed-byte accounting,
//!   [`crate::algorithms::wire::WireBody::try_decode`]), reconnect
//!   repair, and deadline enforcement.
//! - [`agent`] — the device-agent round loop: own a static shard of the
//!   device population (`device % agents == index`), train through the
//!   executor seam, compress through the same algorithms, upload.
//! - [`agent_state`] — the agent's crash-safe durability log
//!   (`agent_state_dir`): per-round framed snapshots of the stateful
//!   compressor (EF residuals, device-local moments, cached frames) so
//!   a *fresh agent process* resumes bit-identically mid-run.
//!
//! The whole stack preserves the repo's determinism contract: a run
//! over this transport produces the byte-identical final model, log
//! rows and comm ledger as the in-process run of the same config —
//! `examples/multiprocess_demo.rs` asserts exactly that across OS
//! processes, and `rust/tests/transport.rs` across threads.

pub mod agent;
pub mod agent_state;
pub mod frame;
pub mod msg;
pub mod net;
pub mod server;

pub use agent::{run_agent, run_agent_with, AgentOptions};
pub use server::{RoundLatency, TransportServer};
