//! Datasets and federated partitioning.
//!
//! The paper trains on Fashion-MNIST / CIFAR-10 / SVHN.  Those corpora are
//! not downloadable in this sandbox, so [`synthetic`] generates
//! class-structured stand-ins with identical tensor shapes and sizes
//! (DESIGN.md §Substitutions), and [`partition`] reproduces the paper's
//! IID and Dirichlet(θ) non-IID splits.

pub mod partition;
pub mod synthetic;

pub use partition::{partition, Partition, ShardPlan};
pub use synthetic::{SyntheticSpec, SyntheticTask};

/// A dataset in memory: row-major images + labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[n, h*w*c]` flattened images.
    pub images: Vec<f32>,
    /// `[n]` class ids.
    pub labels: Vec<i32>,
    /// Image element count (`h*w*c`).
    pub row: usize,
    pub num_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Borrow image `i`.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * self.row..(i + 1) * self.row]
    }

    /// Materialize a subset by sample index.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut images = Vec::with_capacity(idx.len() * self.row);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        Dataset {
            images,
            labels,
            row: self.row,
            num_classes: self.num_classes,
        }
    }

    /// Per-class sample counts (data-imbalance diagnostics).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

/// One device's local shard plus batching helpers.
#[derive(Clone, Debug)]
pub struct Shard {
    pub data: Dataset,
}

impl Shard {
    /// Copy batch `b` (of `batch` samples) into `(x, y)` buffers, cycling
    /// through the shard when it is smaller than `batch * (b+1)` — every
    /// exported program has a fixed batch shape.
    pub fn fill_batch(&self, b: usize, batch: usize, x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        let n = self.data.len().max(1);
        for s in 0..batch {
            let i = (b * batch + s) % n;
            x.extend_from_slice(self.data.image(i));
            y.push(self.data.labels[i]);
        }
    }

    /// Number of full batches in one local epoch.
    pub fn batches_per_epoch(&self, batch: usize) -> usize {
        (self.data.len().max(1)).div_ceil(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            images: (0..12).map(|x| x as f32).collect(),
            labels: vec![0, 1, 2],
            row: 4,
            num_classes: 3,
        }
    }

    #[test]
    fn subset_and_image() {
        let d = tiny();
        assert_eq!(d.image(1), &[4.0, 5.0, 6.0, 7.0]);
        let s = d.subset(&[2, 0]);
        assert_eq!(s.labels, vec![2, 0]);
        assert_eq!(s.image(0), &[8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn histogram() {
        let d = tiny();
        assert_eq!(d.class_histogram(), vec![1, 1, 1]);
    }

    #[test]
    fn batch_cycles() {
        let shard = Shard { data: tiny() };
        let mut x = Vec::new();
        let mut y = Vec::new();
        shard.fill_batch(0, 5, &mut x, &mut y);
        assert_eq!(y, vec![0, 1, 2, 0, 1]);
        assert_eq!(x.len(), 20);
        assert_eq!(shard.batches_per_epoch(2), 2);
    }
}
