"""Shared-sparse-mask sparsification (paper eq. 10-12, 28) as Pallas kernels.

FedAdam-SSM uploads ``(dW, dM, dV)`` all masked by ONE mask — the top-k mask
of ``|dW|`` (eq. 28).  The hot loop is therefore: one global threshold
reduction over ``|dW|`` followed by a *single* fused element-wise pass that
masks all three vectors.  Fusing the three mask-applies into one kernel
reads ``dW`` once for both the compare and the multiply, which matters on a
bandwidth-bound roofline (DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.adam_update import BLOCK
from compile.kernels.topk import topk_threshold


def _sparsify3_kernel(dw_ref, dm_ref, dv_ref, t_ref, wo_ref, mo_ref, vo_ref):
    dw = dw_ref[...]
    keep = (jnp.abs(dw) >= t_ref[0]).astype(jnp.float32)
    wo_ref[...] = dw * keep
    mo_ref[...] = dm_ref[...] * keep
    vo_ref[...] = dv_ref[...] * keep


@functools.partial(jax.jit, static_argnames=("block",))
def ssm_sparsify3(dw, dm, dv, k, *, block=BLOCK):
    """Apply the SSM (top-k mask of ``|dw|``) to all three update vectors.

    Args:
      dw, dm, dv: ``f32[d]`` updates of local model parameters and first /
        second moment estimates (paper's \\Delta W_n^t, \\Delta M_n^t,
        \\Delta V_n^t).
      k: scalar int32 number of kept coordinates; may be traced (runtime
        sparsification-ratio knob, Fig. 5).

    Returns:
      ``(dw_hat, dm_hat, dv_hat)`` — the sparse triple of eq. 10-12.
    """
    d = dw.shape[0]
    tau = topk_threshold(dw, k)
    dpad = (d + block - 1) // block * block
    pad = dpad - d

    def padf(x):
        return jnp.pad(x, (0, pad)) if pad else x

    spec = pl.BlockSpec((block,), lambda i: (i,))
    tspec = pl.BlockSpec((1,), lambda i: (0,))
    outs = pl.pallas_call(
        _sparsify3_kernel,
        grid=(dpad // block,),
        in_specs=[spec, spec, spec, tspec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((dpad,), jnp.float32)] * 3,
        interpret=True,
    )(padf(dw), padf(dm), padf(dv), tau[None])
    if pad:
        outs = tuple(o[:d] for o in outs)
    return tuple(outs)


def _apply_mask_kernel(x_ref, m_ref, o_ref):
    o_ref[...] = x_ref[...] * m_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def apply_mask(x, mask, *, block=BLOCK):
    """Element-wise ``x * mask`` as a blocked Pallas pass (eq. 6)."""
    d = x.shape[0]
    dpad = (d + block - 1) // block * block
    pad = dpad - d
    xp = jnp.pad(x, (0, pad)) if pad else x
    mp = jnp.pad(mask, (0, pad)) if pad else mask
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        _apply_mask_kernel,
        grid=(dpad // block,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((dpad,), jnp.float32),
        interpret=True,
    )(xp, mp)
    return out[:d] if pad else out
