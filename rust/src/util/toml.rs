//! TOML-subset parser for experiment config files.
//!
//! Supported grammar (everything the configs in `configs/` use):
//! `[section]` headers, `key = value` with string / integer / float / bool /
//! homogeneous array values, `#` comments.  Dotted keys and nested tables
//! beyond one section level are not supported — configs stay flat on
//! purpose.

use std::collections::BTreeMap;
use std::fmt;

/// A scalar or array config value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section -> key -> value`; top-level keys live under `""`.
pub type Doc = BTreeMap<String, BTreeMap<String, TomlValue>>;

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Doc, TomlError> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();

    for (ln, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| TomlError {
                line: ln + 1,
                msg: "unterminated section header".into(),
            })?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| TomlError {
            line: ln + 1,
            msg: format!("expected key = value, got {line:?}"),
        })?;
        let key = line[..eq].trim().to_string();
        let val = parse_value(line[eq + 1..].trim(), ln + 1)?;
        doc.get_mut(&section).unwrap().insert(key, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<TomlValue, TomlError> {
    let err = |msg: String| TomlError { line, msg };
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                out.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(format!("cannot parse value {s:?}")))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let doc = parse(
            r#"
# experiment
name = "fig2"   # inline comment
rounds = 100
lr = 0.001
iid = false

[data]
alpha = 0.1
devices = 20
classes = [0, 1, 2]
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("fig2"));
        assert_eq!(doc[""]["rounds"].as_i64(), Some(100));
        assert_eq!(doc[""]["lr"].as_f64(), Some(0.001));
        assert_eq!(doc[""]["iid"].as_bool(), Some(false));
        assert_eq!(doc["data"]["devices"].as_i64(), Some(20));
        assert_eq!(
            doc["data"]["classes"],
            TomlValue::Arr(vec![TomlValue::Int(0), TomlValue::Int(1), TomlValue::Int(2)])
        );
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc[""]["x"].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @").is_err());
    }

    #[test]
    fn string_with_hash() {
        let doc = parse("s = \"a # b\"").unwrap();
        assert_eq!(doc[""]["s"].as_str(), Some("a # b"));
    }
}
