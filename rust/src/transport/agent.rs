//! The device-agent side of the wire: connect, register, train the
//! devices this agent owns, upload compressed deltas.
//!
//! One agent process hosts a *shard* of the device population: agent
//! `i` of `n` owns every device with `device % n == i`.  Each round the
//! server broadcasts the full cohort ([`Msg::RoundStart`]); the agent
//! filters down to its own slots, runs local training through the same
//! executor seam the in-process coordinator uses, compresses through
//! the same algorithm implementations, and uploads one
//! [`Msg::Uplink`] per slot.
//!
//! ## Bit-identity
//!
//! A remote run reproduces the in-process run byte for byte because
//! every input to a device's round is identical:
//!
//! - the data shards come from [`crate::coordinator::build_task_and_plan`] —
//!   the *same* synthetic generation + partition plan the coordinator
//!   derives, seeded by the shared config (the fingerprint handshake
//!   refuses a drifted config before any training happens); each owned
//!   device's shard is synthesized on demand per round and dropped
//!   after, so resident memory is O(owned-cohort · shard), not O(fleet);
//! - local training is a pure function of `(w, m₀, v₀, run_cfg, shard)`;
//! - all per-device compression state (error-feedback memories, moment
//!   residuals) lives with the device's *owning agent*, and ownership is
//!   static — so each device sees exactly the state history it would
//!   have seen in process, regardless of how agents interleave.
//!   `DeviceLocal` moments live in a lazily-materialized
//!   [`ResidualStore`], so `Aggregated`-policy ids (which never touch
//!   them) cost nothing and touched entries obey
//!   `residual_resident_cap` like everywhere else.
//!
//! ## Duplicate rounds
//!
//! After a connection drop the server replays the current round's
//! `RoundStart` on reconnect.  Retraining would mutate error-feedback
//! state twice and break bit-identity, so the agent caches the encoded
//! uplink frames of its latest round and replays them verbatim for a
//! duplicate round number.
//!
//! ## Durability (`agent_state_dir`)
//!
//! With `agent_state_dir` set, the agent appends one durable
//! [`AgentSnapshot`] (algorithm state, device moments, the round's
//! encoded frames) to its [`AgentStateLog`] per completed round —
//! **after training, before sending** — so a *fresh process* pointed at
//! the same directory resumes bit-identical for every stateful id.
//! [`super::agent_state`]'s module docs walk each crash window; the
//! short version is that the persist-before-send ordering makes the
//! server's `RoundStart` replay and the cached-frame replay cover every
//! interleaving between them.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::algorithms::residual_store::ResidualStore;
use crate::algorithms::{self, Algorithm, LocalDelta, MomentumPolicy};
use crate::config::ExperimentConfig;
use crate::coordinator::{build_task_and_plan, compress_wire_with, local_run_cfg, Device};
use crate::data::Shard;
use crate::runtime::{EnginePool, Manifest};
use crate::tensor;
use crate::util::bytes::{ByteReader, ByteWriter};

use super::agent_state::{AgentSnapshot, AgentStateLog};
use super::frame::{read_frame, write_frame, FrameError};
use super::msg::{Msg, Uplink, PROTOCOL_VERSION};
use super::net::Stream;

/// Crash injection for the kill-respawn durability suite: the agent
/// returns (as a killed process would, from the server's point of view)
/// at a precise point in the persist/send ordering.  Production callers
/// use [`run_agent`], which never exits early.
#[derive(Clone, Copy, Debug, Default)]
pub struct AgentOptions {
    /// Exit after round `r` completed fully: state persisted *and*
    /// uplinks sent.
    pub exit_after_round: Option<u64>,
    /// Exit after round `r`'s state was persisted but **before any of
    /// its uplinks were sent** — the crash window that only the durable
    /// cached-frame replay can repair without double-mutating
    /// error-feedback state.
    pub exit_before_send_round: Option<u64>,
}

/// [`run_agent`] with the engine pool built from AOT artifacts — the
/// `device-agent` binary's entry point.  Worker resolution mirrors
/// [`crate::coordinator::Coordinator::new`]; the worker count has no
/// bearing on the bits produced (each device's round is a pure function
/// of its inputs).
pub fn run_agent_from_artifacts(
    cfg: &ExperimentConfig,
    artifacts: impl AsRef<std::path::Path>,
    addr: &str,
    index: usize,
) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let workers = crate::runtime::pool::resolve_workers(cfg.num_workers).min(cfg.devices);
    let pool = EnginePool::load(&manifest, &cfg.model, workers)
        .with_context(|| format!("loading model {:?}", cfg.model))?;
    run_agent(cfg, &pool, addr, index)
}

/// Connect to the server at `addr`, register as agent `index`, and
/// serve rounds until the server sends [`Msg::Shutdown`].
pub fn run_agent(
    cfg: &ExperimentConfig,
    pool: &EnginePool,
    addr: &str,
    index: usize,
) -> Result<()> {
    run_agent_with(cfg, pool, addr, index, &AgentOptions::default())
}

/// [`run_agent`] with [`AgentOptions`] crash injection (tests only).
pub fn run_agent_with(
    cfg: &ExperimentConfig,
    pool: &EnginePool,
    addr: &str,
    index: usize,
    opts: &AgentOptions,
) -> Result<()> {
    cfg.validate()?;
    let meta = pool.meta().clone();
    let mut stream = Stream::connect(addr)?;
    write_frame(
        &mut stream,
        &Msg::Hello {
            version: PROTOCOL_VERSION,
            fingerprint: cfg.fingerprint(),
            agent: index as u32,
        }
        .encode(),
    )
    .map_err(|e| anyhow::anyhow!("sending Hello: {e}"))?;
    let ack = read_frame(&mut stream).map_err(|e| anyhow::anyhow!("reading HelloAck: {e}"))?;
    let Msg::HelloAck { agents, dim } = Msg::decode(&ack)? else {
        bail!("expected HelloAck");
    };
    let agents = agents as usize;
    ensure!(index < agents, "agent index {index} out of range ({agents} agents)");
    ensure!(
        dim as usize == meta.dim,
        "model dimension mismatch: server says {dim}, local model has {}",
        meta.dim
    );
    log::info!("agent {index}/{agents} registered with {addr} (dim {dim})");

    // The agent's world, O(owned-cohort) resident: the shared corpus +
    // shard plan (same seeds as the coordinator — shards synthesize per
    // round on demand), the algorithm state, and the device-local
    // moments in a lazily-materialized store (`Aggregated`-policy ids
    // never touch it, so it costs nothing for them).
    let (task, plan) = build_task_and_plan(cfg, pool);
    let mut algorithm = algorithms::build(cfg, meta.dim)?;
    let mut device_moments =
        ResidualStore::new(2 * meta.dim, cfg.residual_resident_cap, &cfg.residual_spill_dir);
    let run_cfg = local_run_cfg(cfg);
    let handle = pool.handle();

    // The latest round's encoded uplink frames, replayed verbatim if the
    // server re-sends that round (see the module docs).
    let mut cached: Option<(u64, Vec<Vec<u8>>)> = None;

    // Durability: open the state log and restore the previous
    // incarnation's checkpoint, if any.
    let mut state_log: Option<AgentStateLog> = None;
    let mut last_snap: Option<AgentSnapshot> = None;
    if !cfg.agent_state_dir.is_empty() {
        let (slog, restored) = AgentStateLog::open(
            Path::new(&cfg.agent_state_dir),
            index,
            agents,
            cfg.fingerprint(),
            meta.dim,
            cfg.snapshot_every,
        )?;
        if let Some(snap) = restored {
            let mut r = ByteReader::new(&snap.algorithm);
            algorithm
                .load_state(&mut r)
                .context("restoring algorithm state from the agent state log")?;
            r.finish()?;
            let mut r = ByteReader::new(&snap.moments);
            device_moments
                .load_state(&mut r)
                .context("restoring device moments from the agent state log")?;
            r.finish()?;
            cached = Some((snap.round, snap.frames.clone()));
            last_snap = Some(snap);
        }
        state_log = Some(slog);
    }

    loop {
        let payload = match read_frame(&mut stream) {
            Ok(p) => p,
            Err(FrameError::Closed) => bail!("server closed the connection without Shutdown"),
            Err(e) => bail!("reading from server: {e}"),
        };
        match Msg::decode(&payload).context("decoding server message")? {
            Msg::RoundStart { round, w, m, v, assignments } => {
                if let Some((r, frames)) = &cached {
                    if *r == round {
                        log::info!("agent {index}: replaying cached uplinks for round {round}");
                        for frame in frames {
                            stream.write_all(frame)?;
                        }
                        stream.flush()?;
                        continue;
                    }
                }
                let t = round as usize;
                let mode = algorithm.local_mode(t);
                let policy = algorithm.momentum_policy(t);
                let mut frames: Vec<Vec<u8>> = Vec::new();
                for a in assignments.iter().filter(|a| a.device as usize % agents == index) {
                    let di = a.device as usize;
                    ensure!(
                        di < cfg.devices,
                        "assignment names device {di} but only {} exist",
                        cfg.devices
                    );
                    let (m0, v0) = match policy {
                        MomentumPolicy::Aggregated => {
                            let m = m
                                .as_ref()
                                .context("Aggregated moments missing from RoundStart")?;
                            let v = v
                                .as_ref()
                                .context("Aggregated moments missing from RoundStart")?;
                            (m.clone(), v.clone())
                        }
                        MomentumPolicy::DeviceLocal => {
                            let entry = device_moments.get_mut(di as u64);
                            let (em, ev) = entry.split_at(meta.dim);
                            (em.to_vec(), ev.to_vec())
                        }
                    };
                    // Synthesize this device's shard on demand (exactly
                    // the bytes the eager partition would have built) and
                    // drop it with the round.
                    let data = plan.materialize(&task.train, di);
                    let mut device = Device::new(di, Shard { data }, handle.clone());
                    let result =
                        device.train_round(mode, w.clone(), m0.clone(), v0.clone(), &run_cfg)?;
                    let delta = LocalDelta {
                        dw: tensor::sub(&result.w, &w),
                        dm: tensor::sub(&result.m, &m0),
                        dv: tensor::sub(&result.v, &v0),
                        weight: a.weight,
                    };
                    let mean_loss = result.mean_loss;
                    if policy == MomentumPolicy::DeviceLocal {
                        let entry = device_moments.get_mut(di as u64);
                        entry[..meta.dim].copy_from_slice(&result.m);
                        entry[meta.dim..].copy_from_slice(&result.v);
                    }
                    let wire = compress_wire_with(cfg, &handle, algorithm.as_mut(), t, di, delta)?;
                    let body = wire.encode_body()?;
                    let msg = Msg::Uplink(Uplink {
                        round,
                        slot: a.slot,
                        device: a.device,
                        mean_loss,
                        weight: wire.weight,
                        kind: wire.body.kind(),
                        k: wire.body.k() as u64,
                        levels: wire.body.levels(),
                        bits: wire.bits,
                        body,
                    });
                    let mut frame = Vec::new();
                    write_frame(&mut frame, &msg.encode()).expect("Vec<u8> writes cannot fail");
                    frames.push(frame);
                }
                // Durability ordering: persist the completed round BEFORE
                // sending any of its frames.  A crash before this append
                // sent the server nothing (it will replay the round and
                // the restored agent retrains it deterministically); a
                // crash after it replays the durable frames verbatim.
                if let Some(slog) = state_log.as_mut() {
                    let snap = snapshot(round, algorithm.as_ref(), &device_moments, &frames);
                    slog.append(&snap)?;
                    last_snap = Some(snap);
                }
                if opts.exit_before_send_round == Some(round) {
                    log::info!("agent {index}: injected exit before sending round {round}");
                    return Ok(());
                }
                for frame in &frames {
                    stream.write_all(frame)?;
                }
                stream.flush()?;
                cached = Some((round, frames));
                if opts.exit_after_round == Some(round) {
                    log::info!("agent {index}: injected exit after round {round}");
                    return Ok(());
                }
            }
            Msg::Shutdown => {
                // Clean shutdown: leave the log compacted to header +
                // final state so the directory is tidy for inspection.
                if let (Some(slog), Some(snap)) = (state_log.as_mut(), last_snap.as_ref()) {
                    slog.compact(snap)?;
                }
                log::info!("agent {index}: server sent Shutdown, exiting");
                return Ok(());
            }
            other => bail!("unexpected message from server: {other:?}"),
        }
    }
}

/// Assemble the durable checkpoint for one completed round.
fn snapshot(
    round: u64,
    algorithm: &dyn Algorithm,
    device_moments: &ResidualStore,
    frames: &[Vec<u8>],
) -> AgentSnapshot {
    let mut alg = ByteWriter::new();
    algorithm.save_state(&mut alg);
    let mut mom = ByteWriter::new();
    device_moments.save_state(&mut mom);
    AgentSnapshot {
        round,
        algorithm: alg.into_inner(),
        moments: mom.into_inner(),
        frames: frames.to_vec(),
    }
}
