//! Centralized Adam — the "desired model" of Theorem 1.
//!
//! Runs the paper's Adam update (eq. 13-15) in pure rust given a gradient
//! oracle (the AOT `grads` program over the pooled dataset).  Used by the
//! theory harness (`examples/theory_bounds.rs`) to measure the actual
//! divergence `‖w_n^{l,t} − w̌^{l,t}‖` against the Theorem-1 bound, and by
//! unit tests as an independent reference implementation of eq. 3-5.

/// Paper Adam constants.
#[derive(Clone, Copy, Debug)]
pub struct AdamParams {
    pub eta: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams {
            eta: 0.001,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
        }
    }
}

/// In-place Adam state over flat vectors.
#[derive(Clone, Debug)]
pub struct CentralizedAdam {
    pub w: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub params: AdamParams,
}

impl CentralizedAdam {
    pub fn new(w0: Vec<f32>, params: AdamParams) -> Self {
        let d = w0.len();
        CentralizedAdam {
            w: w0,
            m: vec![0.0; d],
            v: vec![0.0; d],
            params,
        }
    }

    /// Seed the moments (Theorem 1 starts the auxiliary sequence from the
    /// non-sparse global state M̃, Ṽ).
    pub fn with_moments(mut self, m: Vec<f32>, v: Vec<f32>) -> Self {
        assert_eq!(m.len(), self.w.len());
        assert_eq!(v.len(), self.w.len());
        self.m = m;
        self.v = v;
        self
    }

    /// One Adam step with gradient `g` (paper eq. 3-5 / 13-15: eps inside
    /// the sqrt, no bias correction). Identical arithmetic to the Layer-1
    /// Pallas kernel.
    pub fn step(&mut self, g: &[f32]) {
        let AdamParams {
            eta,
            beta1,
            beta2,
            eps,
        } = self.params;
        for i in 0..self.w.len() {
            let gi = g[i];
            self.m[i] = beta1 * self.m[i] + (1.0 - beta1) * gi;
            self.v[i] = beta2 * self.v[i] + (1.0 - beta2) * gi * gi;
            self.w[i] -= eta * self.m[i] / (self.v[i] + eps).sqrt();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_matches_formula() {
        let p = AdamParams {
            eta: 0.1,
            beta1: 0.9,
            beta2: 0.99,
            eps: 1e-6,
        };
        let mut opt = CentralizedAdam::new(vec![1.0], p);
        opt.step(&[2.0]);
        let m = 0.1 * 2.0;
        let v = 0.01 * 4.0;
        let w = 1.0 - 0.1 * m / ((v + 1e-6) as f32).sqrt();
        assert!((opt.m[0] - m).abs() < 1e-7);
        assert!((opt.v[0] - v).abs() < 1e-7);
        assert!((opt.w[0] - w).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        // f(w) = 0.5 * ||w - target||^2, grad = w - target.
        let target = [3.0f32, -2.0, 0.5];
        let mut opt = CentralizedAdam::new(
            vec![0.0; 3],
            AdamParams {
                eta: 0.05,
                ..Default::default()
            },
        );
        for _ in 0..2000 {
            let g: Vec<f32> = opt.w.iter().zip(&target).map(|(w, t)| w - t).collect();
            opt.step(&g);
        }
        for (w, t) in opt.w.iter().zip(&target) {
            assert!((w - t).abs() < 0.05, "{w} vs {t}");
        }
    }

    #[test]
    fn with_moments_seeds_state() {
        let opt = CentralizedAdam::new(vec![0.0; 2], AdamParams::default())
            .with_moments(vec![1.0, 2.0], vec![3.0, 4.0]);
        assert_eq!(opt.m, vec![1.0, 2.0]);
        assert_eq!(opt.v, vec![3.0, 4.0]);
    }
}
