//! Quantizer microbench: 1-bit EF and s-level uniform compressors
//! (the baselines' hot path) across model dimensions.
//!
//! Run: `cargo bench --bench quant`.

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::quant::{onebit_compress, uniform_compress, ErrorFeedback};
use fedadam_ssm::rng::Rng;

fn main() {
    let mut bench = from_env();
    let mut rng = Rng::new(3);

    for &d in &[54_314usize, 176_778, 1_663_370] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(d);
        bench.run(format!("onebit+EF d={d}"), || {
            black_box(onebit_compress(&x, &mut ef));
        });
        for &s in &[4u32, 16, 256] {
            bench.run(format!("uniform s={s} d={d}"), || {
                black_box(uniform_compress(&x, s));
            });
        }
    }

    bench.report("quantizers");
    println!("\n{}", bench.to_csv());
}
