//! `device-agent` — the remote device-shard process of a transport run.
//!
//! One coordinator (`fedadam-ssm run --set transport_listen=...`) plus
//! `transport_agents` copies of this binary make a multi-process
//! federated run; agent `i` owns every device with
//! `device % transport_agents == i`.  The agent must resolve the **same
//! experiment config** as the server (same file / same `--set`s) — the
//! registration handshake refuses a mismatched config fingerprint.
//!
//! Example (two agents against a server on port 7000):
//! ```text
//! device-agent --connect 127.0.0.1:7000 --agent 0 --config exp.toml &
//! device-agent --connect 127.0.0.1:7000 --agent 1 --config exp.toml &
//! ```

use std::io::Write as _;

use anyhow::{Context, Result};

use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::transport::agent::run_agent_from_artifacts;

/// Minimal stderr logger (offline build: no tracing-subscriber).
struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

static LOGGER: StderrLogger = StderrLogger;

const USAGE: &str = "\
device-agent — remote device shard for a fedadam-ssm transport run

USAGE:
    device-agent --connect <addr> --agent <index> [OPTIONS]

OPTIONS:
    --connect <addr>      server address: host:port or unix:/path [required]
    --agent <index>       this agent's index in 0..transport_agents [required]
    --artifacts <dir>     AOT artifacts directory [default: artifacts]
    --config <file>       TOML experiment config — must resolve to the same
                          config fingerprint as the server's, or the
                          registration handshake is refused
    --set key=value       override one config key (repeatable; notably
                          --set agent_state_dir=DIR journals this agent's
                          per-device compressor state to DIR/agent_<i>.state
                          each round, so a killed agent process restarted
                          with the same flags resumes bit-identically)
    --verbose             debug logging
";

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if cli.flag("help") {
        println!("{USAGE}");
        return;
    }
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(if cli.flag("verbose") {
        log::LevelFilter::Debug
    } else {
        log::LevelFilter::Info
    });
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cli: &Cli) -> Result<()> {
    let mut cfg = match cli.opt("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    for (k, v) in &cli.sets {
        cfg.set(k, v)?;
    }
    cfg.validate()?;
    let addr = cli.opt("connect").context("--connect <addr> is required")?;
    let index: usize = cli
        .opt_parse("agent")?
        .context("--agent <index> is required")?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    run_agent_from_artifacts(&cfg, artifacts, addr, index)
}
