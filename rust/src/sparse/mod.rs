//! Sparse transport: top-k selection, sparse vectors and wire encodings.
//!
//! The paper's uplink is either a dense vector (`FedAdam`), three sparse
//! vectors with three masks (`FedAdam-Top`), or three sparse vectors under
//! one shared mask (`FedAdam-SSM` and the other SSM variants).  This module
//! provides the shared substrate:
//!
//! - [`topk`] — exact-k selection via quickselect with by-index tie break;
//! - [`SparseVec`] — indices + values with dense round-trips;
//! - [`codec`] — the paper's bit-cost model (`§IV`, `§VII-A`), including
//!   the `min{bitmask, index-list}` encoding rule.

pub mod codec;
pub mod topk;

pub use topk::{top_k_indices, top_k_threshold};

/// A sparse view of an `f32[dim]` vector: sorted unique indices + values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Gather `values = dense[indices]`; `indices` must be sorted unique.
    pub fn gather(dense: &[f32], indices: &[u32]) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        SparseVec {
            dim: dense.len(),
            values: indices.iter().map(|&i| dense[i as usize]).collect(),
            indices: indices.to_vec(),
        }
    }

    /// Build from a dense vector by keeping its non-zeros.
    ///
    /// NOT suitable for reconstructing a priced top-k support from a
    /// masked dense vector: a kept lane whose value is exactly `0.0` is
    /// indistinguishable from a masked-out lane here and gets dropped,
    /// leaving `nnz < k` while the cost model charged for `k`.  Use
    /// [`SparseVec::gather`] with the mask's index list instead (see
    /// `Coordinator::compress_upload`'s XLA path).
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        SparseVec {
            dim: dense.len(),
            indices,
            values,
        }
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Positions `(a, b)` into `indices`/`values` such that
    /// `indices[a..b]` are exactly the stored lanes in `[lo, hi)`.
    ///
    /// `O(log nnz)` via binary search (indices are sorted unique) — the
    /// sharded server reduce uses this to restrict a payload to one
    /// contiguous lane shard without scanning the whole support.
    pub fn index_range(&self, lo: u32, hi: u32) -> (usize, usize) {
        let a = self.indices.partition_point(|&i| i < lo);
        let b = self.indices.partition_point(|&i| i < hi);
        (a, b)
    }

    /// Scatter back to a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.scatter_into(&mut out);
        out
    }

    /// `out[indices] = values` without clearing other lanes.
    pub fn scatter_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
    }

    /// `out[indices] += w * values` — the server's sparse accumulate.
    pub fn axpy_into(&self, out: &mut [f32], w: f32) {
        debug_assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] += w * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let sv = SparseVec::from_dense(&dense);
        assert_eq!(sv.nnz(), 2);
        assert_eq!(sv.indices, vec![1, 3]);
        assert_eq!(sv.to_dense(), dense);
    }

    #[test]
    fn gather_matches_dense() {
        let dense = vec![5.0, 6.0, 7.0, 8.0];
        let sv = SparseVec::gather(&dense, &[0, 2]);
        assert_eq!(sv.values, vec![5.0, 7.0]);
        assert_eq!(sv.to_dense(), vec![5.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn gather_preserves_zero_valued_masked_lanes() {
        // Regression for the XLA sparsify upload path: with
        // x = [5, 0, 0, 0] and k = 2 the top-k mask is {0, 1} (zero-valued
        // lane 1 wins the tie on index), and the masked dense output looks
        // identical to the input.  Reconstructing the upload support from
        // the mask indices must keep BOTH priced lanes; `from_dense` on
        // the masked vector silently drops the zero-valued one.
        use crate::sparse::top_k_indices;
        let dw = vec![5.0f32, 0.0, 0.0, 0.0];
        let masked = dw.clone(); // what the kernel returns for k = 2
        let idx = top_k_indices(&dw, 2);
        assert_eq!(idx, vec![0, 1]);
        let upload = SparseVec::gather(&masked, &idx);
        assert_eq!(upload.nnz(), 2, "support must match the priced k");
        assert_eq!(upload.values, vec![5.0, 0.0]);
        assert_eq!(
            SparseVec::from_dense(&masked).nnz(),
            1,
            "from_dense undercounts — the bug this guards against"
        );
        // Round-trip stays faithful.
        assert_eq!(upload.to_dense(), masked);
    }

    #[test]
    fn index_range_brackets_sorted_indices() {
        let sv = SparseVec {
            dim: 10,
            indices: vec![1, 3, 4, 8],
            values: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(sv.index_range(0, 10), (0, 4));
        assert_eq!(sv.index_range(2, 5), (1, 3)); // lanes {3, 4}
        assert_eq!(sv.index_range(5, 8), (3, 3)); // empty
        assert_eq!(sv.index_range(8, 9), (3, 4));
        assert_eq!(sv.index_range(3, 3), (1, 1)); // degenerate range
    }

    #[test]
    fn axpy_accumulates_sparse() {
        let sv = SparseVec {
            dim: 4,
            indices: vec![1, 3],
            values: vec![2.0, 4.0],
        };
        let mut out = vec![1.0; 4];
        sv.axpy_into(&mut out, 0.5);
        assert_eq!(out, vec![1.0, 2.0, 1.0, 3.0]);
    }
}
