//! Lazily materialized, disk-spilling per-device residual store.
//!
//! The `-ef` / `-qef` / `onebit` / `efficient` algorithm ids and the
//! coordinator's device-local Adam moments all keep **per-device** state:
//! fixed-width `f32` vectors indexed by device id.  Holding them dense
//! (`Vec<Memory>` sized to the fleet) costs O(num_devices) RAM even though
//! a round only touches O(cohort) devices — a non-starter at the 10⁶+
//! registered devices cross-device FL is motivated by.
//!
//! [`ResidualStore`] replaces the dense vectors with three tiers:
//!
//! 1. **untouched** — a device the run never sampled owns *no* state at
//!    all; its entry is defined to be all-zeros and materializes on first
//!    [`ResidualStore::get_mut`];
//! 2. **resident** — up to `resident_cap` recently-touched entries live in
//!    RAM (`resident_cap = 0` means unbounded, i.e. dense-equivalent);
//! 3. **spilled** — beyond the cap, the least-recently-used entry is
//!    evicted to a fixed-slot spill file under `spill_dir` and reloaded on
//!    the next touch.
//!
//! ## Exact-rehydration contract
//!
//! Spilling is invisible to the numbers: entries round-trip through disk
//! as **raw little-endian `f32` bits**, so `-0.0`, subnormals and even NaN
//! payloads survive evict→reload bit-identically, and a capped store is
//! bit-identical to an unbounded one for every read sequence.  Snapshots
//! ([`ResidualStore::save_state`]) serialize only *touched* entries (in
//! ascending id order), so journal snapshots stay O(touched), and
//! [`ResidualStore::load_state`] restores them regardless of which tier
//! each entry happened to occupy when saved.
//!
//! ```
//! use fedadam_ssm::algorithms::residual_store::ResidualStore;
//!
//! let dir = std::env::temp_dir().join(format!("fedadam-doc-rs-{}", std::process::id()));
//! std::fs::create_dir_all(&dir).unwrap();
//!
//! // Cap of 1 resident entry: touching a second device evicts the first.
//! let mut store = ResidualStore::new(3, 1, dir.to_str().unwrap());
//! store.get_mut(7).copy_from_slice(&[-0.0, 1.0e-42, f32::MIN_POSITIVE]);
//! store.get_mut(999_983); // device id far above the cap — evicts 7 to disk
//! assert!(!store.is_resident(7));
//!
//! // Evict → reload is bit-identical, signed zero and subnormal included.
//! let back = store.peek(7).unwrap();
//! assert_eq!(back[0].to_bits(), (-0.0f32).to_bits());
//! assert_eq!(back[1].to_bits(), 1.0e-42f32.to_bits());
//! assert_eq!(back[2].to_bits(), f32::MIN_POSITIVE.to_bits());
//!
//! drop(store); // removes its spill file
//! std::fs::remove_dir_all(&dir).ok();
//! ```

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{ensure, Result};

use crate::util::bytes::{ByteReader, ByteWriter};

/// Monotonic suffix so several stores (coordinator moments + algorithm
/// residuals) can share one `spill_dir` without filename collisions.
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(0);

/// One resident entry: the vector plus its LRU tick.
#[derive(Clone, Debug)]
struct Resident {
    data: Vec<f32>,
    tick: u64,
}

/// A sparse, LRU-spilling map from device id to a fixed-width `f32`
/// vector (see the [module docs](self) for the tiering and the
/// exact-rehydration contract).
///
/// All disk I/O goes through [`std::os::unix::fs::FileExt`] positioned
/// reads/writes on one spill file, so reads need only `&self` — which is
/// what lets [`ResidualStore::save_state`] match the `&self` signature of
/// `Algorithm::save_state`.  I/O errors on the spill path panic with
/// context: the store cannot return a partial entry without silently
/// breaking bit-identity.
#[derive(Debug)]
pub struct ResidualStore {
    entry_dim: usize,
    resident_cap: usize,
    spill_dir: String,
    store_id: u64,
    resident: BTreeMap<u64, Resident>,
    /// Spilled entries: device id → fixed slot index in the spill file.
    /// A slot is assigned on first spill and owned for the store's life.
    slots: BTreeMap<u64, u64>,
    next_slot: u64,
    spill: Option<(File, PathBuf)>,
    tick: u64,
}

impl ResidualStore {
    /// A store of `entry_dim`-wide entries keeping at most `resident_cap`
    /// of them in RAM (`0` = unbounded, never touches disk).  `spill_dir`
    /// may be empty iff the cap is `0`; the spill file itself is created
    /// lazily on the first eviction and removed on drop.
    pub fn new(entry_dim: usize, resident_cap: usize, spill_dir: &str) -> ResidualStore {
        assert!(entry_dim > 0, "residual store entries must be non-empty");
        assert!(
            resident_cap == 0 || !spill_dir.is_empty(),
            "residual_resident_cap > 0 requires residual_spill_dir"
        );
        ResidualStore {
            entry_dim,
            resident_cap,
            spill_dir: spill_dir.to_string(),
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            resident: BTreeMap::new(),
            slots: BTreeMap::new(),
            next_slot: 0,
            spill: None,
            tick: 0,
        }
    }

    /// Width of every entry.
    pub fn entry_dim(&self) -> usize {
        self.entry_dim
    }

    /// Number of entries ever touched.  A resident entry may *also* own a
    /// spill slot from an earlier eviction, so this is a union count.
    pub fn touched(&self) -> usize {
        let resident_only = self
            .resident
            .keys()
            .filter(|id| !self.slots.contains_key(id))
            .count();
        resident_only + self.slots.len()
    }

    /// Whether `id`'s entry currently lives in RAM (diagnostics / tests;
    /// the answer never affects values, only where they are stored).
    pub fn is_resident(&self, id: u64) -> bool {
        self.resident.contains_key(&id)
    }

    /// Mutable access to `id`'s entry, materializing zeros on first touch
    /// and rehydrating from the spill file if it was evicted.  May evict
    /// the least-recently-used *other* entry to disk.
    pub fn get_mut(&mut self, id: u64) -> &mut [f32] {
        self.tick += 1;
        let tick = self.tick;
        if !self.resident.contains_key(&id) {
            // A previously-spilled entry keeps its slot for the next
            // eviction; the resident copy shadows the disk copy meanwhile.
            let data = match self.slots.get(&id).copied() {
                Some(slot) => self.read_slot(slot),
                None => vec![0.0f32; self.entry_dim],
            };
            self.evict_down_to(self.resident_cap.saturating_sub(1), id);
            self.resident.insert(id, Resident { data, tick });
        }
        let entry = self.resident.get_mut(&id).expect("entry just ensured resident");
        entry.tick = tick;
        &mut entry.data
    }

    /// Non-promoting read of `id`'s entry from whichever tier holds it;
    /// `None` if the device was never touched.  Does not move the entry
    /// or advance the LRU clock — safe for tests and snapshots.
    pub fn peek(&self, id: u64) -> Option<Vec<f32>> {
        if let Some(entry) = self.resident.get(&id) {
            return Some(entry.data.clone());
        }
        self.slots.get(&id).map(|&slot| self.read_slot(slot))
    }

    /// Serialize every touched entry (ascending id, raw `f32` bits) —
    /// O(touched), not O(fleet).  Read-only: tiering is unchanged.
    pub fn save_state(&self, out: &mut ByteWriter) {
        let mut ids: Vec<u64> = self.resident.keys().chain(self.slots.keys()).copied().collect();
        ids.sort_unstable();
        ids.dedup();
        out.put_usize(ids.len());
        for id in ids {
            out.put_u64(id);
            let data = self.peek(id).expect("touched id must have an entry");
            out.put_f32s(&data);
        }
    }

    /// Restore an exact [`ResidualStore::save_state`] image: all prior
    /// entries (and the spill file) are discarded, then the snapshot's
    /// entries are re-inserted in ascending id order under the same cap,
    /// re-spilling as needed.
    pub fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        self.resident.clear();
        self.slots.clear();
        self.next_slot = 0;
        self.tick = 0;
        if let Some((file, _)) = &self.spill {
            file.set_len(0)
                .unwrap_or_else(|e| panic!("residual store: truncating spill file: {e}"));
        }
        let n = input.take_usize()?;
        let mut prev: Option<u64> = None;
        for _ in 0..n {
            let id = input.take_u64()?;
            ensure!(
                prev.map_or(true, |p| p < id),
                "residual store snapshot ids must be strictly ascending"
            );
            prev = Some(id);
            let data = input.take_f32s()?;
            ensure!(
                data.len() == self.entry_dim,
                "residual store snapshot entry has dim {}, store expects {}",
                data.len(),
                self.entry_dim
            );
            self.tick += 1;
            let tick = self.tick;
            self.evict_down_to(self.resident_cap.saturating_sub(1), id);
            self.resident.insert(id, Resident { data, tick });
        }
        Ok(())
    }

    /// Evict least-recently-used residents until at most `keep` remain
    /// (no-op when the cap is `0` = unbounded).  `incoming` is the id
    /// about to be inserted — never evicted, and exempt from the count.
    fn evict_down_to(&mut self, keep: usize, incoming: u64) {
        if self.resident_cap == 0 {
            return;
        }
        while self.resident.len() > keep {
            let victim = self
                .resident
                .iter()
                .filter(|(&id, _)| id != incoming)
                .min_by_key(|(&id, e)| (e.tick, id))
                .map(|(&id, _)| id);
            let Some(victim) = victim else { break };
            let entry = self.resident.remove(&victim).expect("victim is resident");
            let slot = *self.slots.entry(victim).or_insert_with(|| {
                let s = self.next_slot;
                self.next_slot += 1;
                s
            });
            self.write_slot(slot, &entry.data);
        }
    }

    fn read_slot(&self, slot: u64) -> Vec<f32> {
        let (file, path) = self.spill.as_ref().expect("spilled entry without a spill file");
        let mut buf = vec![0u8; self.entry_dim * 4];
        file.read_exact_at(&mut buf, slot * (self.entry_dim as u64) * 4)
            .unwrap_or_else(|e| {
                panic!("residual store: reading slot {slot} of {}: {e}", path.display())
            });
        buf.chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect()
    }

    fn write_slot(&mut self, slot: u64, data: &[f32]) {
        if self.spill.is_none() {
            let path = PathBuf::from(&self.spill_dir).join(format!(
                "residuals-{}-{}.bin",
                std::process::id(),
                self.store_id
            ));
            std::fs::create_dir_all(&self.spill_dir).unwrap_or_else(|e| {
                panic!("residual store: creating spill dir {}: {e}", self.spill_dir)
            });
            let file = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(&path)
                .unwrap_or_else(|e| panic!("residual store: opening {}: {e}", path.display()));
            self.spill = Some((file, path));
        }
        let mut buf = Vec::with_capacity(data.len() * 4);
        for x in data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        let (file, path) = self.spill.as_ref().expect("spill file just ensured");
        file.write_all_at(&buf, slot * (self.entry_dim as u64) * 4)
            .unwrap_or_else(|e| {
                panic!("residual store: writing slot {slot} of {}: {e}", path.display())
            });
    }
}

impl Drop for ResidualStore {
    fn drop(&mut self) {
        if let Some((_, path)) = self.spill.take() {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("fedadam-rstore-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn first_touch_is_zeros_and_unbounded_never_spills() {
        let mut s = ResidualStore::new(4, 0, "");
        assert_eq!(s.peek(3), None);
        assert_eq!(s.get_mut(3), &[0.0; 4]);
        s.get_mut(3)[1] = 2.5;
        assert_eq!(s.peek(3), Some(vec![0.0, 2.5, 0.0, 0.0]));
        assert_eq!(s.touched(), 1);
        for id in 0..64 {
            s.get_mut(id);
        }
        assert!(s.spill.is_none(), "cap 0 must never create a spill file");
        assert!(s.is_resident(3));
    }

    #[test]
    fn evict_reload_is_bit_identical() {
        let dir = tmp("bits");
        let mut s = ResidualStore::new(3, 2, &dir);
        let nasty = [-0.0f32, 1.0e-42, f32::NAN];
        s.get_mut(0).copy_from_slice(&nasty);
        s.get_mut(1_000_003); // fills the cap
        s.get_mut(7); // evicts id 0 (LRU)
        assert!(!s.is_resident(0));
        let back = s.peek(0).unwrap();
        for (a, b) in back.iter().zip(&nasty) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // get_mut rehydrates the same bits.
        let again = s.get_mut(0).to_vec();
        for (a, b) in again.iter().zip(&nasty) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn capped_matches_unbounded_for_any_access_sequence() {
        let dir = tmp("oracle");
        let mut capped = ResidualStore::new(2, 2, &dir);
        let mut dense = ResidualStore::new(2, 0, "");
        let sequence = [5u64, 900_001, 5, 17, 42, 900_001, 5, 3, 17];
        for (step, &id) in sequence.iter().enumerate() {
            let x = (step as f32 + 1.0) * if step % 2 == 0 { -1.0 } else { 1.0 };
            capped.get_mut(id)[step % 2] += x;
            dense.get_mut(id)[step % 2] += x;
        }
        for &id in &sequence {
            let a = capped.peek(id).unwrap();
            let b = dense.peek(id).unwrap();
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "id {id}"
            );
        }
    }

    #[test]
    fn snapshot_roundtrips_across_tiers() {
        let dir = tmp("snap");
        let mut s = ResidualStore::new(2, 1, &dir);
        s.get_mut(9).copy_from_slice(&[1.5, -0.0]);
        s.get_mut(2).copy_from_slice(&[f32::MIN_POSITIVE, 4.0]); // spills 9
        let mut w = ByteWriter::new();
        s.save_state(&mut w);
        let bytes = w.into_inner();

        let mut restored = ResidualStore::new(2, 1, &dir);
        restored.get_mut(77); // pre-existing state must be discarded
        let mut r = ByteReader::new(&bytes);
        restored.load_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(restored.peek(77), None);
        assert_eq!(restored.touched(), 2);
        for id in [9u64, 2] {
            let a = s.peek(id).unwrap();
            let b = restored.peek(id).unwrap();
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "id {id}"
            );
        }
        // And the restored store keeps working under its cap.
        restored.get_mut(9)[0] += 1.0;
        assert_eq!(restored.peek(9).unwrap()[0], 2.5);
    }

    #[test]
    fn load_rejects_unsorted_and_misshapen_snapshots() {
        let mut w = ByteWriter::new();
        w.put_usize(2);
        w.put_u64(5);
        w.put_f32s(&[1.0, 2.0]);
        w.put_u64(3); // out of order
        w.put_f32s(&[1.0, 2.0]);
        let bytes = w.into_inner();
        let mut s = ResidualStore::new(2, 0, "");
        assert!(s.load_state(&mut ByteReader::new(&bytes)).is_err());

        let mut w = ByteWriter::new();
        w.put_usize(1);
        w.put_u64(0);
        w.put_f32s(&[1.0, 2.0, 3.0]); // wrong entry_dim
        let bytes = w.into_inner();
        let mut s = ResidualStore::new(2, 0, "");
        assert!(s.load_state(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn drop_removes_the_spill_file() {
        let dir = tmp("drop");
        let path;
        {
            let mut s = ResidualStore::new(1, 1, &dir);
            s.get_mut(0);
            s.get_mut(1); // forces a spill
            path = s.spill.as_ref().map(|(_, p)| p.clone()).expect("spill file");
            assert!(path.is_file());
        }
        assert!(!path.exists(), "spill file must be removed on drop");
    }
}
