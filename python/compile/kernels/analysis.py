"""Layer-1 performance model: VMEM footprint + roofline estimates.

interpret=True gives CPU-numpy timings which are NOT a TPU proxy, so the
perf pass for L1 optimizes *structure*: bytes moved per element, operands
resident in VMEM per block, and arithmetic intensity against the TPU
roofline.  This module computes those numbers for every kernel and block
size; ``python -m compile.kernels.analysis`` prints the §Perf table used
in DESIGN.md / EXPERIMENTS.md.

Model (TPU v4 per-core, representative): 16 MiB VMEM, ~1.2 TB/s HBM,
VPU ~4.4e12 f32 FLOP/s (element-wise path; the MXU is irrelevant here —
all L1 kernels are bandwidth-bound).
"""

from __future__ import annotations

import dataclasses

VMEM_BYTES = 16 * 2**20
HBM_BW = 1.2e12  # bytes/s
VPU_FLOPS = 4.4e12  # f32 element-wise

F32 = 4


@dataclasses.dataclass(frozen=True)
class KernelProfile:
    name: str
    #: f32 operands streamed in per element of the flat vector.
    reads_per_elem: int
    #: f32 operands streamed out per element.
    writes_per_elem: int
    #: approximate FLOPs per element (fused arithmetic).
    flops_per_elem: int
    #: operand blocks resident simultaneously (in + out + scratch).
    resident_blocks: int

    def vmem_footprint(self, block: int) -> int:
        """Bytes of VMEM at the chosen block size."""
        return self.resident_blocks * block * F32

    def fits_vmem(self, block: int) -> bool:
        # Leave half of VMEM for double buffering + compiler scratch.
        return self.vmem_footprint(block) * 2 <= VMEM_BYTES

    def bytes_per_elem(self) -> int:
        return (self.reads_per_elem + self.writes_per_elem) * F32

    def arithmetic_intensity(self) -> float:
        """FLOP per HBM byte — far below the ridge => bandwidth-bound."""
        return self.flops_per_elem / self.bytes_per_elem()

    def roofline_time(self, d: int) -> float:
        """Lower-bound runtime (s) on the memory roofline."""
        mem = d * self.bytes_per_elem() / HBM_BW
        compute = d * self.flops_per_elem / VPU_FLOPS
        return max(mem, compute)

    def bound(self) -> str:
        ridge = VPU_FLOPS / HBM_BW  # FLOP/byte at the roofline ridge
        return "memory" if self.arithmetic_intensity() < ridge else "compute"


#: The kernels as written in this package (single fused pass each).
PROFILES = [
    # adam_update: reads w,m,v,g; writes w',m',v'; ~10 flops (2 fma, mul,
    # add, sqrt≈4, div, sub).
    KernelProfile("adam_update", 4, 3, 10, 7),
    # ssm_sparsify3: reads dw,dm,dv (+tau scalar); writes 3 outs; compare+3 muls.
    KernelProfile("ssm_sparsify3", 3, 3, 4, 6),
    # topk_mask compare pass.
    KernelProfile("topk_mask", 1, 1, 2, 2),
    # onebit: reads x,e; writes q,e'; add, cmp, select, sub.
    KernelProfile("onebit_quantize", 2, 2, 4, 4),
    # uniform: read x; write q; div, clamp, fma, round, fma.
    KernelProfile("uniform_quantize", 1, 1, 6, 2),
]


def naive_adam_passes() -> int:
    """Bytes/elem of an UNFUSED Adam (separate m, v, w updates + temps):
    m-pass (r m,g; w m), v-pass (r v,g; w v), w-pass (r w,m,v; w w)."""
    return (2 + 1 + 2 + 1 + 3 + 1) * F32


def report(block: int = 64 * 1024, d: int = 9_750_922) -> str:
    """Markdown §Perf table for dimension `d` (default: VGG-11)."""
    lines = [
        f"L1 roofline model at d={d:,} (VGG-11), block={block} f32 "
        f"({block * F32 // 1024} KiB):",
        "",
        "| kernel | B/elem | resident VMEM | AI (FLOP/B) | bound | roofline t | vs unfused |",
        "|--------|--------|---------------|-------------|-------|------------|------------|",
    ]
    for p in PROFILES:
        fit = "OK" if p.fits_vmem(block) else "OVERFLOW"
        speedup = (
            f"{naive_adam_passes() / p.bytes_per_elem():.2f}x"
            if p.name == "adam_update"
            else "-"
        )
        lines.append(
            f"| {p.name} | {p.bytes_per_elem()} | "
            f"{p.vmem_footprint(block) / 2**20:.2f} MiB ({fit}) | "
            f"{p.arithmetic_intensity():.2f} | {p.bound()} | "
            f"{p.roofline_time(d) * 1e6:.0f} µs | {speedup} |"
        )
    ridge = VPU_FLOPS / HBM_BW
    lines += [
        "",
        f"ridge point {ridge:.1f} FLOP/B — every kernel sits below it: the "
        "correct optimization is minimizing bytes/element, which the fused "
        "single-pass formulation achieves (1 read + 1 write per operand).",
    ]
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
