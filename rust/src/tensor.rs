//! Flat `f32` vector math used on the coordinator hot path.
//!
//! Everything operates on plain slices — the runtime ABI to the AOT
//! artifacts is `Vec<f32>` — and the mutating variants are written to be
//! allocation-free so the server's aggregation loop stays zero-alloc
//! (DESIGN.md §Perf L3).

/// `out += a * x` (axpy).
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &xi) in out.iter_mut().zip(x) {
        *o += a * xi;
    }
}

/// `out = x - y` into a fresh vector.
pub fn sub(x: &[f32], y: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

/// `out += x` element-wise.
#[inline]
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    axpy(out, 1.0, x);
}

/// `out *= a`.
#[inline]
pub fn scale(out: &mut [f32], a: f32) {
    for o in out.iter_mut() {
        *o *= a;
    }
}

/// Euclidean norm.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Squared Euclidean norm.
pub fn l2_norm_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
}

/// Max |x_i|.
pub fn linf_norm(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |acc, &v| acc.max(v.abs()))
}

/// `||x - y||_2`.
pub fn l2_dist(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Weighted average of rows into `out`: `out = Σ w_i x_i / Σ w_i`.
///
/// Single pass per row, accumulating in-place (the server reduce).
pub fn weighted_mean_into(out: &mut [f32], rows: &[(&[f32], f64)]) {
    out.fill(0.0);
    let total: f64 = rows.iter().map(|(_, w)| *w).sum();
    if total == 0.0 {
        return;
    }
    for (row, w) in rows {
        let coef = (*w / total) as f32;
        axpy(out, coef, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_accumulates() {
        let mut out = vec![1.0, 2.0];
        axpy(&mut out, 2.0, &[10.0, 20.0]);
        assert_eq!(out, vec![21.0, 42.0]);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(linf_norm(&[-7.0, 3.0]), 7.0);
        assert!((l2_dist(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-12);
        assert!((l2_norm_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_mean_weights() {
        let a = vec![1.0f32, 0.0];
        let b = vec![0.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        weighted_mean_into(&mut out, &[(&a, 3.0), (&b, 1.0)]);
        assert!((out[0] - 0.75).abs() < 1e-6);
        assert!((out[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn weighted_mean_zero_total() {
        let a = vec![1.0f32; 4];
        let mut out = vec![9.0f32; 4];
        weighted_mean_into(&mut out, &[(&a, 0.0)]);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn sub_and_scale() {
        let d = sub(&[5.0, 7.0], &[2.0, 3.0]);
        assert_eq!(d, vec![3.0, 4.0]);
        let mut s = d.clone();
        scale(&mut s, 0.5);
        assert_eq!(s, vec![1.5, 2.0]);
    }
}
