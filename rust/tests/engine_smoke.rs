//! Integration: the PJRT engine executes every AOT program of `mlp_tiny`.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use fedadam_ssm::runtime::{Engine, Manifest};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) if m.models.contains_key("mlp_tiny") => Some(m),
        _ => {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }
}

#[test]
fn all_programs_roundtrip() {
    let Some(m) = manifest() else { return };
    let engine = Engine::load(&m, "mlp_tiny").unwrap();
    let h = engine.handle();
    let meta = h.meta().clone();
    let d = meta.dim;
    let row: usize = meta.row();

    // init: deterministic by seed, different across seeds.
    let w0 = h.init(0).unwrap();
    assert_eq!(w0.len(), d);
    assert_eq!(w0, h.init(0).unwrap());
    assert_ne!(w0, h.init(1).unwrap());
    let norm: f64 = w0.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(norm > 0.1, "init should be non-degenerate, norm={norm}");

    // Deterministic synthetic batch.
    let b = meta.batch;
    let x: Vec<f32> = (0..b * row).map(|i| ((i % 17) as f32) / 17.0 - 0.5).collect();
    let y: Vec<i32> = (0..b).map(|i| (i % meta.num_classes) as i32).collect();

    // train: loss finite and decreasing over a few steps on a fixed batch.
    let zeros = vec![0.0f32; d];
    let (mut w, mut mm, mut vv, first_loss) = h
        .train_step(w0.clone(), zeros.clone(), zeros.clone(), x.clone(), y.clone(), 0.01)
        .unwrap();
    assert!(first_loss.is_finite());
    let mut last = first_loss;
    for _ in 0..10 {
        let (w2, m2, v2, loss) = h
            .train_step(w, mm, vv, x.clone(), y.clone(), 0.01)
            .unwrap();
        w = w2;
        mm = m2;
        vv = v2;
        last = loss;
    }
    assert!(
        last < first_loss,
        "loss should fall on a fixed batch: {first_loss} -> {last}"
    );

    // epoch: one dispatch over nb batches matches nb sequential train calls.
    let nb = meta.epoch_batches;
    let xs: Vec<f32> = (0..nb).flat_map(|_| x.clone()).collect();
    let ys: Vec<i32> = (0..nb).flat_map(|_| y.clone()).collect();
    let (we, me, ve, _) = h
        .epoch_step(w0.clone(), zeros.clone(), zeros.clone(), xs, ys, 0.01)
        .unwrap();
    let (mut ws, mut ms, mut vs) = (w0.clone(), zeros.clone(), zeros.clone());
    for _ in 0..nb {
        let (a, bb, c, _) = h
            .train_step(ws, ms, vs, x.clone(), y.clone(), 0.01)
            .unwrap();
        ws = a;
        ms = bb;
        vs = c;
    }
    let max_diff = we
        .iter()
        .zip(&ws)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "epoch != train^nb, max diff {max_diff}");
    assert_eq!(me.len(), ms.len());
    assert_eq!(ve.len(), vs.len());

    // eval: weights zero out padding.
    let e = meta.eval_batch;
    let ex: Vec<f32> = (0..e * row).map(|i| ((i % 13) as f32) / 13.0).collect();
    let ey: Vec<i32> = (0..e).map(|i| (i % meta.num_classes) as i32).collect();
    let mut wt = vec![1.0f32; e];
    for slot in wt.iter_mut().skip(e / 2) {
        *slot = 0.0;
    }
    let (loss_sum, correct, weight) = h.eval_batch(&w, ex, ey, wt).unwrap();
    assert!((weight - (e / 2) as f64).abs() < 1e-6);
    assert!(loss_sum.is_finite());
    assert!(correct <= weight + 1e-6);

    // sgd + grads agree: w - eta*g == sgd(w).
    let (g, gloss) = h.grads(&w0, x.clone(), y.clone()).unwrap();
    let (wsgd, sloss) = h
        .sgd_step(w0.clone(), x.clone(), y.clone(), 0.5)
        .unwrap();
    assert!((gloss - sloss).abs() < 1e-5);
    let max_diff = wsgd
        .iter()
        .zip(w0.iter().zip(&g))
        .map(|(ws, (w0i, gi))| (ws - (w0i - 0.5 * gi)).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "sgd != w - eta*g, diff {max_diff}");

    // sparsify: agrees with the rust top-k on tie-free input.
    let dw: Vec<f32> = (0..d).map(|i| ((i as f32) + 1.0) * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let dm: Vec<f32> = (0..d).map(|i| i as f32 * 0.5).collect();
    let dv: Vec<f32> = (0..d).map(|i| i as f32 * 0.25).collect();
    let k = d / 10;
    let (sw, sm, sv) = h
        .sparsify(dw.clone(), dm.clone(), dv.clone(), k as i32)
        .unwrap();
    let mask = fedadam_ssm::sparse::topk::top_k_mask(&dw, k);
    for i in 0..d {
        if mask[i] {
            assert_eq!(sw[i], dw[i]);
            assert_eq!(sm[i], dm[i]);
            assert_eq!(sv[i], dv[i]);
        } else {
            assert_eq!(sw[i], 0.0, "lane {i}");
            assert_eq!(sm[i], 0.0);
            assert_eq!(sv[i], 0.0);
        }
    }

    // Engine handle is Send: exercise from a second thread.
    let h2 = h.clone();
    std::thread::spawn(move || {
        let w = h2.init(3).unwrap();
        assert_eq!(w.len(), d);
    })
    .join()
    .unwrap();
}
