//! Fairness-Top baseline [40]: a shared mask chosen from the *union* of the
//! three update vectors.
//!
//! Han et al.'s "fairness" sparsifier selects coordinates by comparing all
//! candidate vectors on a common scale.  (ΔW, ΔM, ΔV) live on wildly
//! different magnitudes (Fig. 1: ΔW ≫ ΔM ≫ ΔV), so the union is taken
//! after per-vector L∞ normalization; the mask keeps the top-k of
//! `max(|ΔW|/‖ΔW‖∞, |ΔM|/‖ΔM‖∞, |ΔV|/‖ΔV‖∞)`.  Same wire cost as
//! FedAdam-SSM; the paper prices its selection at `O(9dk)`.

use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::sparse::codec::cost;
use crate::sparse::{top_k_indices, SparseVec};
use crate::tensor::linf_norm;

pub struct FairnessTop {
    dim: usize,
    k: usize,
    /// Scratch for the union score (no per-round allocation).
    score: Vec<f32>,
}

impl FairnessTop {
    pub fn new(dim: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= dim);
        FairnessTop {
            dim,
            k,
            score: vec![0.0; dim],
        }
    }
}

impl Algorithm for FairnessTop {
    fn name(&self) -> &'static str {
        "fairness-top"
    }

    fn compress(&mut self, _round: usize, _device: usize, delta: LocalDelta) -> Upload {
        let nw = linf_norm(&delta.dw).max(1e-30);
        let nm = linf_norm(&delta.dm).max(1e-30);
        let nv = linf_norm(&delta.dv).max(1e-30);
        for i in 0..self.dim {
            let a = delta.dw[i].abs() / nw;
            let b = delta.dm[i].abs() / nm;
            let c = delta.dv[i].abs() / nv;
            self.score[i] = a.max(b).max(c);
        }
        let idx = top_k_indices(&self.score, self.k);
        Upload {
            dw: Recon::Sparse(SparseVec::gather(&delta.dw, &idx)),
            dm: Some(Recon::Sparse(SparseVec::gather(&delta.dm, &idx))),
            dv: Some(Recon::Sparse(SparseVec::gather(&delta.dv, &idx))),
            weight: delta.weight,
            bits: cost::fedadam_ssm(self.dim, self.k),
        }
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        // Union support carried through `Aggregate` (see ssm.rs: a recount
        // of non-zeros undercounts on exact-zero cancellation).
        cost::fedadam_ssm(self.dim, agg.dw_support)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_mask_mixes_sources() {
        // dw dominates lane 0, dm lane 1, dv lane 2 (after normalization
        // each wins its own lane with score 1.0).
        let mut a = FairnessTop::new(6, 3);
        let delta = LocalDelta {
            dw: vec![100.0, 1.0, 0.0, 50.0, 0.0, 0.0],
            dm: vec![0.0, 2.0, 0.0, 0.0, 1.0, 0.0],
            dv: vec![0.0, 0.0, 0.002, 0.0, 0.0, 0.001],
            weight: 1.0,
        };
        let up = a.compress(0, 0, delta);
        match &up.dw {
            Recon::Sparse(sv) => assert_eq!(sv.indices, vec![0, 1, 2]),
            _ => panic!(),
        }
    }

    #[test]
    fn same_cost_as_ssm() {
        let mut a = FairnessTop::new(1000, 50);
        let delta = LocalDelta {
            dw: vec![1.0; 1000],
            dm: vec![1.0; 1000],
            dv: vec![1.0; 1000],
            weight: 1.0,
        };
        assert_eq!(a.compress(0, 0, delta).bits, cost::fedadam_ssm(1000, 50));
    }
}
