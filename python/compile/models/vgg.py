"""VGG-11 for CIFAR-10-shaped inputs (paper §VII-A).

Paper description: "eight 3x3 convolutional layers, three fully connected
layers, and a final softmax output layer" — the standard VGG-11 'A'
configuration adapted to 32x32 inputs (five max-pools reduce the spatial
extent to 1x1, classifier is 512-512-10).

``scale`` divides every channel width (``scale=8`` -> ``vgg_mini``), keeping
the architecture — depth, pooling schedule, classifier shape — identical to
the full model.
"""

from __future__ import annotations

import jax

from compile.models.common import Model, ParamSpec, conv2d, dense, max_pool

# VGG-11 'A' config: channels, 'M' = 2x2 max pool.
_CFG = (64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M")


def make_vgg(scale=1, name="vgg11", input_shape=(32, 32, 3), classes=10):
    """Build VGG-11 with channel widths divided by ``scale``."""
    specs = []
    cin = input_shape[2]
    conv_layers = []  # (spec-index, pool-after?)
    idx = 0
    for item in _CFG:
        if item == "M":
            if conv_layers:
                conv_layers[-1] = (conv_layers[-1][0], conv_layers[-1][1] + 1)
            continue
        cout = max(4, item // scale)
        specs.append(ParamSpec(f"conv{idx}/kernel", (3, 3, cin, cout), "he"))
        specs.append(ParamSpec(f"conv{idx}/bias", (cout,), "zeros"))
        conv_layers.append((idx, 0))
        cin = cout
        idx += 1
    # After 5 pools: 32 -> 1; feature dim = last conv width.
    feat = cin
    fc = max(8, 512 // scale)
    specs.append(ParamSpec("fc1/kernel", (feat, fc), "he"))
    specs.append(ParamSpec("fc1/bias", (fc,), "zeros"))
    specs.append(ParamSpec("fc2/kernel", (fc, fc), "he"))
    specs.append(ParamSpec("fc2/bias", (fc,), "zeros"))
    specs.append(ParamSpec("fc3/kernel", (fc, classes), "he"))
    specs.append(ParamSpec("fc3/bias", (classes,), "zeros"))
    specs = tuple(specs)
    pools_after = tuple(p for _, p in conv_layers)

    def apply(flat, x):
        model = _self[0]
        params = model.unflatten(flat)
        y = x
        for li, pools in enumerate(pools_after):
            k, b = params[2 * li], params[2 * li + 1]
            y = jax.nn.relu(conv2d(y, k, b))
            for _ in range(pools):
                y = max_pool(y)
        y = y.reshape(y.shape[0], -1)
        off = 2 * len(pools_after)
        y = jax.nn.relu(dense(y, params[off], params[off + 1]))
        y = jax.nn.relu(dense(y, params[off + 2], params[off + 3]))
        return dense(y, params[off + 4], params[off + 5])

    model = Model(name=name, specs=specs, apply=apply, input_shape=input_shape, num_classes=classes)
    _self = [model]
    return model
