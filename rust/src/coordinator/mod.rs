//! The round coordinator: Algorithm 2's outer loop.
//!
//! Owns the engine, data, devices, algorithm and ledger; each round it
//! (1) hands devices the global state per the algorithm's momentum policy,
//! (2) runs `L` local epochs per device through the AOT programs,
//! (3) compresses and "uploads" each delta (bit-accurately priced),
//! (4) FedAvg-aggregates, post-processes, applies, and
//! (5) evaluates + logs.

pub mod device;
pub mod server;

use std::time::Instant;

use anyhow::{Context, Result};

use crate::algorithms::{self, Algorithm, LocalDelta, MomentumPolicy, Upload};
use crate::config::{ExperimentConfig, SparsifyBackend};
use crate::data::{partition, synthetic, Dataset, Partition, Shard};
use crate::metrics::comm::CommLedger;
use crate::metrics::{ExperimentLog, RoundRecord};
use crate::runtime::{Engine, EngineHandle, Manifest};
use crate::tensor;

pub use device::{Device, LocalRunConfig};
pub use server::{aggregate, GlobalState};

/// A fully-wired experiment ready to run.
pub struct Coordinator {
    pub cfg: ExperimentConfig,
    engine: Engine,
    devices: Vec<Device>,
    test_set: Dataset,
    algorithm: Box<dyn Algorithm>,
    global: GlobalState,
    /// Per-device `(m, v)` for `MomentumPolicy::DeviceLocal` algorithms.
    device_moments: Vec<(Vec<f32>, Vec<f32>)>,
    ledger: CommLedger,
    log: ExperimentLog,
    round: usize,
    /// Round-robin participation RNG (partial participation).
    sampler: crate::rng::Rng,
}

impl Coordinator {
    /// Build everything: engine, data, shards, algorithm, initial model.
    pub fn new(cfg: ExperimentConfig, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        cfg.validate()?;
        let manifest = Manifest::load(artifacts_dir)?;
        let engine = Engine::load(&manifest, &cfg.model)
            .with_context(|| format!("loading model {:?}", cfg.model))?;
        let meta = engine.meta().clone();

        // Synthetic stand-in corpus shaped for this model.
        let spec = synthetic::SyntheticSpec::for_input_shape(
            &meta.input_shape,
            cfg.train_samples,
            cfg.test_samples,
        );
        let task = synthetic::generate(&spec, cfg.seed);
        let how = Partition::parse(cfg.iid, cfg.dirichlet_theta);
        let shards = partition(&task.train, cfg.devices, how, cfg.seed);

        let handle = engine.handle();
        let devices: Vec<Device> = shards
            .into_iter()
            .enumerate()
            .map(|(i, data)| Device::new(i, Shard { data }, handle.clone()))
            .collect();

        let algorithm = algorithms::build(&cfg, meta.dim)?;
        let w0 = handle.init(cfg.seed as i32)?;
        let global = GlobalState::new(w0);
        let device_moments = (0..cfg.devices)
            .map(|_| (vec![0.0f32; meta.dim], vec![0.0f32; meta.dim]))
            .collect();

        let cfg_seed = cfg.seed;
        let log = ExperimentLog {
            name: cfg.name.clone(),
            algorithm: cfg.algorithm.clone(),
            model: cfg.model.clone(),
            iid: cfg.iid,
            rounds: Vec::new(),
        };
        Ok(Coordinator {
            cfg,
            engine,
            devices,
            test_set: task.test,
            algorithm,
            global,
            device_moments,
            ledger: CommLedger::default(),
            log,
            round: 0,
            sampler: crate::rng::Rng::new(cfg_seed ^ 0x5a3c_91f7),
        })
    }

    /// Devices participating this round (uniform without replacement when
    /// `participation < 1`; at least one device always runs).
    fn sample_participants(&mut self) -> Vec<usize> {
        let n = self.devices.len();
        let m = ((n as f64 * self.cfg.participation).round() as usize).clamp(1, n);
        if m == n {
            return (0..n).collect();
        }
        let mut idx: Vec<usize> = (0..n).collect();
        self.sampler.shuffle(&mut idx);
        idx.truncate(m);
        idx.sort_unstable();
        idx
    }

    /// Immutable view of the global state.
    pub fn global(&self) -> &GlobalState {
        &self.global
    }

    pub fn handle(&self) -> EngineHandle {
        self.engine.handle()
    }

    /// Run one communication round; returns its record.
    pub fn step_round(&mut self) -> Result<RoundRecord> {
        let t = self.round;
        let start = Instant::now();
        let run_cfg = LocalRunConfig {
            local_epochs: self.cfg.local_epochs,
            max_batches_per_epoch: self.cfg.max_batches_per_epoch,
            lr: self.cfg.lr as f32,
            use_epoch_program: self.cfg.use_epoch_program,
        };
        let mode = self.algorithm.local_mode(t);
        let policy = self.algorithm.momentum_policy(t);
        let dim = self.global.dim();

        let participants = self.sample_participants();
        let mut uploads: Vec<Upload> = Vec::with_capacity(participants.len());
        let mut loss_sum = 0.0f64;
        for di in participants.iter().copied() {
            // 1. Download global state (moments per policy).
            let (m0, v0) = match policy {
                MomentumPolicy::Aggregated => (self.global.m.clone(), self.global.v.clone()),
                MomentumPolicy::DeviceLocal => self.device_moments[di].clone(),
            };
            // 2. Local training.
            let result = self.devices[di].train_round(
                mode,
                self.global.w.clone(),
                m0.clone(),
                v0.clone(),
                &run_cfg,
            )?;
            loss_sum += result.mean_loss;
            // 3. Deltas (Algorithm 2 line 9: vs the downloaded state).
            let delta = LocalDelta {
                dw: tensor::sub(&result.w, &self.global.w),
                dm: tensor::sub(&result.m, &m0),
                dv: tensor::sub(&result.v, &v0),
                weight: self.devices[di].weight(),
            };
            if policy == MomentumPolicy::DeviceLocal {
                self.device_moments[di] = (result.m, result.v);
            }
            // 4. Compress + upload.
            let upload = self.compress_upload(t, di, delta)?;
            self.ledger.up(upload.bits);
            uploads.push(upload);
        }

        // 5. Server aggregate + broadcast.
        let mut agg = aggregate(&uploads, dim);
        self.algorithm.postprocess(&mut agg);
        self.ledger
            .down(self.algorithm.downlink_bits(&agg), participants.len());
        let update_norm = tensor::l2_norm(&agg.dw);
        self.global.apply(&agg);

        // 6. Evaluate.
        let (test_loss, test_acc) = if t % self.cfg.eval_every == 0 || t + 1 == self.cfg.rounds {
            self.evaluate()?
        } else {
            (f64::NAN, f64::NAN)
        };

        let record = RoundRecord {
            round: t,
            train_loss: loss_sum / participants.len() as f64,
            test_loss,
            test_accuracy: test_acc,
            uplink_bits: self.ledger.uplink_bits,
            downlink_bits: self.ledger.downlink_bits,
            wall_secs: start.elapsed().as_secs_f64(),
            update_norm,
        };
        self.log.rounds.push(record.clone());
        self.round += 1;
        Ok(record)
    }

    /// Compress via the configured backend (native quickselect, or the
    /// AOT Pallas sparsifier for the plain SSM algorithm).
    fn compress_upload(&mut self, t: usize, di: usize, delta: LocalDelta) -> Result<Upload> {
        if self.cfg.sparsify_backend == SparsifyBackend::Xla
            && self.cfg.algorithm == "fedadam-ssm"
        {
            // Cross-layer path: run eq. 10-12 + 28 inside XLA, then encode.
            let dim = delta.dw.len();
            let k = self.cfg.k_for(dim);
            let (sw, sm, sv) = self
                .engine
                .handle()
                .sparsify(delta.dw, delta.dm, delta.dv, k as i32)?;
            use crate::algorithms::Recon;
            use crate::sparse::{codec::cost, SparseVec};
            return Ok(Upload {
                dw: Recon::Sparse(SparseVec::from_dense(&sw)),
                dm: Some(Recon::Sparse(SparseVec::from_dense(&sm))),
                dv: Some(Recon::Sparse(SparseVec::from_dense(&sv))),
                weight: delta.weight,
                bits: cost::fedadam_ssm(dim, k),
            });
        }
        Ok(self.algorithm.compress(t, di, delta))
    }

    /// Evaluate the global model on the held-out test set.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        evaluate_model(&self.engine.handle(), &self.global.w, &self.test_set)
    }

    /// Run all configured rounds, returning the full log.
    pub fn run(&mut self) -> Result<ExperimentLog> {
        while self.round < self.cfg.rounds {
            let r = self.step_round()?;
            log::info!(
                "[{}] round {:>3}: loss {:.4} acc {} uplink {:.2} Mbit ({:.1}s)",
                self.cfg.algorithm,
                r.round,
                r.train_loss,
                if r.test_accuracy.is_nan() {
                    "-".to_string()
                } else {
                    format!("{:.3}", r.test_accuracy)
                },
                r.uplink_bits as f64 / 1e6,
                r.wall_secs,
            );
        }
        Ok(self.log.clone())
    }

    /// The log accumulated so far.
    pub fn log(&self) -> &ExperimentLog {
        &self.log
    }
}

/// Evaluate `w` over `data` in fixed-size weighted eval batches.
pub fn evaluate_model(
    engine: &EngineHandle,
    w: &[f32],
    data: &Dataset,
) -> Result<(f64, f64)> {
    let meta = engine.meta().clone();
    let e = meta.eval_batch;
    let row = meta.row();
    let mut loss_sum = 0.0;
    let mut correct = 0.0;
    let mut weight = 0.0;
    let mut start = 0;
    while start < data.len() {
        let n = (data.len() - start).min(e);
        let mut x = Vec::with_capacity(e * row);
        let mut y = Vec::with_capacity(e);
        let mut wt = Vec::with_capacity(e);
        for i in 0..e {
            if i < n {
                x.extend_from_slice(data.image(start + i));
                y.push(data.labels[start + i]);
                wt.push(1.0);
            } else {
                x.extend(std::iter::repeat(0.0).take(row));
                y.push(0);
                wt.push(0.0);
            }
        }
        let (ls, c, wsum) = engine.eval_batch(w, x, y, wt)?;
        loss_sum += ls;
        correct += c;
        weight += wsum;
        start += n;
    }
    if weight == 0.0 {
        return Ok((f64::NAN, f64::NAN));
    }
    Ok((loss_sum / weight, correct / weight))
}
