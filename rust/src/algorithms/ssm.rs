//! FedAdam-SSM — the paper's contribution (Algorithm 2) — plus the
//! SSM_M / SSM_V ablation variants of §VII-A.
//!
//! One **shared sparse mask** sparsifies all three update vectors
//! (eq. 10-12).  The optimal mask (§V-B, eq. 28) is the top-k mask of
//! `|ΔW|`: Theorem 1 bounds the FedAdam-SSM ↔ centralized-Adam divergence
//! by `Γ‖(1-mask)∘ΔW‖ + Λ‖(1-mask)∘ΔM‖ + Θ‖(1-mask)∘ΔV‖ + Φ`, and
//! Proposition 1 shows `Γ > Θ > Λ` under the (mild) condition
//! `β₂ < 1 − 1/(1+2Gρ√d)`; combined with `ΔW ≫ ΔM, ΔV` (Fig. 1) the ΔW
//! term dominates, so masking by `|ΔW|` minimizes the bound.  SSM_M / SSM_V
//! pick the mask from `|ΔM|` / `|ΔV|` instead — same wire cost, provably
//! worse bound, and measurably worse accuracy (Fig. 2 / Table I).
//!
//! Uplink: one mask + three k-value lists = `min{3kq + d, k(3q + log₂ d)}`.

use anyhow::Result;

use super::wire::{WireBody, WireUpload, KIND_SHARED_MASK};
use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::sparse::codec::{cost, pack_positions, BitPacker, Q};
use crate::sparse::{top_k_indices, SparseVec};

/// Which delta supplies the shared mask.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskSource {
    /// `1_{Top_k}(ΔW)` — the optimal SSM (eq. 28).
    W,
    /// `1_{Top_k}(ΔM)` — ablation (FedAdam-SSM_M).
    M,
    /// `1_{Top_k}(ΔV)` — ablation (FedAdam-SSM_V).
    V,
}

pub struct FedAdamSsm {
    dim: usize,
    k: usize,
    source: MaskSource,
}

impl FedAdamSsm {
    pub fn new(dim: usize, k: usize, source: MaskSource) -> Self {
        assert!(k >= 1 && k <= dim);
        FedAdamSsm { dim, k, source }
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

impl Algorithm for FedAdamSsm {
    fn name(&self) -> &'static str {
        match self.source {
            MaskSource::W => "fedadam-ssm",
            MaskSource::M => "fedadam-ssm-m",
            MaskSource::V => "fedadam-ssm-v",
        }
    }

    fn compress(&mut self, _round: usize, _device: usize, delta: LocalDelta) -> Upload {
        let source = match self.source {
            MaskSource::W => &delta.dw,
            MaskSource::M => &delta.dm,
            MaskSource::V => &delta.dv,
        };
        let idx = top_k_indices(source, self.k);
        Upload {
            dw: Recon::Sparse(SparseVec::gather(&delta.dw, &idx)),
            dm: Some(Recon::Sparse(SparseVec::gather(&delta.dm, &idx))),
            dv: Some(Recon::Sparse(SparseVec::gather(&delta.dv, &idx))),
            weight: delta.weight,
            bits: cost::fedadam_ssm(self.dim, self.k),
        }
    }

    fn compress_wire(
        &mut self,
        _round: usize,
        _device: usize,
        delta: LocalDelta,
    ) -> Result<WireUpload> {
        // Fused wire path: write the shared-mask body straight from the
        // dense deltas — the positions word-at-a-time, the kept lanes'
        // f32 bits gathered in place — with no intermediate `SparseVec`s.
        // Byte-identical by construction to the staged
        // `compress → from_upload → SharedMask::encode` path (the f32
        // payload bits pass through verbatim); debug builds assert it.
        let source = match self.source {
            MaskSource::W => &delta.dw,
            MaskSource::M => &delta.dm,
            MaskSource::V => &delta.dv,
        };
        let idx = top_k_indices(source, self.k);
        let bits = cost::fedadam_ssm(self.dim, self.k);
        let mut p = BitPacker::with_capacity(bits as usize);
        pack_positions(&mut p, self.dim, &idx);
        for src in [&delta.dw, &delta.dm, &delta.dv] {
            for &i in &idx {
                p.push(src[i as usize].to_bits() as u64, Q);
            }
        }
        let bytes = p.finish();
        #[cfg(debug_assertions)]
        {
            let gather = |src: &[f32]| -> Vec<f32> {
                idx.iter().map(|&i| src[i as usize]).collect()
            };
            let staged = WireBody::SharedMask {
                dim: self.dim,
                indices: idx.clone(),
                w: gather(&delta.dw),
                m: gather(&delta.dm),
                v: gather(&delta.dv),
            };
            debug_assert_eq!(staged.wire_bits(), bits);
            debug_assert_eq!(
                staged.encode(),
                bytes,
                "fused SSM wire encode is not byte-identical to the staged path"
            );
        }
        Ok(WireUpload {
            body: WireBody::Packed {
                kind: KIND_SHARED_MASK,
                dim: self.dim,
                k: idx.len(),
                levels: 0,
                bytes,
            },
            weight: delta.weight,
            bits,
        })
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        // The aggregated update's support is the union of device masks;
        // broadcast uses the same min{bitmap, index} coding with 3 values
        // per kept coordinate (the union support is shared by all three).
        // The union size is carried through `Aggregate` — recounting
        // non-zeros of the summed vector undercounts whenever device
        // contributions cancel to exact 0.0 or a masked lane holds a
        // true-zero value.
        cost::fedadam_ssm(self.dim, agg.dw_support)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(dim: usize) -> LocalDelta {
        // dw biggest at high indices, dm biggest at low indices.
        LocalDelta {
            dw: (0..dim).map(|i| i as f32).collect(),
            dm: (0..dim).map(|i| (dim - i) as f32).collect(),
            dv: vec![1.0; dim],
            weight: 1.0,
        }
    }

    #[test]
    fn mask_from_w_keeps_top_w_lanes() {
        let mut a = FedAdamSsm::new(10, 3, MaskSource::W);
        let up = a.compress(0, 0, delta(10));
        match &up.dw {
            Recon::Sparse(sv) => assert_eq!(sv.indices, vec![7, 8, 9]),
            _ => panic!("expected sparse"),
        }
        // The SAME mask applies to dm (whose own top-3 would be [0,1,2]).
        match &up.dm {
            Some(Recon::Sparse(sv)) => {
                assert_eq!(sv.indices, vec![7, 8, 9]);
                assert_eq!(sv.values, vec![3.0, 2.0, 1.0]);
            }
            _ => panic!("expected sparse dm"),
        }
    }

    #[test]
    fn mask_from_m_differs() {
        let mut a = FedAdamSsm::new(10, 3, MaskSource::M);
        let up = a.compress(0, 0, delta(10));
        match &up.dw {
            Recon::Sparse(sv) => assert_eq!(sv.indices, vec![0, 1, 2]),
            _ => panic!("expected sparse"),
        }
    }

    #[test]
    fn uplink_cost_is_ssm_formula() {
        let mut a = FedAdamSsm::new(100_000, 5_000, MaskSource::W);
        let d = LocalDelta {
            dw: vec![1.0; 100_000],
            dm: vec![1.0; 100_000],
            dv: vec![1.0; 100_000],
            weight: 1.0,
        };
        let up = a.compress(0, 0, d);
        assert_eq!(up.bits, cost::fedadam_ssm(100_000, 5_000));
        assert!(up.bits < cost::fedadam_top(100_000, 5_000));
    }

    #[test]
    #[should_panic]
    fn zero_k_rejected() {
        FedAdamSsm::new(10, 0, MaskSource::W);
    }

    #[test]
    fn downlink_prices_union_support_despite_cancellation() {
        use crate::coordinator::server::aggregate;

        // Device 0 masks lanes {0, 1}, device 1 masks lanes {1, 2}; their
        // lane-1 contributions cancel exactly under equal weights.  The
        // broadcast still carries the 3-lane union, so downlink must price
        // k = 3 — the naive non-zero recount would see only 2.
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 8,
                indices: i,
                values: v,
            })
        };
        let uploads = vec![
            Upload {
                dw: sv(vec![0, 1], vec![1.0, 1.0]),
                dm: Some(sv(vec![0, 1], vec![0.1, 0.1])),
                dv: Some(sv(vec![0, 1], vec![0.2, 0.2])),
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![1, 2], vec![-1.0, 1.0]),
                dm: Some(sv(vec![1, 2], vec![0.1, 0.1])),
                dv: Some(sv(vec![1, 2], vec![0.2, 0.2])),
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 8);
        assert_eq!(agg.dw[1], 0.0, "lane 1 must cancel exactly");
        let a = FedAdamSsm::new(8, 2, MaskSource::W);
        assert_eq!(a.downlink_bits(&agg), cost::fedadam_ssm(8, 3));
    }
}
