//! Sparse s-level uniform quantization — the SSM × quantizer composition.
//!
//! The last unexplored cell of the paper's accuracy-vs-bits frontier:
//! FedAdam-SSM's shared sparse mask picks `k` lanes, and instead of
//! shipping three f32 value lists (`3kq` bits) each list is s-level
//! uniform-quantized against its own max-magnitude scale — the same
//! deterministic rounding as [`super::uniform`], restricted to the kept
//! lanes.  Wire format per vector: `k·ceil(log₂ s)` packed bits + one f32
//! scale; the mask travels once, `min{bitmap, index-list}`-coded exactly
//! like the f32 SSM (`sparse::codec`).
//!
//! Reconstruction is an **exact dequantized [`SparseVec`]**: every masked
//! lane keeps its index even when its (de)quantized value is `0.0` — the
//! support on the wire is the priced support (see
//! `SparseVec::from_dense`'s warning about exact-zero kept lanes).

use crate::sparse::codec::{
    cost, decode_positions, encode_positions, index_bits, mask_bits, pack_positions,
    try_decode_positions, BitPacker, DecodeError, MaskEncoding, Q,
};
use crate::sparse::SparseVec;

/// One vector's kept-lane values, s-level quantized and bit-packed.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseUniformPacket {
    /// Kept-lane count (the mask's `k`; the mask itself lives outside).
    pub k: usize,
    /// Shared max-magnitude scale: `max |values|` over the kept lanes.
    pub scale: f32,
    /// Bin count `s - 1` (mirrors [`super::UniformPacket`]).
    pub levels: u32,
    /// LSB-first packed codes, `k · ceil(log₂ s)` bits.
    pub codes: Vec<u8>,
}

impl SparseUniformPacket {
    /// Representable levels `s`.
    pub fn s_levels(&self) -> u32 {
        self.levels + 1
    }

    /// Packed value-payload length in bits: `k · ceil(log₂ s)` (the scale
    /// is priced separately).
    pub fn payload_bits(&self) -> u64 {
        self.k as u64 * index_bits(self.s_levels() as usize)
    }
}

/// Quantize the kept-lane `values` to `s_levels` representable values
/// (`s_levels >= 2`), packing `ceil(log₂ s)` bits per lane.
///
/// Delegates to the dense [`super::uniform_compress`] — the sparse
/// quantizer IS the dense one restricted to the kept lanes, so the grid
/// math (scale fold, safe divisor, rounding) lives in exactly one place.
pub fn sparse_uniform_compress(values: &[f32], s_levels: u32) -> SparseUniformPacket {
    let p = super::uniform_compress(values, s_levels);
    SparseUniformPacket {
        k: p.dim,
        scale: p.scale,
        levels: p.levels,
        codes: p.codes,
    }
}

/// Dequantize back to `k` values on the s-level grid (exactly `0.0`
/// everywhere when the scale is zero).
///
/// Trusted in-process path; transport-facing callers must use
/// [`try_sparse_uniform_decompress`].
pub fn sparse_uniform_decompress(p: &SparseUniformPacket) -> Vec<f32> {
    super::uniform::dequantize_codes(&p.codes, p.k, p.scale, p.levels)
}

/// Fallible [`sparse_uniform_decompress`] for untrusted bytes: same
/// structural checks as [`super::uniform::try_uniform_decompress`]
/// (exact code length, on-grid codes, zero padding, finite scale).
pub fn try_sparse_uniform_decompress(p: &SparseUniformPacket) -> Result<Vec<f32>, DecodeError> {
    super::uniform::try_dequantize_codes(&p.codes, p.k, p.scale, p.levels)
}

/// Exact dequantized reconstruction at the mask's `indices`: the support
/// is the index list verbatim — a lane dequantizing to `0.0` stays.
pub fn reconstruct(dim: usize, indices: &[u32], p: &SparseUniformPacket) -> SparseVec {
    debug_assert_eq!(indices.len(), p.k);
    SparseVec {
        dim,
        indices: indices.to_vec(),
        values: sparse_uniform_decompress(p),
    }
}

/// One device's full quantized-SSM uplink message: one coded mask + three
/// packed value lists + three f32 scales.
#[derive(Clone, Debug)]
pub struct SsmQUplink {
    /// Model dimension `d` (the mask's index space).
    pub dim: usize,
    /// Kept-lane count `k` (the shared mask's support size).
    pub k: usize,
    /// Which position coding `min{bitmap, index-list}` picked.
    pub encoding: MaskEncoding,
    /// Packed mask bits (shared by all three vectors).
    pub positions: Vec<u8>,
    /// Quantized kept-lane values of `ΔW`.
    pub w: SparseUniformPacket,
    /// Quantized kept-lane values of `ΔM`.
    pub m: SparseUniformPacket,
    /// Quantized kept-lane values of `ΔV`.
    pub v: SparseUniformPacket,
}

impl SsmQUplink {
    /// Total size on the wire in bits — equals
    /// [`cost::fedadam_ssm_q`]`(dim, k, s)` by construction (the value
    /// payload and scales are common to both mask codings, so minimizing
    /// the mask bits minimizes the total).
    pub fn wire_bits(&self) -> u64 {
        let pos_bits = match self.encoding {
            MaskEncoding::Bitmap => self.dim as u64,
            MaskEncoding::IndexList => self.k as u64 * index_bits(self.dim),
        };
        pos_bits + self.w.payload_bits() + self.m.payload_bits() + self.v.payload_bits() + 3 * Q
    }
}

/// Encode the shared mask + the three kept-lane value lists.
///
/// The encoded message prices exactly to the ledger formula, and the
/// decode side reconstructs the support verbatim:
///
/// ```
/// use fedadam_ssm::quant::sparse_uniform::{ssm_q_decode, ssm_q_encode};
/// use fedadam_ssm::sparse::codec::cost;
///
/// let idx = [2u32, 5, 9];
/// let msg = ssm_q_encode(
///     12, &idx,
///     &[0.5, -1.0, 0.0],    // ΔW kept values (one exactly 0.0)
///     &[0.1, 0.2, 0.3],     // ΔM
///     &[0.01, 0.02, 0.03],  // ΔV
///     16,
/// );
/// assert_eq!(msg.wire_bits(), cost::fedadam_ssm_q(12, 3, 16));
/// let (w, _m, _v) = ssm_q_decode(&msg);
/// assert_eq!(w.indices, idx); // exact support — zero-valued lanes stay
/// ```
pub fn ssm_q_encode(
    dim: usize,
    indices: &[u32],
    w_vals: &[f32],
    m_vals: &[f32],
    v_vals: &[f32],
    s_levels: u32,
) -> SsmQUplink {
    debug_assert!(indices.len() == w_vals.len());
    debug_assert!(indices.len() == m_vals.len() && indices.len() == v_vals.len());
    let (encoding, positions) = encode_positions(dim, indices);
    let msg = SsmQUplink {
        dim,
        k: indices.len(),
        encoding,
        positions,
        w: sparse_uniform_compress(w_vals, s_levels),
        m: sparse_uniform_compress(m_vals, s_levels),
        v: sparse_uniform_compress(v_vals, s_levels),
    };
    debug_assert_eq!(
        msg.wire_bits(),
        cost::fedadam_ssm_q(dim, msg.k, s_levels as usize),
        "encoded quantized-SSM message disagrees with the priced ledger formula"
    );
    msg
}

/// Output of the fused single-pass encoder [`ssm_q_encode_fused`]: the
/// canonical contiguous wire-body bytes plus the exact dequantized
/// kept-lane values (the reconstructions the in-process aggregation path
/// consumes — [`ssm_q_decode`] of the equivalent staged message yields
/// bitwise the same values).
#[derive(Clone, Debug)]
pub struct SsmQFused {
    /// The contiguous LSB-first wire body — byte-for-byte what
    /// `WireBody::SsmQ(ssm_q_encode(..)).encode()` produces, exactly
    /// `ceil(bits / 8)` bytes.
    pub bytes: Vec<u8>,
    /// Priced size: [`cost::fedadam_ssm_q`]`(dim, k, s)`.
    pub bits: u64,
    /// Dequantized kept-lane values of `ΔW` (index order of the mask).
    pub w: Vec<f32>,
    /// Dequantized kept-lane values of `ΔM`.
    pub m: Vec<f32>,
    /// Dequantized kept-lane values of `ΔV`.
    pub v: Vec<f32>,
}

/// Quantize one vector's kept lanes straight into the open bitstream and
/// return their dequantized values — the fused form of
/// `gather → uniform_compress → repack → dequantize_codes`, with the grid
/// math kept expression-for-expression identical so the codes and the
/// reconstructions are bitwise those of the staged path.
fn quantize_lanes_into(
    p: &mut BitPacker,
    indices: &[u32],
    src: &[f32],
    levels: u32,
    code_bits: u64,
) -> Vec<f32> {
    // Same fold as `uniform_compress` over the gathered (index-ascending)
    // values: f32::max is order-sensitive only around NaN, so matching the
    // walk order keeps the scale bit-identical.
    let scale = indices
        .iter()
        .fold(0.0f32, |a, &i| a.max(src[i as usize].abs()));
    let safe = scale.max(1e-30);
    let mut out = Vec::with_capacity(indices.len());
    for &i in indices {
        let t = (src[i as usize] / safe).clamp(-1.0, 1.0);
        let q = ((t + 1.0) * 0.5 * levels as f32).round() as u64;
        p.push(q, code_bits);
        out.push(if scale == 0.0 {
            0.0
        } else {
            (q as f32 / levels as f32 * 2.0 - 1.0) * scale
        });
    }
    p.push(scale.to_bits() as u64, Q);
    out
}

/// Fused single-pass sparsify→quantize→pack encoder for the quantized-SSM
/// uplink: walks the `k` selected lanes of the **dense** `(ΔW, ΔM, ΔV)`
/// directly, quantizes each kept lane, and writes the packed contiguous
/// wire body in place — no intermediate gathered `Vec`, per-section code
/// buffer, or [`SsmQUplink`] struct.  Byte-identical by construction to
/// the staged `gather → ssm_q_encode → WireBody::SsmQ::encode` path
/// (debug-asserted there and property-tested in `tests/proptests.rs`),
/// and the returned dequantized values are bitwise the staged
/// [`ssm_q_decode`] reconstructions.
pub fn ssm_q_encode_fused(
    dim: usize,
    indices: &[u32],
    dw: &[f32],
    dm: &[f32],
    dv: &[f32],
    s_levels: u32,
) -> SsmQFused {
    assert!(s_levels >= 2, "need at least 2 levels");
    let levels = s_levels - 1;
    let code_bits = index_bits(s_levels as usize);
    let bits = cost::fedadam_ssm_q(dim, indices.len(), s_levels as usize);
    let mut p = BitPacker::with_capacity(bits as usize);
    pack_positions(&mut p, dim, indices);
    let w = quantize_lanes_into(&mut p, indices, dw, levels, code_bits);
    let m = quantize_lanes_into(&mut p, indices, dm, levels, code_bits);
    let v = quantize_lanes_into(&mut p, indices, dv, levels, code_bits);
    let bytes = p.finish();
    debug_assert_eq!(bytes.len() as u64, bits.div_ceil(8));
    SsmQFused { bytes, bits, w, m, v }
}

/// Decode to the three exact dequantized [`SparseVec`]s the server sees.
///
/// Trusted in-process path (the message came from [`ssm_q_encode`] in
/// this address space); transport-facing callers must use
/// [`try_ssm_q_decode`].
pub fn ssm_q_decode(msg: &SsmQUplink) -> (SparseVec, SparseVec, SparseVec) {
    let indices = decode_positions(msg.encoding, msg.dim, msg.k, &msg.positions);
    let w = reconstruct(msg.dim, &indices, &msg.w);
    let m = reconstruct(msg.dim, &indices, &msg.m);
    let v = reconstruct(msg.dim, &indices, &msg.v);
    (w, m, v)
}

/// Fallible [`ssm_q_decode`] for untrusted bytes: never panics, and only
/// accepts the canonical output of [`ssm_q_encode`] — the
/// `min{}`-cheaper mask coding for `(dim, k)`, exactly `k`
/// strictly-increasing positions `< dim`, and three value packets whose
/// `k`, code length, code range, padding, and scale all validate.
pub fn try_ssm_q_decode(msg: &SsmQUplink) -> Result<(SparseVec, SparseVec, SparseVec), DecodeError> {
    let (_, canonical) = mask_bits(msg.dim, msg.k);
    if msg.encoding != canonical {
        return Err(DecodeError::BadValue("non-canonical position encoding"));
    }
    let indices = try_decode_positions(msg.encoding, msg.dim, msg.k, &msg.positions)?;
    let mut vecs = Vec::with_capacity(3);
    for packet in [&msg.w, &msg.m, &msg.v] {
        if packet.k != msg.k {
            return Err(DecodeError::CountMismatch {
                expected: msg.k,
                got: packet.k,
            });
        }
        vecs.push(SparseVec {
            dim: msg.dim,
            indices: indices.clone(),
            values: try_sparse_uniform_decompress(packet)?,
        });
    }
    let v = vecs.pop().expect("three packets");
    let m = vecs.pop().expect("three packets");
    let w = vecs.pop().expect("three packets");
    Ok((w, m, v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::top_k_indices;

    #[test]
    fn roundtrip_error_bounded_by_half_bin() {
        let mut rng = Rng::new(21);
        let x: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        for &s in &[2u32, 3, 4, 5, 16, 256] {
            let p = sparse_uniform_compress(&x, s);
            let y = sparse_uniform_decompress(&p);
            let bin = 2.0 * p.scale / (s - 1) as f32;
            for (xi, yi) in x.iter().zip(&y) {
                assert!((xi - yi).abs() <= bin / 2.0 + 1e-5, "s={s} x={xi} y={yi}");
            }
        }
    }

    #[test]
    fn matches_dense_uniform_quantizer_on_same_values() {
        // The sparse quantizer is the dense one restricted to kept lanes:
        // identical grid, identical codes, identical dequantization.
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
        for &s in &[2u32, 5, 16] {
            let dense = crate::quant::uniform_compress(&x, s);
            let sparse = sparse_uniform_compress(&x, s);
            assert_eq!(sparse.scale, dense.scale, "s={s}");
            assert_eq!(sparse.codes, dense.codes, "s={s}");
            assert_eq!(
                sparse_uniform_decompress(&sparse),
                crate::quant::uniform_decompress(&dense),
                "s={s}"
            );
        }
    }

    #[test]
    fn all_zero_kept_lanes_reconstruct_exactly() {
        let p = sparse_uniform_compress(&[0.0; 7], 16);
        assert_eq!(p.scale, 0.0);
        let sv = reconstruct(100, &[3, 10, 20, 30, 40, 50, 99], &p);
        assert_eq!(sv.nnz(), 7, "zero-valued kept lanes must keep their indices");
        assert_eq!(sv.values, vec![0.0; 7]);
    }

    #[test]
    fn message_roundtrip_and_wire_bits() {
        let mut rng = Rng::new(23);
        let d = 4096;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        for &k in &[1usize, 64, 500, d] {
            let idx = top_k_indices(&x, k);
            let gather = |src: &[f32]| -> Vec<f32> {
                idx.iter().map(|&i| src[i as usize]).collect()
            };
            let (wv, mv, vv) = (gather(&x), gather(&x), gather(&x));
            for &s in &[2u32, 3, 16] {
                let msg = ssm_q_encode(d, &idx, &wv, &mv, &vv, s);
                assert_eq!(msg.wire_bits(), cost::fedadam_ssm_q(d, k, s as usize));
                let (sw, sm, sv) = ssm_q_decode(&msg);
                assert_eq!(sw.indices, idx, "k={k} s={s}: mask lost on the wire");
                assert_eq!(sm.indices, idx);
                assert_eq!(sv.indices, idx);
                assert_eq!(sw.values, sparse_uniform_decompress(&msg.w));
                assert_eq!(sw.nnz(), k);
            }
        }
    }

    #[test]
    fn try_decode_accepts_canonical_and_rejects_malformed() {
        let d = 4096;
        let idx = [3u32, 77, 512, 4095];
        let vals = [0.5f32, -1.0, 0.25, 2.0];
        let msg = ssm_q_encode(d, &idx, &vals, &vals, &vals, 16);
        let (w, m, v) = try_ssm_q_decode(&msg).unwrap();
        let (tw, tm, tv) = ssm_q_decode(&msg);
        assert_eq!((w, m, v), (tw, tm, tv));

        let mut torn = msg.clone();
        torn.positions.truncate(torn.positions.len() - 1);
        assert!(try_ssm_q_decode(&torn).is_err());

        let mut short_codes = msg.clone();
        short_codes.m.codes.truncate(1);
        assert!(try_ssm_q_decode(&short_codes).is_err());

        let mut wrong_k = msg.clone();
        wrong_k.v.k = 3;
        assert!(matches!(
            try_ssm_q_decode(&wrong_k),
            Err(DecodeError::CountMismatch { expected: 4, got: 3 })
        ));

        let mut wrong_enc = msg;
        wrong_enc.encoding = MaskEncoding::Bitmap;
        assert!(try_ssm_q_decode(&wrong_enc).is_err());
    }

    #[test]
    fn fused_encode_matches_staged_bytes_and_recons() {
        use crate::algorithms::wire::WireBody;
        let mut rng = Rng::new(31);
        for &d in &[1usize, 64, 170, 1000, 4096] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
            let z: Vec<f32> = (0..d).map(|_| (rng.normal() as f32).abs() * 0.01).collect();
            for &k in &[1usize, d / 3 + 1, d] {
                let idx = top_k_indices(&x, k);
                for &s in &[2u32, 3, 16, 256] {
                    let fused = super::ssm_q_encode_fused(d, &idx, &x, &y, &z, s);
                    let gather =
                        |src: &[f32]| idx.iter().map(|&i| src[i as usize]).collect::<Vec<f32>>();
                    let staged =
                        ssm_q_encode(d, &idx, &gather(&x), &gather(&y), &gather(&z), s);
                    let (sw, sm, sv) = ssm_q_decode(&staged);
                    assert_eq!(fused.bits, staged.wire_bits(), "d={d} k={k} s={s}");
                    assert_eq!(
                        fused.bytes,
                        WireBody::SsmQ(staged).encode(),
                        "d={d} k={k} s={s}: fused bytes diverge from staged wire body"
                    );
                    assert_eq!(fused.w, sw.values, "d={d} k={k} s={s}");
                    assert_eq!(fused.m, sm.values);
                    assert_eq!(fused.v, sv.values);
                }
            }
        }
    }

    #[test]
    fn fused_encode_zero_scale_vector() {
        // A vector whose kept lanes are all exactly 0.0 has scale 0 and
        // must reconstruct exactly 0.0 on every kept lane.
        let d = 100;
        let idx = [3u32, 10, 77];
        let w = vec![0.0f32; d];
        let m: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let fused = super::ssm_q_encode_fused(d, &idx, &w, &m, &w, 16);
        assert_eq!(fused.w, vec![0.0; 3]);
        assert_eq!(fused.v, vec![0.0; 3]);
        assert_eq!(fused.bits, cost::fedadam_ssm_q(d, 3, 16));
        assert_eq!(fused.bytes.len() as u64, fused.bits.div_ceil(8));
    }

    #[test]
    fn extremes_and_midpoint_are_exact_for_odd_s() {
        // Odd s puts a representable level at exactly 0, so {-max, 0, max}
        // survive the round trip bit-exactly.
        let p = sparse_uniform_compress(&[-2.0, 0.0, 2.0], 5);
        assert_eq!(sparse_uniform_decompress(&p), vec![-2.0, 0.0, 2.0]);
    }
}
