//! Wire-transport integration suite.
//!
//! Pins the transport tentpole's two contracts end to end, on the
//! pure-Rust reference backend (no PJRT artifacts needed):
//!
//! - **Bit-identity**: a remote run — one coordinator with
//!   `transport_listen` set, device-agent shards connected over real
//!   sockets (TCP and Unix-domain) — reproduces the in-process run byte
//!   for byte: every logged number and the final `(W, M, V)`, at
//!   pipeline depth 0 and with the overlapped loop, across agent counts,
//!   with stateful (error-feedback, device-local-moment) algorithms.
//! - **Hostile bytes**: the server's trust boundary.  `compress` and
//!   `compress_wire → encode → try_decode → try_into_upload` are
//!   observationally identical twins for every algorithm id; a
//!   mid-round connection drop is repaired by reconnect + downlink
//!   replay without double-counting; a protocol violation costs the
//!   sender its connection and surfaces in the round-timeout report; a
//!   mispriced message is refused at *send* time in every build profile.

use std::io::Write as _;
use std::path::PathBuf;
use std::time::Duration;

use fedadam_ssm::algorithms::wire::{WireBody, WireUpload};
use fedadam_ssm::algorithms::{self, LocalDelta, Recon, Upload, ALL_WITH_EXTENSIONS};
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool, ModelMeta};
use fedadam_ssm::transport::frame::{read_frame, write_frame};
use fedadam_ssm::transport::msg::{Assignment, Msg, Uplink, PROTOCOL_VERSION};
use fedadam_ssm::transport::net::Stream;
use fedadam_ssm::transport::{run_agent, run_agent_with, AgentOptions, TransportServer};

const INPUT_SHAPE: [usize; 3] = [4, 4, 1]; // row 16
const CLASSES: usize = 10;

fn meta() -> ModelMeta {
    // dim = 10 * (16 + 1) = 170
    reference_meta(&INPUT_SHAPE, CLASSES, 4, 8, 2)
}

fn base_cfg(algo: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "transport".into();
    cfg.model = "reference-linear".into();
    cfg.algorithm = algo.into();
    cfg.rounds = 4;
    cfg.devices = 3;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 2;
    cfg.lr = 0.02;
    cfg.train_samples = 96;
    cfg.test_samples = 50;
    cfg.seed = 7;
    cfg.eval_every = 1;
    cfg.quant_levels = 16;
    cfg.warmup_rounds = 2;
    cfg.num_workers = 2;
    cfg
}

type RunOut = (ExperimentLog, Vec<f32>, Vec<f32>, Vec<f32>);

fn run_in_process(cfg: ExperimentConfig) -> RunOut {
    let pool = reference_pool(meta(), cfg.num_workers).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg, pool).expect("coordinator");
    let log = coord.run().expect("in-process run");
    let gs = coord.global();
    (log, gs.w.clone(), gs.m.clone(), gs.v.clone())
}

/// Run `cfg` remotely: bind the coordinator's transport at `listen`,
/// spawn `agents` device-agent threads against the resolved address —
/// the same code path the `device-agent` binary runs, minus the process
/// boundary — and drive the round loop over real sockets.
fn run_remote(mut cfg: ExperimentConfig, listen: &str, agents: usize) -> RunOut {
    cfg.transport_listen = listen.into();
    cfg.transport_agents = agents;
    cfg.transport_timeout_secs = 30.0;
    let pool = reference_pool(meta(), cfg.num_workers).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg.clone(), pool).expect("coordinator");
    let addr = coord.transport_addr().expect("transport bound");
    let handles: Vec<_> = (0..agents)
        .map(|i| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            std::thread::spawn(move || -> anyhow::Result<()> {
                let pool = reference_pool(meta(), 1)?;
                run_agent(&cfg, &pool, &addr, i)
            })
        })
        .collect();
    let log = coord.run().expect("remote run");
    for (i, h) in handles.into_iter().enumerate() {
        h.join()
            .expect("agent thread panicked")
            .unwrap_or_else(|e| panic!("agent {i} failed: {e:#}"));
    }
    let gs = coord.global();
    (log, gs.w.clone(), gs.m.clone(), gs.v.clone())
}

fn assert_identical(a: &RunOut, b: &RunOut, compare_sim: bool, tag: &str) {
    assert_eq!(a.1, b.1, "{tag}: global W diverged");
    assert_eq!(a.2, b.2, "{tag}: global M diverged");
    assert_eq!(a.3, b.3, "{tag}: global V diverged");
    assert_eq!(a.0.rounds.len(), b.0.rounds.len(), "{tag}: round count");
    for (x, y) in a.0.rounds.iter().zip(&b.0.rounds) {
        let t = format!("{tag} round {}", x.round);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{t}: train loss");
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{t}: test loss");
        assert_eq!(
            x.test_accuracy.to_bits(),
            y.test_accuracy.to_bits(),
            "{t}: accuracy"
        );
        assert_eq!(x.uplink_bits, y.uplink_bits, "{t}: uplink ledger");
        assert_eq!(x.downlink_bits, y.downlink_bits, "{t}: downlink ledger");
        assert_eq!(x.update_norm.to_bits(), y.update_norm.to_bits(), "{t}: norm");
        if compare_sim {
            assert_eq!(x.sim_secs.to_bits(), y.sim_secs.to_bits(), "{t}: sim clock");
        }
    }
}

// ---------------------------------------------------------------------------
// compress / compress_wire twin conformance
// ---------------------------------------------------------------------------

fn recon_eq(a: &Recon, b: &Recon) -> bool {
    match (a, b) {
        (Recon::Dense(x), Recon::Dense(y)) => {
            x.len() == y.len()
                && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (Recon::Sparse(x), Recon::Sparse(y)) => {
            x.indices == y.indices
                && x.values.len() == y.values.len()
                && x.values
                    .iter()
                    .zip(&y.values)
                    .all(|(p, q)| p.to_bits() == q.to_bits())
        }
        _ => false,
    }
}

fn upload_eq(a: &Upload, b: &Upload) -> bool {
    let opt_eq = |x: &Option<Recon>, y: &Option<Recon>| match (x, y) {
        (Some(x), Some(y)) => recon_eq(x, y),
        (None, None) => true,
        _ => false,
    };
    recon_eq(&a.dw, &b.dw)
        && opt_eq(&a.dm, &b.dm)
        && opt_eq(&a.dv, &b.dv)
        && a.weight.to_bits() == b.weight.to_bits()
        && a.bits == b.bits
}

/// Deterministic pseudo-random delta (no rand crate in the offline build).
fn synth_delta(seed: &mut u64, dim: usize, weight: f64) -> LocalDelta {
    let mut next = || {
        *seed = seed
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((*seed >> 40) as u32) as f32 / (1u32 << 24) as f32 - 0.5
    };
    LocalDelta {
        dw: (0..dim).map(|_| next()).collect(),
        dm: (0..dim).map(|_| next() * 0.1).collect(),
        dv: (0..dim).map(|_| (next() * 0.01).abs()).collect(),
        weight,
    }
}

#[test]
fn compress_wire_is_an_observational_twin_of_compress() {
    // For EVERY buildable algorithm id: two independently-built instances
    // fed identical deltas — one through the in-process `compress` path,
    // one through the full transport path (compress_wire → encode_body →
    // try_decode → try_into_upload) — must produce bit-identical uploads
    // with identical priced bits, and the framed body must honor the
    // byte-accounting invariant the server enforces.
    let dim = 64;
    for algo in ALL_WITH_EXTENSIONS {
        let cfg = base_cfg(algo);
        let mut local = algorithms::build(&cfg, dim).unwrap();
        let mut remote = algorithms::build(&cfg, dim).unwrap();
        let mut seed = 0x5EED_0001u64;
        for round in 0..4 {
            for device in 0..cfg.devices {
                let delta = synth_delta(&mut seed, dim, 30.0 + device as f64);
                let want = local.compress(round, device, delta.clone());
                let wire = remote
                    .compress_wire(round, device, delta)
                    .unwrap_or_else(|e| panic!("{algo}: compress_wire: {e:#}"));
                assert_eq!(wire.bits, want.bits, "{algo} r{round} d{device}: priced bits");
                let body = wire
                    .encode_body()
                    .unwrap_or_else(|e| panic!("{algo}: encode_body: {e:#}"));
                assert_eq!(
                    body.len() as u64,
                    wire.bits.div_ceil(8),
                    "{algo} r{round} d{device}: framed bytes != ceil(bits/8)"
                );
                let decoded = WireBody::try_decode(
                    wire.body.kind(),
                    dim,
                    wire.body.k(),
                    wire.body.levels(),
                    wire.bits,
                    &body,
                )
                .unwrap_or_else(|e| panic!("{algo} r{round} d{device}: try_decode: {e}"));
                let got = decoded
                    .try_into_upload(wire.weight)
                    .unwrap_or_else(|e| panic!("{algo} r{round} d{device}: try_into_upload: {e}"));
                assert!(
                    upload_eq(&want, &got),
                    "{algo} r{round} d{device}: decoded upload diverged from compress()"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// socket bit-identity
// ---------------------------------------------------------------------------

#[test]
fn tcp_remote_run_is_bit_identical_to_in_process() {
    // The stateful extremes: fedadam-ssm-qef carries per-device
    // error-feedback memory through the quantizer, efficient-adam keeps
    // device-local moments — both live agent-side in a remote run, and
    // both must still reproduce the in-process bytes.  simtime on: the
    // simulated clock must survive the transport too.
    for algo in ["fedadam-ssm-qef", "efficient-adam"] {
        let mut cfg = base_cfg(algo);
        cfg.simtime = true;
        let local = run_in_process(cfg.clone());
        let remote = run_remote(cfg, "127.0.0.1:0", 2);
        assert_identical(&local, &remote, true, &format!("{algo} tcp x2"));
    }
}

#[test]
fn remote_identity_holds_across_agent_counts() {
    // Device ownership is static (device % agents) but the *sharding*
    // must not matter: 1 agent and 3 agents (devices == agents: one
    // device each) produce the same bytes.
    let cfg = base_cfg("fedadam-ssm-q");
    let local = run_in_process(cfg.clone());
    for agents in [1usize, 3] {
        let remote = run_remote(cfg.clone(), "127.0.0.1:0", agents);
        assert_identical(&local, &remote, false, &format!("ssm-q tcp x{agents}"));
    }
}

#[test]
fn remote_identity_holds_under_the_overlapped_loop() {
    // pipeline_depth >= 2 overlaps eval with the next round's training;
    // the remote round driver slots uploads out of arrival order.  The
    // two reorderings composed must still be a no-op on the bytes.
    let mut cfg = base_cfg("fedadam-ssm");
    cfg.rounds = 5;
    cfg.eval_every = 2;
    cfg.pipeline_depth = 2;
    let local = run_in_process(cfg.clone());
    let remote = run_remote(cfg, "127.0.0.1:0", 2);
    assert_identical(&local, &remote, false, "ssm tcp depth2");
}

#[test]
fn uds_remote_run_is_bit_identical_to_in_process() {
    let sock = std::env::temp_dir().join(format!("fedadam-transport-{}.sock", std::process::id()));
    let listen = format!("unix:{}", sock.display());
    let cfg = base_cfg("fedadam-ssm");
    let local = run_in_process(cfg.clone());
    let remote = run_remote(cfg, &listen, 2);
    assert_identical(&local, &remote, false, "ssm uds x2");
    assert!(!sock.exists(), "socket file not cleaned up on shutdown");
}

// ---------------------------------------------------------------------------
// durability: a killed agent respawns as a FRESH process and stays
// bit-identical (rust/src/transport/agent_state.rs)
// ---------------------------------------------------------------------------

fn tmp_state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fedadam-agentstate-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The experiment CSV with the non-deterministic cells normalized:
/// `wall_secs` is host time and the measured uplink-latency cells are
/// host time too (finite over a real wire, empty in process) — all three
/// are outside the bit-identity contract; every other cell must match
/// byte for byte.
fn csv_normalized(log: &ExperimentLog) -> String {
    let mut log = log.clone();
    for r in &mut log.rounds {
        r.wall_secs = 0.0;
        r.meas_uplink_max_secs = f64::NAN;
        r.meas_uplink_mean_secs = f64::NAN;
    }
    log.to_csv()
}

/// [`run_remote`], except agent `kill_agent` runs with `kill` crash
/// injection and — once its first incarnation has exited — is replaced
/// by a **fresh** [`run_agent`] call on a freshly-built pool.  All agent
/// state is function-local or in `agent_state_dir`, so thread-exit +
/// fresh call is observationally a process kill + respawn.
fn run_remote_with_kill(
    mut cfg: ExperimentConfig,
    listen: &str,
    agents: usize,
    kill_agent: usize,
    kill: AgentOptions,
) -> RunOut {
    cfg.transport_listen = listen.into();
    cfg.transport_agents = agents;
    cfg.transport_timeout_secs = 30.0;
    let pool = reference_pool(meta(), cfg.num_workers).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg.clone(), pool).expect("coordinator");
    let addr = coord.transport_addr().expect("transport bound");
    let handles: Vec<_> = (0..agents)
        .map(|i| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            std::thread::spawn(move || -> anyhow::Result<()> {
                if i == kill_agent {
                    // First incarnation: dies at the injected point.
                    let pool = reference_pool(meta(), 1)?;
                    run_agent_with(&cfg, &pool, &addr, i, &kill)?;
                    drop(pool);
                    // Respawn: nothing survives but the state directory.
                    let pool = reference_pool(meta(), 1)?;
                    run_agent(&cfg, &pool, &addr, i)
                } else {
                    let pool = reference_pool(meta(), 1)?;
                    run_agent(&cfg, &pool, &addr, i)
                }
            })
        })
        .collect();
    let log = coord.run().expect("remote run with kill");
    for (i, h) in handles.into_iter().enumerate() {
        h.join()
            .expect("agent thread panicked")
            .unwrap_or_else(|e| panic!("agent {i} failed: {e:#}"));
    }
    let gs = coord.global();
    (log, gs.w.clone(), gs.m.clone(), gs.v.clone())
}

/// Shared asserts for the kill-respawn suite: full bit-identity against
/// the in-process run, CSV equality modulo the host-time cells, and the
/// measured-latency columns populated on the wire / empty in process.
fn assert_respawn_identical(local: &RunOut, remote: &RunOut, tag: &str) {
    assert_identical(local, remote, false, tag);
    assert_eq!(
        csv_normalized(&local.0),
        csv_normalized(&remote.0),
        "{tag}: CSV diverged beyond the host-time cells"
    );
    for r in &remote.0.rounds {
        assert!(
            r.meas_uplink_max_secs.is_finite() && r.meas_uplink_mean_secs.is_finite(),
            "{tag}: remote round {} missing measured uplink latency",
            r.round
        );
        assert!(
            r.meas_uplink_max_secs >= r.meas_uplink_mean_secs,
            "{tag}: round {} max < mean",
            r.round
        );
    }
    for r in &local.0.rounds {
        assert!(
            r.meas_uplink_max_secs.is_nan() && r.meas_uplink_mean_secs.is_nan(),
            "{tag}: in-process round {} claims a measured wire latency",
            r.round
        );
    }
}

#[test]
fn killed_agent_respawns_as_a_fresh_process_bit_identical() {
    // EF state lives inside the algorithm on the owning agent; kill that
    // agent after round 1 completed (state persisted, uplinks sent) and
    // respawn it cold.  Without the durable state log the respawn would
    // restart EF memories from zero and every later round would diverge.
    // Grid: both EF ids x TCP/UDS x 1-or-2 agents.
    let grid: [(&str, bool, usize); 4] = [
        ("fedadam-ssm-ef", false, 2),
        ("fedadam-ssm-qef", false, 1),
        ("fedadam-ssm-ef", true, 1),
        ("fedadam-ssm-qef", true, 2),
    ];
    for (algo, uds, agents) in grid {
        let wire = if uds { "uds" } else { "tcp" };
        let tag = format!("respawn-{algo}-{wire}-x{agents}");
        let dir = tmp_state_dir(&tag);
        let mut cfg = base_cfg(algo);
        cfg.agent_state_dir = dir.to_string_lossy().into_owned();
        let sock =
            std::env::temp_dir().join(format!("fedadam-{}-{tag}.sock", std::process::id()));
        let listen = if uds {
            format!("unix:{}", sock.display())
        } else {
            "127.0.0.1:0".to_string()
        };
        let local = run_in_process(base_cfg(algo));
        let kill = AgentOptions {
            exit_after_round: Some(1),
            ..AgentOptions::default()
        };
        let remote = run_remote_with_kill(cfg, &listen, agents, 0, kill);
        assert_respawn_identical(&local, &remote, &tag);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn crash_between_persist_and_send_replays_durable_frames_verbatim() {
    // The nastiest window: the round's state (post-compression EF
    // mutations included) is durable, but NO uplink reached the server.
    // The server replays the round on reconnect; retraining it would
    // mutate EF state a second time, so the respawned agent must replay
    // the persisted frames byte for byte instead.  Every stateful id:
    // EF and quantized-EF (state in the algorithm), one-bit and
    // efficient-adam (device-local moments; warmup_rounds=2 puts round 2
    // in the stateful phase).
    for algo in [
        "fedadam-ssm-ef",
        "fedadam-ssm-qef",
        "onebit-adam",
        "efficient-adam",
    ] {
        let tag = format!("presend-{algo}");
        let dir = tmp_state_dir(&tag);
        let mut cfg = base_cfg(algo);
        cfg.agent_state_dir = dir.to_string_lossy().into_owned();
        let local = run_in_process(base_cfg(algo));
        let kill = AgentOptions {
            exit_before_send_round: Some(2),
            ..AgentOptions::default()
        };
        let remote = run_remote_with_kill(cfg, "127.0.0.1:0", 2, 1, kill);
        assert_respawn_identical(&local, &remote, &tag);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// trust boundary: reconnects, violations, send-side pricing
// ---------------------------------------------------------------------------

fn client_hello(stream: &mut Stream, fingerprint: u64, agent: u32) {
    write_frame(
        stream,
        &Msg::Hello {
            version: PROTOCOL_VERSION,
            fingerprint,
            agent,
        }
        .encode(),
    )
    .unwrap();
    let ack = read_frame(stream).unwrap();
    let Msg::HelloAck { .. } = Msg::decode(&ack).unwrap() else {
        panic!("expected HelloAck");
    };
}

fn read_round_start(stream: &mut Stream) -> u64 {
    let payload = read_frame(stream).unwrap();
    let Msg::RoundStart { round, .. } = Msg::decode(&payload).unwrap() else {
        panic!("expected RoundStart");
    };
    round
}

fn dense_uplink_frame(round: u64, a: &Assignment, dim: usize, fill: f32) -> Vec<u8> {
    let body = WireBody::Dense3 {
        dw: vec![fill; dim],
        dm: vec![fill * 0.5; dim],
        dv: vec![fill.abs() * 0.25; dim],
    };
    let msg = Msg::Uplink(Uplink {
        round,
        slot: a.slot,
        device: a.device,
        mean_loss: 1.5 + f64::from(a.slot),
        weight: a.weight,
        kind: body.kind(),
        k: body.k() as u64,
        levels: body.levels(),
        bits: body.wire_bits(),
        body: body.encode(),
    });
    let mut frame = Vec::new();
    write_frame(&mut frame, &msg.encode()).unwrap();
    frame
}

#[test]
fn reconnect_mid_round_is_repaired_by_replay_without_double_count() {
    // Agent 0 uploads slot 0, drops its connection mid-round, reconnects,
    // receives the replayed RoundStart, re-sends slot 0 (a benign
    // duplicate) and finishes slot 1.  The sink must see each slot
    // exactly once.
    let dim = 6;
    let fp = 0xFEED_u64;
    let mut server = TransportServer::bind("127.0.0.1:0", 1, 2.0, fp, dim).unwrap();
    let addr = server.addr().to_string();
    let asn = vec![
        Assignment { slot: 0, device: 0, weight: 10.0 },
        Assignment { slot: 1, device: 1, weight: 11.0 },
    ];
    let asn_client = asn.clone();
    let client = std::thread::spawn(move || {
        let mut s = Stream::connect(&addr).unwrap();
        client_hello(&mut s, fp, 0);
        assert_eq!(read_round_start(&mut s), 3);
        s.write_all(&dense_uplink_frame(3, &asn_client[0], dim, 0.5)).unwrap();
        s.flush().unwrap();
        // Let the server ingest slot 0 before the connection dies.
        std::thread::sleep(Duration::from_millis(100));
        drop(s);

        let mut s = Stream::connect(&addr).unwrap();
        client_hello(&mut s, fp, 0);
        assert_eq!(read_round_start(&mut s), 3, "reconnect must replay the round");
        s.write_all(&dense_uplink_frame(3, &asn_client[0], dim, 0.5)).unwrap();
        s.write_all(&dense_uplink_frame(3, &asn_client[1], dim, -0.25)).unwrap();
        s.flush().unwrap();
        // Wait for Shutdown so the server owns the teardown order.
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        loop {
            match read_frame(&mut s) {
                Ok(p) => {
                    if matches!(Msg::decode(&p), Ok(Msg::Shutdown)) {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    });

    let mut got: Vec<(usize, usize, u64)> = Vec::new();
    let w = vec![0.0f32; dim];
    server
        .run_round(3, &w, None, None, &asn, |slot, device, mean_loss, upload| {
            assert!(mean_loss.is_finite());
            got.push((slot, device, upload.bits));
            Ok(())
        })
        .unwrap();
    server.shutdown();
    client.join().unwrap();

    got.sort_unstable();
    let dense3_bits = 3 * dim as u64 * 32;
    assert_eq!(
        got,
        vec![(0, 0, dense3_bits), (1, 1, dense3_bits)],
        "each slot must land exactly once despite the replay"
    );
}

#[test]
fn protocol_violation_drops_the_connection_and_surfaces_in_the_timeout() {
    // A tampered weight echo is a violation: the server drops the
    // connection, and with no reconnect the round deadline reports both
    // the missing slots and the violation that caused them.
    let dim = 4;
    let fp = 7u64;
    let mut server = TransportServer::bind("127.0.0.1:0", 1, 0.3, fp, dim).unwrap();
    let addr = server.addr().to_string();
    let asn = vec![Assignment { slot: 0, device: 0, weight: 10.0 }];
    let mut tampered = asn[0].clone();
    tampered.weight = 10.5;
    let client = std::thread::spawn(move || {
        let mut s = Stream::connect(&addr).unwrap();
        client_hello(&mut s, fp, 0);
        let round = read_round_start(&mut s);
        s.write_all(&dense_uplink_frame(round, &tampered, dim, 1.0)).unwrap();
        s.flush().unwrap();
        // The server hangs up on us; observe it rather than racing it.
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = read_frame(&mut s);
    });

    let w = vec![0.0f32; dim];
    let err = server
        .run_round(0, &w, None, None, &asn, |_, _, _, _| Ok(()))
        .expect_err("tampered uplink must not complete the round");
    let text = format!("{err:#}");
    assert!(text.contains("timed out"), "unexpected error: {text}");
    assert!(
        text.contains("weight echo mismatch"),
        "timeout must carry the violation: {text}"
    );
    client.join().unwrap();
}

#[test]
fn fingerprint_mismatch_is_refused_at_registration() {
    let dim = 4;
    let mut server = TransportServer::bind("127.0.0.1:0", 1, 0.3, 42, dim).unwrap();
    let addr = server.addr().to_string();
    let asn = vec![Assignment { slot: 0, device: 0, weight: 1.0 }];
    let client = std::thread::spawn(move || {
        let mut s = Stream::connect(&addr).unwrap();
        write_frame(
            &mut s,
            &Msg::Hello { version: PROTOCOL_VERSION, fingerprint: 43, agent: 0 }.encode(),
        )
        .unwrap();
        // The server refuses the handshake: no ack, connection dropped.
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        assert!(read_frame(&mut s).is_err(), "mismatched fingerprint got an ack");
    });
    let w = vec![0.0f32; dim];
    let err = server
        .run_round(0, &w, None, None, &asn, |_, _, _, _| Ok(()))
        .expect_err("no registered agent: the round cannot run");
    assert!(
        format!("{err:#}").contains("did not register"),
        "unexpected error: {err:#}"
    );
    client.join().unwrap();
}

#[test]
fn mispriced_message_is_refused_at_send_in_every_profile() {
    // Satellite 3: the priced-bits == framed-bytes invariant is an
    // `ensure!`, not a debug_assert — it must hold under `--release` too.
    // Lying about the price in either direction fails encode_body().
    let body = WireBody::Dense3 {
        dw: vec![1.0; 5],
        dm: vec![0.5; 5],
        dv: vec![0.25; 5],
    };
    let honest = body.wire_bits();
    for lie in [honest + 1, honest + 8, honest.saturating_sub(1), 0] {
        if lie == honest {
            continue;
        }
        let wire = WireUpload { body: body.clone(), weight: 1.0, bits: lie };
        assert!(
            wire.encode_body().is_err(),
            "encode_body accepted priced bits {lie} for a {honest}-bit body"
        );
    }
    let wire = WireUpload { body, weight: 1.0, bits: honest };
    let bytes = wire.encode_body().expect("honest pricing must encode");
    assert_eq!(bytes.len() as u64, honest.div_ceil(8));
}
