"""Quantization kernels for the baseline algorithms (paper §VII-A).

- :func:`onebit_quantize` — the compression step of **1-bit Adam** [29]:
  error-compensated sign quantization.  The compressed representation is
  ``scale * sign(x + e)`` where ``scale = mean(|x + e|)`` and the new error
  feedback memory is ``(x + e) - scale * sign(x + e)``.
- :func:`uniform_quantize` — the two-way compressor of **Efficient-Adam**
  [28]: s-level uniform quantization on ``[-max|x|, max|x|]`` with
  deterministic rounding (the rust L3 mirrors both, bit-packing included).

Both kernels are single fused element-wise passes; the global reductions
(mean / max of ``|x|``) run as XLA reductions before the Pallas pass, same
structure as the SSM kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.adam_update import BLOCK


def _onebit_kernel(x_ref, e_ref, s_ref, q_ref, eo_ref):
    c = x_ref[...] + e_ref[...]
    scale = s_ref[0]
    # sign(0) := +1 so every lane carries exactly one bit.
    q = jnp.where(c >= 0.0, scale, -scale)
    q_ref[...] = q
    eo_ref[...] = c - q


@functools.partial(jax.jit, static_argnames=("block",))
def onebit_quantize(x, err, *, block=BLOCK):
    """Error-compensated 1-bit (sign) quantization.

    Args:
      x: ``f32[d]`` vector to compress.
      err: ``f32[d]`` error-feedback memory from the previous round.

    Returns:
      ``(q, err')`` where ``q = scale * sign(x + err)`` is the dequantized
      representation (1 bit/lane + one f32 scale on the wire) and ``err'``
      is the updated memory.
    """
    d = x.shape[0]
    c = x + err
    scale = jnp.mean(jnp.abs(c))
    dpad = (d + block - 1) // block * block
    pad = dpad - d
    xp = jnp.pad(x, (0, pad)) if pad else x
    ep = jnp.pad(err, (0, pad)) if pad else err
    spec = pl.BlockSpec((block,), lambda i: (i,))
    sspec = pl.BlockSpec((1,), lambda i: (0,))
    q, eo = pl.pallas_call(
        _onebit_kernel,
        grid=(dpad // block,),
        in_specs=[spec, spec, sspec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((dpad,), jnp.float32)] * 2,
        interpret=True,
    )(xp, ep, scale[None])
    if pad:
        q, eo = q[:d], eo[:d]
    return q, eo


def _uniform_kernel(x_ref, p_ref, q_ref):
    # p = [scale, levels]; levels = s - 1 bins over [-scale, scale].
    scale = p_ref[0]
    levels = p_ref[1]
    x = x_ref[...]
    # Guard scale == 0 (all-zero input): emit zeros.
    safe = jnp.maximum(scale, 1e-30)
    t = jnp.clip(x / safe, -1.0, 1.0)  # [-1, 1]
    q = jnp.round((t + 1.0) * 0.5 * levels)  # {0..levels}
    deq = (q / levels * 2.0 - 1.0) * safe
    q_ref[...] = jnp.where(scale > 0.0, deq, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("block",))
def uniform_quantize(x, s_levels, *, block=BLOCK):
    """Deterministic s-level uniform quantization over ``[-max|x|, max|x|]``.

    Args:
      x: ``f32[d]``.
      s_levels: number of representable values ``s >= 2`` (wire cost
        ``ceil(log2 s)`` bits/lane + one f32 scale); may be traced.

    Returns:
      Dequantized ``f32[d]`` (the value the server reconstructs).
    """
    d = x.shape[0]
    scale = jnp.max(jnp.abs(x))
    levels = jnp.asarray(s_levels, jnp.float32) - 1.0
    dpad = (d + block - 1) // block * block
    pad = dpad - d
    xp = jnp.pad(x, (0, pad)) if pad else x
    spec = pl.BlockSpec((block,), lambda i: (i,))
    pspec = pl.BlockSpec((2,), lambda i: (0,))
    params = jnp.stack([scale, levels])
    q = pl.pallas_call(
        _uniform_kernel,
        grid=(dpad // block,),
        in_specs=[spec, pspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((dpad,), jnp.float32),
        interpret=True,
    )(xp, params)
    return q[:d] if pad else q
