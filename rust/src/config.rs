//! Experiment configuration: TOML files + CLI overrides.
//!
//! Defaults follow the paper §VII-A: N = 20 devices, L = 30 local epochs,
//! η = 0.001, α = 0.05, Dirichlet θ = 0.1, Adam (0.9, 0.999, 1e-6).  The
//! CPU-scale experiment configs under `configs/` shrink N / L / corpus so a
//! full sweep runs in minutes; every knob here is runtime (no recompiled
//! artifacts needed).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::toml::{self, TomlValue};

/// Where the SSM sparsification runs (DESIGN.md §Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparsifyBackend {
    /// rust quickselect (`sparse::topk`) — default, O(d).
    Native,
    /// The AOT-compiled Layer-1 Pallas kernel (`sparsify` program).
    Xla,
}

impl SparsifyBackend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(SparsifyBackend::Native),
            "xla" => Ok(SparsifyBackend::Xla),
            _ => bail!("unknown sparsify backend {s:?} (native|xla)"),
        }
    }
}

/// Which cohort sampler picks each round's participants
/// (see [`crate::coordinator::sampler`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParticipationMode {
    /// Uniform without replacement — the original loop's behavior and the
    /// bit-identical default.
    Uniform,
    /// `m` i.i.d. draws with probability proportional to local data size,
    /// with the unbiased `1/(m·p_i)` FedAvg re-weighting carried through
    /// the cohort-weight path.
    Importance,
    /// Deterministic per-device on/off duty-cycle traces plus
    /// over-selection with a deadline (the slowest over-selected
    /// candidates are dropped).
    Availability,
}

impl ParticipationMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uniform" => Ok(ParticipationMode::Uniform),
            "importance" => Ok(ParticipationMode::Importance),
            "availability" => Ok(ParticipationMode::Availability),
            _ => bail!("unknown participation mode {s:?} (uniform|importance|availability)"),
        }
    }

    /// The config-file spelling (inverse of [`Self::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            ParticipationMode::Uniform => "uniform",
            ParticipationMode::Importance => "importance",
            ParticipationMode::Availability => "availability",
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Experiment tag used in output files.
    pub name: String,
    /// Model name in the AOT manifest (e.g. `cnn_small`).
    pub model: String,
    /// Algorithm id — see `algorithms::build`.
    pub algorithm: String,
    /// Communication rounds `T`.
    pub rounds: usize,
    /// Devices `N`.
    pub devices: usize,
    /// Local epochs `L`.
    pub local_epochs: usize,
    /// Cap on batches per local epoch (0 = full shard). Keeps CPU runs fast.
    pub max_batches_per_epoch: usize,
    /// Learning rate η.
    pub lr: f64,
    /// Sparsification ratio α = k/d.
    pub sparsity: f64,
    /// IID split?
    pub iid: bool,
    /// Dirichlet concentration θ for non-IID.
    pub dirichlet_theta: f64,
    /// Training corpus size (synthetic stand-in).
    pub train_samples: usize,
    /// Test corpus size.
    pub test_samples: usize,
    /// RNG seed (data, partition, init).
    pub seed: u64,
    /// Evaluate every this many rounds (1 = every round).
    pub eval_every: usize,
    /// Efficient-Adam quantization levels `s`.
    pub quant_levels: usize,
    /// 1-bit Adam warmup rounds.
    pub warmup_rounds: usize,
    /// Use the fused `epoch` (lax.scan) program where possible.
    ///
    /// §Perf finding: on CPU-PJRT the scanned program defeats XLA's
    /// per-dispatch optimizer (231 ms vs 109 ms for 4 cnn_small batches;
    /// 1.47x end-to-end), so the default is OFF here; on TPU the scan is
    /// the dispatch-amortization win, so flip it per target.
    pub use_epoch_program: bool,
    /// SSM selection backend.
    pub sparsify_backend: SparsifyBackend,
    /// Fraction of devices participating per round (1.0 = all, the paper's
    /// setting; < 1.0 = partial participation through the configured
    /// [`ParticipationMode`]).
    pub participation: f64,
    /// How the per-round cohort is drawn (`uniform` | `importance` |
    /// `availability`).  `uniform` reproduces the original loop bit for
    /// bit; see [`crate::coordinator::sampler`] for the other two.
    pub participation_mode: ParticipationMode,
    /// `availability` mode: fraction of rounds each device is on-duty
    /// (its deterministic duty-cycle trace fires with this rate).
    pub duty_cycle: f64,
    /// `availability` mode: over-selection factor — up to
    /// `ceil(target · over_select)` available devices are contacted and
    /// the slowest extras are dropped at the deadline (>= 1.0).
    pub over_select: f64,
    /// Advance a simulated wall-clock per round (virtual time — never
    /// reads the host clock) and record it in the experiment log.  The
    /// latency model itself ([`crate::simtime::LatencyModel`]) is always
    /// built; this knob only gates the clock and the logged column.
    pub simtime: bool,
    /// Simulated per-device uplink bandwidth in Mbit/s (uplink seconds =
    /// `wire_bits / (sim_bandwidth_mbps · 1e6)`).
    pub sim_bandwidth_mbps: f64,
    /// Simulated baseline training throughput in samples/second (the
    /// fastest device; compute seconds = samples · slowdown / this).
    pub sim_samples_per_sec: f64,
    /// Device-speed heterogeneity: per-device slowdown factors are drawn
    /// log-uniformly from `[1, sim_hetero]` (seed-deterministic).
    /// `1.0` = homogeneous fleet.
    pub sim_hetero: f64,
    /// Engine-pool worker threads (each owns its own PJRT client and
    /// compiled executables).  `0` = auto-detect core count; `1` (default)
    /// reproduces the original single-engine actor.  Results are bitwise
    /// identical at any worker count — only wall-clock changes.
    pub num_workers: usize,
    /// Lane shards for the server-side aggregation reduce.  `0` (default)
    /// = one shard per pool worker.  The reduce partitions `[0, dim)` into
    /// fixed contiguous ranges, so results are bitwise identical at any
    /// shard count — only wall-clock changes.
    pub agg_shards: usize,
    /// Round-loop pipelining depth.  `0` (default) = legacy barrier
    /// (train all → aggregate → eval inline); `1` = streaming aggregation
    /// (uploads fold into the server accumulator as they land); `>= 2` =
    /// plus train/eval overlap (round `t`'s eval fans out through the
    /// engine pool concurrently with round `t+1`'s training dispatch; at
    /// most `pipeline_depth - 1` evals stay in flight).  Results are
    /// bitwise identical at any depth — only wall-clock changes.
    pub pipeline_depth: usize,
    /// Event-journal directory (see [`crate::coordinator::journal`]).
    /// Non-empty = journal every round-loop state transition there and
    /// snapshot full coordinator state every `snapshot_every` rounds;
    /// empty (default) = journaling off.  Journaling is pure observation:
    /// results are bitwise identical with it on or off.
    pub journal: String,
    /// Resume an interrupted run from this journal directory (empty =
    /// fresh start).  The resumed run restores the latest snapshot,
    /// re-executes the logged tail under byte-exact replay verification,
    /// finishes the remaining rounds, and keeps appending to the same
    /// journal.  The journal must have been written by a config with the
    /// same [`ExperimentConfig::fingerprint`].
    pub resume: String,
    /// Snapshot cadence in rounds when journaling (must be >= 1; a crash
    /// re-executes at most this many rounds on resume).
    pub snapshot_every: usize,
    /// Wire-transport listen address (see [`crate::transport`]).
    /// Non-empty = the coordinator binds here (`host:port` for TCP, port
    /// `0` picks a free one; `unix:/path` for a Unix domain socket) and
    /// farms each round's local training out to remote device-agent
    /// processes instead of its in-process thread pool.  Empty (default)
    /// = fully in-process.  Results are bitwise identical either way —
    /// only the process topology changes.
    pub transport_listen: String,
    /// Number of device-agent processes the transport server waits for.
    /// Agent `i` owns every device with `device % transport_agents == i`.
    /// Must be >= 1 when `transport_listen` is set.
    pub transport_agents: usize,
    /// Transport I/O deadline in (real) seconds: how long the server
    /// waits for agents to register, and for each in-flight uplink before
    /// declaring the connection dead and re-admitting a reconnect.
    pub transport_timeout_secs: f64,
    /// Resident cap of the per-device residual store (the `-ef`/`-qef`/
    /// `onebit`/`efficient` error-feedback residuals and the coordinator's
    /// device-local Adam moments): at most this many per-device entries
    /// stay in RAM; least-recently-used entries beyond it spill to
    /// `residual_spill_dir` and rehydrate bit-identically on the next
    /// touch.  `0` (default) = unbounded, i.e. the classic dense-in-RAM
    /// behavior.  Pure memory-placement knob — results are bit-identical
    /// at any cap, so it is excluded from the fingerprint.
    pub residual_resident_cap: usize,
    /// Directory for the residual store's spill files (created on first
    /// eviction, removed with the run).  Required non-empty when
    /// `residual_resident_cap > 0`.
    pub residual_spill_dir: String,
    /// Directory where each device agent persists its per-device
    /// compressor state (error-feedback residuals, 1-bit warmup,
    /// device-local Adam moments, the last round's encoded uplink
    /// frames) as a crash-safe `agent_<index>.state` append log — see
    /// [`crate::transport::agent_state`].  Non-empty = every agent
    /// appends one durable record per completed round *before* sending
    /// that round's uplinks (compacted in place every `snapshot_every`
    /// records and on clean shutdown), so a **fresh agent process**
    /// pointed at the same directory resumes bit-identical to the
    /// uninterrupted run for every stateful id.  Empty (default) =
    /// agent state lives and dies with its process.  Pure durability
    /// plumbing — results are bit-identical with it on or off — so it
    /// is excluded from the fingerprint.
    pub agent_state_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            model: "cnn_small".into(),
            algorithm: "fedadam-ssm".into(),
            rounds: 30,
            devices: 8,
            local_epochs: 3,
            max_batches_per_epoch: 4,
            lr: 0.001,
            sparsity: 0.05,
            iid: true,
            dirichlet_theta: 0.1,
            train_samples: 2048,
            test_samples: 512,
            seed: 17,
            eval_every: 1,
            quant_levels: 16,
            warmup_rounds: 3,
            use_epoch_program: false,
            sparsify_backend: SparsifyBackend::Native,
            participation: 1.0,
            participation_mode: ParticipationMode::Uniform,
            duty_cycle: 0.8,
            over_select: 1.5,
            simtime: false,
            sim_bandwidth_mbps: 8.0,
            sim_samples_per_sec: 2000.0,
            sim_hetero: 4.0,
            num_workers: 1,
            agg_shards: 0,
            pipeline_depth: 0,
            journal: String::new(),
            resume: String::new(),
            snapshot_every: 8,
            transport_listen: String::new(),
            transport_agents: 0,
            transport_timeout_secs: 30.0,
            residual_resident_cap: 0,
            residual_spill_dir: String::new(),
            agent_state_dir: String::new(),
        }
    }
}

impl ExperimentConfig {
    /// The paper's full-scale settings (§VII-A) — for reference / real runs.
    pub fn paper_defaults() -> Self {
        ExperimentConfig {
            devices: 20,
            local_epochs: 30,
            max_batches_per_epoch: 0,
            lr: 0.001,
            sparsity: 0.05,
            dirichlet_theta: 0.1,
            train_samples: 60_000,
            test_samples: 10_000,
            ..Default::default()
        }
    }

    /// `k = round(alpha * d)`, clamped to `[1, d]`.
    pub fn k_for(&self, dim: usize) -> usize {
        ((self.sparsity * dim as f64).round() as usize).clamp(1, dim)
    }

    /// Load from a TOML-subset file (flat keys and/or `[experiment]`).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("{}: {e}", path.as_ref().display()))?;
        let mut cfg = ExperimentConfig::default();
        for section in ["", "experiment"] {
            if let Some(table) = doc.get(section) {
                for (k, v) in table {
                    cfg.set(k, &render(v))?;
                }
            }
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override (CLI `--set key=value`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T> {
            v.parse()
                .map_err(|_| anyhow!("invalid value {v:?} for {k}"))
        }
        match key {
            "name" => self.name = value.into(),
            "model" => self.model = value.into(),
            "algorithm" => self.algorithm = value.into(),
            "rounds" => self.rounds = p(key, value)?,
            "devices" => self.devices = p(key, value)?,
            "local_epochs" => self.local_epochs = p(key, value)?,
            "max_batches_per_epoch" => self.max_batches_per_epoch = p(key, value)?,
            "lr" => self.lr = p(key, value)?,
            "sparsity" => self.sparsity = p(key, value)?,
            "iid" => self.iid = p(key, value)?,
            "dirichlet_theta" => self.dirichlet_theta = p(key, value)?,
            "train_samples" => self.train_samples = p(key, value)?,
            "test_samples" => self.test_samples = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "eval_every" => self.eval_every = p(key, value)?,
            "quant_levels" => self.quant_levels = p(key, value)?,
            "warmup_rounds" => self.warmup_rounds = p(key, value)?,
            "use_epoch_program" => self.use_epoch_program = p(key, value)?,
            "sparsify_backend" => self.sparsify_backend = SparsifyBackend::parse(value)?,
            "participation" => self.participation = p(key, value)?,
            "participation_mode" => self.participation_mode = ParticipationMode::parse(value)?,
            "duty_cycle" => self.duty_cycle = p(key, value)?,
            "over_select" => self.over_select = p(key, value)?,
            "simtime" => self.simtime = p(key, value)?,
            "sim_bandwidth_mbps" => self.sim_bandwidth_mbps = p(key, value)?,
            "sim_samples_per_sec" => self.sim_samples_per_sec = p(key, value)?,
            "sim_hetero" => self.sim_hetero = p(key, value)?,
            "num_workers" => self.num_workers = p(key, value)?,
            "agg_shards" => self.agg_shards = p(key, value)?,
            "pipeline_depth" => self.pipeline_depth = p(key, value)?,
            "journal" => self.journal = value.into(),
            "resume" => self.resume = value.into(),
            "snapshot_every" => self.snapshot_every = p(key, value)?,
            "transport_listen" => self.transport_listen = value.into(),
            "transport_agents" => self.transport_agents = p(key, value)?,
            "transport_timeout_secs" => self.transport_timeout_secs = p(key, value)?,
            "residual_resident_cap" => self.residual_resident_cap = p(key, value)?,
            "residual_spill_dir" => self.residual_spill_dir = value.into(),
            "agent_state_dir" => self.agent_state_dir = value.into(),
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    /// Sanity checks before a run.
    pub fn validate(&self) -> Result<()> {
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if self.devices == 0 {
            bail!("devices must be > 0");
        }
        if self.local_epochs == 0 {
            bail!("local_epochs must be > 0");
        }
        if !(0.0 < self.sparsity && self.sparsity <= 1.0) {
            bail!("sparsity must be in (0, 1], got {}", self.sparsity);
        }
        if self.lr <= 0.0 {
            bail!("lr must be > 0");
        }
        // Quantized algorithms get the check with the id in the message
        // (an `s < 2` quantizer has no representable grid at all); the
        // generic bound below still guards configs that merely carry the
        // knob for a later `--set algorithm=` switch.
        if crate::algorithms::uses_quant_levels(&self.algorithm) && self.quant_levels < 2 {
            bail!(
                "quant_levels must be >= 2 for algorithm {:?} (s-level quantizer), got {}",
                self.algorithm,
                self.quant_levels
            );
        }
        if self.quant_levels < 2 {
            bail!("quant_levels must be >= 2");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be > 0");
        }
        if !(0.0 < self.participation && self.participation <= 1.0) {
            bail!("participation must be in (0, 1], got {}", self.participation);
        }
        if !(0.0 < self.duty_cycle && self.duty_cycle <= 1.0) {
            bail!("duty_cycle must be in (0, 1], got {}", self.duty_cycle);
        }
        if !(1.0 <= self.over_select && self.over_select.is_finite()) {
            bail!("over_select must be >= 1.0, got {}", self.over_select);
        }
        if !(0.0 < self.sim_bandwidth_mbps && self.sim_bandwidth_mbps.is_finite()) {
            bail!("sim_bandwidth_mbps must be > 0, got {}", self.sim_bandwidth_mbps);
        }
        if !(0.0 < self.sim_samples_per_sec && self.sim_samples_per_sec.is_finite()) {
            bail!("sim_samples_per_sec must be > 0, got {}", self.sim_samples_per_sec);
        }
        if !(1.0 <= self.sim_hetero && self.sim_hetero.is_finite()) {
            bail!("sim_hetero must be >= 1.0, got {}", self.sim_hetero);
        }
        if self.snapshot_every == 0 {
            bail!("snapshot_every must be >= 1 (0 would journal without ever snapshotting)");
        }
        if !self.transport_listen.is_empty() {
            if self.transport_agents == 0 {
                bail!("transport_agents must be >= 1 when transport_listen is set");
            }
            if !(self.transport_timeout_secs > 0.0 && self.transport_timeout_secs.is_finite()) {
                bail!(
                    "transport_timeout_secs must be > 0, got {}",
                    self.transport_timeout_secs
                );
            }
            // The journal's replay oracle assumes the round loop owns
            // training in-process; crash-safe journaling of a distributed
            // round is a different (two-phase) protocol.
            if !self.journal.is_empty() || !self.resume.is_empty() {
                bail!("transport_listen cannot be combined with journal/resume");
            }
        }
        if self.residual_resident_cap > 0 && self.residual_spill_dir.is_empty() {
            bail!(
                "residual_resident_cap = {} needs somewhere to spill evicted entries: \
                 set residual_spill_dir to a writable directory (or 0 to keep all \
                 residuals in RAM)",
                self.residual_resident_cap
            );
        }
        if !self.resume.is_empty() {
            // The knob must point at a journal written by an equivalent
            // config; `verify_resumable` checks existence, format version
            // and the determinism fingerprint.
            crate::coordinator::journal::verify_resumable(
                Path::new(&self.resume),
                self.fingerprint(),
            )
            .with_context(|| format!("resume = {:?} is not a resumable journal", self.resume))?;
        }
        Ok(())
    }

    /// FNV-1a hash over every determinism-bearing knob — the journal
    /// header records it so `resume` can reject a foreign journal.
    ///
    /// Included: everything that steers the data, training, cohorts,
    /// wire pricing, the eval cadence or the event-stream shape
    /// (`pipeline_depth` changes which eval events fire and the
    /// overlapped sim-clock schedule, so it is determinism-bearing here).
    /// Excluded: pure perf knobs (`num_workers`, `agg_shards`) — the
    /// determinism contract makes resuming under a different worker or
    /// shard count bit-neutral — the journal plumbing itself
    /// (`name`, `journal`, `resume`, `snapshot_every`), and the transport
    /// topology (`transport_listen`, `transport_agents`,
    /// `transport_timeout_secs`): a remote run is bit-identical to the
    /// in-process run, and the device agents' Hello handshake compares
    /// this fingerprint against the server's, which must not depend on
    /// which side of the socket a process sits.  Also excluded, for the
    /// same bit-neutrality reason: the residual store's placement knobs
    /// (`residual_resident_cap`, `residual_spill_dir`) and the agent
    /// durability directory (`agent_state_dir`) — an agent resumed from
    /// its state log replays exactly the run it would have produced
    /// uninterrupted, and the state log's own header records this
    /// fingerprint to reject a foreign directory.
    pub fn fingerprint(&self) -> u64 {
        let canon = format!(
            "{}|{}|{}|{}|{}|{}|{:016x}|{:016x}|{}|{:016x}|{}|{}|{}|{}|{}|{}|{}|{:?}|{:016x}|{}|{:016x}|{:016x}|{}|{:016x}|{:016x}|{:016x}|{}",
            self.model,
            self.algorithm,
            self.rounds,
            self.devices,
            self.local_epochs,
            self.max_batches_per_epoch,
            self.lr.to_bits(),
            self.sparsity.to_bits(),
            self.iid,
            self.dirichlet_theta.to_bits(),
            self.train_samples,
            self.test_samples,
            self.seed,
            self.eval_every,
            self.quant_levels,
            self.warmup_rounds,
            self.use_epoch_program,
            self.sparsify_backend,
            self.participation.to_bits(),
            self.participation_mode.as_str(),
            self.duty_cycle.to_bits(),
            self.over_select.to_bits(),
            self.simtime,
            self.sim_bandwidth_mbps.to_bits(),
            self.sim_samples_per_sec.to_bits(),
            self.sim_hetero.to_bits(),
            self.pipeline_depth,
        );
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        for b in canon.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }

    /// Apply the CI determinism-matrix environment overrides:
    /// `FEDADAM_NUM_WORKERS`, `FEDADAM_AGG_SHARDS`,
    /// `FEDADAM_PIPELINE_DEPTH` and `FEDADAM_PARTICIPATION_MODE` (when
    /// set) override `num_workers` / `agg_shards` / `pipeline_depth` /
    /// `participation_mode`.  Test base configs call this so one test
    /// binary can be swept across the worker × shard × pipeline ×
    /// participation-mode grid without recompiling.  (Tests whose
    /// expectations depend on the cohort covering every device — e.g.
    /// ledger totals of `devices × formula` — pin
    /// `participation_mode = Uniform` explicitly after this call, the
    /// same way every test pins `algorithm`.)
    ///
    /// (The per-algorithm CI lane's `FEDADAM_ALGORITHM` is deliberately
    /// NOT handled here: algorithm ids carry per-test expectations — cost
    /// formulas, momentum policies — so the conformance suite reads that
    /// variable itself when choosing which ids to sweep, and every test
    /// keeps pinning `algorithm` explicitly after this call.)
    ///
    /// Panics on a present-but-unparseable value: a typo'd matrix entry
    /// must fail the lane loudly, not silently test the defaults.
    pub fn apply_env_overrides(&mut self) {
        fn env_usize(key: &str) -> Option<usize> {
            let v = std::env::var(key).ok()?;
            match v.parse() {
                Ok(n) => Some(n),
                Err(_) => panic!("{key}={v:?} is not a valid usize"),
            }
        }
        if let Some(n) = env_usize("FEDADAM_NUM_WORKERS") {
            self.num_workers = n;
        }
        if let Some(n) = env_usize("FEDADAM_AGG_SHARDS") {
            self.agg_shards = n;
        }
        if let Some(n) = env_usize("FEDADAM_PIPELINE_DEPTH") {
            self.pipeline_depth = n;
        }
        if let Ok(v) = std::env::var("FEDADAM_PARTICIPATION_MODE") {
            self.participation_mode = ParticipationMode::parse(&v)
                .unwrap_or_else(|e| panic!("FEDADAM_PARTICIPATION_MODE: {e}"));
        }
        if let Some(n) = env_usize("FEDADAM_SNAPSHOT_EVERY") {
            self.snapshot_every = n;
        }
        if let Ok(v) = std::env::var("FEDADAM_RESUME") {
            // A present-but-empty value is a typo'd lane (an empty string
            // would silently mean "fresh run") — fail it loudly, matching
            // the override contract.
            if v.is_empty() {
                panic!("FEDADAM_RESUME is set but empty; point it at a journal directory");
            }
            self.resume = v;
        }
    }
}

fn render(v: &TomlValue) -> String {
    match v {
        TomlValue::Str(s) => s.clone(),
        TomlValue::Int(i) => i.to_string(),
        TomlValue::Float(f) => f.to_string(),
        TomlValue::Bool(b) => b.to_string(),
        TomlValue::Arr(_) => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
        ExperimentConfig::paper_defaults().validate().unwrap();
    }

    #[test]
    fn set_overrides() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("algorithm", "fedadam-top").unwrap();
        cfg.set("lr", "0.01").unwrap();
        cfg.set("iid", "false").unwrap();
        cfg.set("sparsify_backend", "xla").unwrap();
        cfg.set("num_workers", "4").unwrap();
        cfg.set("agg_shards", "8").unwrap();
        cfg.set("pipeline_depth", "2").unwrap();
        assert_eq!(cfg.algorithm, "fedadam-top");
        assert_eq!(cfg.lr, 0.01);
        assert!(!cfg.iid);
        assert_eq!(cfg.sparsify_backend, SparsifyBackend::Xla);
        assert_eq!(cfg.num_workers, 4);
        assert_eq!(cfg.agg_shards, 8);
        assert_eq!(cfg.pipeline_depth, 2);
        assert!(cfg.set("num_workers", "many").is_err());
        assert!(cfg.set("agg_shards", "many").is_err());
        assert!(cfg.set("pipeline_depth", "many").is_err());
        assert!(cfg.set("nope", "1").is_err());
        assert!(cfg.set("lr", "abc").is_err());
    }

    #[test]
    fn participation_and_simtime_knobs_ride_through_set() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.participation_mode, ParticipationMode::Uniform);
        cfg.set("participation_mode", "importance").unwrap();
        cfg.set("duty_cycle", "0.6").unwrap();
        cfg.set("over_select", "2.0").unwrap();
        cfg.set("simtime", "true").unwrap();
        cfg.set("sim_bandwidth_mbps", "0.5").unwrap();
        cfg.set("sim_samples_per_sec", "1500").unwrap();
        cfg.set("sim_hetero", "2.5").unwrap();
        assert_eq!(cfg.participation_mode, ParticipationMode::Importance);
        assert_eq!(cfg.duty_cycle, 0.6);
        assert_eq!(cfg.over_select, 2.0);
        assert!(cfg.simtime);
        assert_eq!(cfg.sim_bandwidth_mbps, 0.5);
        assert_eq!(cfg.sim_samples_per_sec, 1500.0);
        assert_eq!(cfg.sim_hetero, 2.5);
        cfg.validate().unwrap();
        cfg.set("participation_mode", "availability").unwrap();
        assert_eq!(cfg.participation_mode, ParticipationMode::Availability);
        assert_eq!(ParticipationMode::Availability.as_str(), "availability");
        assert!(cfg.set("participation_mode", "round-robin").is_err());
    }

    #[test]
    fn invalid_sampler_and_simtime_configs_rejected() {
        let bad = [
            ("duty_cycle", "0.0"),
            ("duty_cycle", "1.5"),
            ("over_select", "0.9"),
            ("sim_bandwidth_mbps", "0"),
            ("sim_samples_per_sec", "-1"),
            ("sim_hetero", "0.5"),
        ];
        for (key, value) in bad {
            let mut cfg = ExperimentConfig::default();
            cfg.set(key, value).unwrap();
            assert!(cfg.validate().is_err(), "{key}={value} must be rejected");
        }
    }

    #[test]
    fn k_clamps() {
        let mut cfg = ExperimentConfig::default();
        cfg.sparsity = 0.05;
        assert_eq!(cfg.k_for(1000), 50);
        cfg.sparsity = 1e-9;
        assert_eq!(cfg.k_for(1000), 1);
        cfg.sparsity = 1.0;
        assert_eq!(cfg.k_for(1000), 1000);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = ExperimentConfig::default();
        cfg.sparsity = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.rounds = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.quant_levels = 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn quantized_algorithms_reject_bad_levels_by_name() {
        // Every quantized id must fail s < 2 with an error naming the id,
        // not the generic bound — the fix the regression in efficient-adam
        // -only checking used to hide.
        for id in ["efficient-adam", "fedadam-ssm-q", "fedadam-ssm-qef"] {
            let mut cfg = ExperimentConfig::default();
            cfg.algorithm = id.into();
            cfg.quant_levels = 1;
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(id), "error {err:?} must name {id}");
            cfg.quant_levels = 2;
            cfg.validate().unwrap();
        }
        // Non-quantized ids still hit the generic bound (no id named).
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.quant_levels = 0;
        let err = cfg.validate().unwrap_err().to_string();
        assert!(!err.contains("fedadam-ssm"), "generic bound names no id: {err:?}");
    }

    #[test]
    fn journal_knobs_ride_through_set_and_validate() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.snapshot_every, 8);
        cfg.set("journal", "/tmp/j").unwrap();
        cfg.set("snapshot_every", "3").unwrap();
        assert_eq!(cfg.journal, "/tmp/j");
        assert_eq!(cfg.snapshot_every, 3);
        assert!(cfg.set("snapshot_every", "often").is_err());
        cfg.validate().unwrap();
        cfg.set("snapshot_every", "0").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("snapshot_every"), "error must name the knob: {err}");
    }

    #[test]
    fn resume_must_point_at_a_real_compatible_journal() {
        // Missing directory: rejected, error names the knob.
        let mut cfg = ExperimentConfig::default();
        cfg.resume = "/nonexistent/journal-dir".into();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("resume"), "error must name the knob: {err}");

        // Foreign journal (different fingerprint): rejected by name too.
        let dir = std::env::temp_dir().join(format!("fedadam-cfg-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let other = {
            let mut c = ExperimentConfig::default();
            c.seed = 12345; // determinism-bearing difference
            c.fingerprint()
        };
        crate::coordinator::journal::Journal::create(&dir, other).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.resume = dir.to_string_lossy().into_owned();
        let err = format!("{:#}", cfg.validate().unwrap_err());
        assert!(err.contains("resume"), "error must name the knob: {err}");
        assert!(err.contains("foreign"), "{err}");

        // Matching journal: accepted.
        crate::coordinator::journal::Journal::create(&dir, cfg.fingerprint()).unwrap();
        cfg.validate().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_tracks_determinism_bearing_knobs_only() {
        let base = ExperimentConfig::default().fingerprint();
        // Perf + plumbing knobs must NOT move the fingerprint.
        let mut cfg = ExperimentConfig::default();
        cfg.num_workers = 8;
        cfg.agg_shards = 4;
        cfg.name = "other-name".into();
        cfg.journal = "/tmp/j".into();
        cfg.snapshot_every = 2;
        cfg.transport_listen = "127.0.0.1:0".into();
        cfg.transport_agents = 2;
        cfg.transport_timeout_secs = 5.0;
        cfg.residual_resident_cap = 4; // memory placement, not semantics
        cfg.residual_spill_dir = "/tmp/r".into();
        cfg.agent_state_dir = "/tmp/agent-state".into(); // durability, not semantics
        assert_eq!(cfg.fingerprint(), base);
        // Determinism-bearing knobs must.
        for (key, value) in [
            ("seed", "99"),
            ("rounds", "7"),
            ("algorithm", "fedadam-ssm-qef"),
            ("participation_mode", "importance"),
            ("pipeline_depth", "2"),
            ("simtime", "true"),
        ] {
            let mut cfg = ExperimentConfig::default();
            cfg.set(key, value).unwrap();
            assert_ne!(cfg.fingerprint(), base, "{key}={value} must move the fingerprint");
        }
    }

    #[test]
    fn residual_knobs_ride_through_set_and_validate() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.residual_resident_cap, 0);
        assert!(cfg.residual_spill_dir.is_empty());
        cfg.set("residual_resident_cap", "64").unwrap();
        cfg.set("residual_spill_dir", "/tmp/spill").unwrap();
        assert_eq!(cfg.residual_resident_cap, 64);
        assert_eq!(cfg.residual_spill_dir, "/tmp/spill");
        cfg.validate().unwrap();
        assert!(cfg.set("residual_resident_cap", "many").is_err());

        // A cap with nowhere to spill is rejected, naming the knob.
        let mut cfg = ExperimentConfig::default();
        cfg.set("residual_resident_cap", "8").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("residual_spill_dir"), "error must name the knob: {err}");
    }

    #[test]
    fn agent_state_dir_rides_through_set_and_validate() {
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.agent_state_dir.is_empty());
        cfg.set("agent_state_dir", "/tmp/agent-state").unwrap();
        assert_eq!(cfg.agent_state_dir, "/tmp/agent-state");
        cfg.validate().unwrap();
        // Composes with the transport knobs (its whole point).
        cfg.set("transport_listen", "127.0.0.1:0").unwrap();
        cfg.set("transport_agents", "2").unwrap();
        cfg.validate().unwrap();
    }

    #[test]
    fn transport_knobs_ride_through_set_and_validate() {
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.transport_listen.is_empty());
        cfg.set("transport_listen", "127.0.0.1:7000").unwrap();
        cfg.set("transport_agents", "3").unwrap();
        cfg.set("transport_timeout_secs", "2.5").unwrap();
        assert_eq!(cfg.transport_listen, "127.0.0.1:7000");
        assert_eq!(cfg.transport_agents, 3);
        assert_eq!(cfg.transport_timeout_secs, 2.5);
        cfg.validate().unwrap();
        assert!(cfg.set("transport_agents", "several").is_err());

        // Listening with zero agents is a stall, not a run.
        let mut cfg = ExperimentConfig::default();
        cfg.set("transport_listen", "unix:/tmp/fedadam.sock").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("transport_agents"), "{err}");

        // Non-positive timeout rejected by name.
        cfg.set("transport_agents", "1").unwrap();
        cfg.set("transport_timeout_secs", "0").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("transport_timeout_secs"), "{err}");

        // Transport excludes the journal/resume machinery.
        cfg.set("transport_timeout_secs", "30").unwrap();
        cfg.set("journal", "/tmp/j").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("journal"), "{err}");
    }

    #[test]
    fn typoed_journal_env_overrides_panic() {
        // Serialized against other env tests by unique var usage; the
        // suite never sets these two vars elsewhere.
        std::env::set_var("FEDADAM_SNAPSHOT_EVERY", "often");
        let result = std::panic::catch_unwind(|| {
            let mut cfg = ExperimentConfig::default();
            cfg.apply_env_overrides();
        });
        std::env::remove_var("FEDADAM_SNAPSHOT_EVERY");
        assert!(result.is_err(), "typo'd FEDADAM_SNAPSHOT_EVERY must panic");

        std::env::set_var("FEDADAM_RESUME", "");
        let result = std::panic::catch_unwind(|| {
            let mut cfg = ExperimentConfig::default();
            cfg.apply_env_overrides();
        });
        std::env::remove_var("FEDADAM_RESUME");
        assert!(result.is_err(), "empty FEDADAM_RESUME must panic");

        std::env::set_var("FEDADAM_SNAPSHOT_EVERY", "5");
        std::env::set_var("FEDADAM_RESUME", "/tmp/some-journal");
        let mut cfg = ExperimentConfig::default();
        cfg.apply_env_overrides();
        std::env::remove_var("FEDADAM_SNAPSHOT_EVERY");
        std::env::remove_var("FEDADAM_RESUME");
        assert_eq!(cfg.snapshot_every, 5);
        assert_eq!(cfg.resume, "/tmp/some-journal");
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fedadam-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.toml");
        std::fs::write(
            &p,
            "name = \"t\"\nrounds = 5\n[experiment]\nlr = 0.01\niid = false\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_file(&p).unwrap();
        assert_eq!(cfg.name, "t");
        assert_eq!(cfg.rounds, 5);
        assert_eq!(cfg.lr, 0.01);
        assert!(!cfg.iid);
        std::fs::remove_dir_all(&dir).ok();
    }
}
