//! Ablation: error-feedback memory on top of the SSM (DESIGN.md ablation
//! list) and partial device participation.
//!
//! Compares `fedadam-ssm` vs `fedadam-ssm-ef` — and the quantized pair
//! `fedadam-ssm-q` vs `fedadam-ssm-qef`, where the EF memory additionally
//! absorbs the s-level rounding error — at aggressive sparsity (where
//! dropped-mass accumulation matters most), and full vs partial
//! participation — design axes the paper leaves open.
//!
//! ```text
//! cargo run --release --example ablation_ef -- [--quick]
//! ```

use anyhow::Result;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    let quick = cli.flag("quick");

    let mut base = ExperimentConfig::default();
    base.model = cli.opt_or("model", "cnn_small").to_string();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 5 } else { 15 });
    base.devices = if quick { 3 } else { 6 };
    base.train_samples = if quick { 512 } else { 2048 };
    base.test_samples = if quick { 128 } else { 512 };
    base.local_epochs = 2;
    base.iid = false;

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("case,alpha,participation,best_acc,final_loss\n");
    println!(
        "{:<18} {:>7} {:>14} {:>10} {:>12}",
        "algorithm", "alpha", "participation", "best acc", "final loss"
    );
    // EF ablation across sparsity levels, for both the f32 and the
    // s-level-quantized (s = 4) SSM wire formats.
    for &alpha in if quick { &[0.01f64][..] } else { &[0.005f64, 0.01, 0.05][..] } {
        for algo in ["fedadam-ssm", "fedadam-ssm-ef", "fedadam-ssm-q", "fedadam-ssm-qef"] {
            let mut cfg = base.clone();
            cfg.algorithm = algo.into();
            cfg.quant_levels = 4;
            cfg.sparsity = alpha;
            cfg.name = format!("ablation_{algo}_a{alpha}");
            let mut coord = Coordinator::new(cfg, artifacts)?;
            let log = coord.run()?;
            let fl = log.rounds.last().unwrap().train_loss;
            println!(
                "{:<18} {:>7} {:>14} {:>10.3} {:>12.4}",
                algo, alpha, 1.0, log.best_accuracy(), fl
            );
            csv.push_str(&format!("{algo},{alpha},1.0,{:.4},{fl:.4}\n", log.best_accuracy()));
        }
    }
    // Participation ablation at the default alpha.
    for &part in if quick { &[0.5f64][..] } else { &[1.0f64, 0.5, 0.25][..] } {
        let mut cfg = base.clone();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.participation = part;
        cfg.name = format!("ablation_part{part}");
        let mut coord = Coordinator::new(cfg, artifacts)?;
        let log = coord.run()?;
        let fl = log.rounds.last().unwrap().train_loss;
        println!(
            "{:<18} {:>7} {:>14} {:>10.3} {:>12.4}",
            "fedadam-ssm", cfg_alpha(&log), part, log.best_accuracy(), fl
        );
        csv.push_str(&format!(
            "fedadam-ssm,0.05,{part},{:.4},{fl:.4}\n",
            log.best_accuracy()
        ));
    }
    std::fs::write("results/ablation_ef.csv", csv)?;
    println!("\nwrote results/ablation_ef.csv");
    Ok(())
}

fn cfg_alpha(_log: &fedadam_ssm::metrics::ExperimentLog) -> f64 {
    0.05
}
