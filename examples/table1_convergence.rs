//! Table I reproduction: minimum cumulative uplink (Mbit) to reach a target
//! test accuracy, per algorithm, IID and non-IID — plus the speedup ratios
//! the paper reports relative to FedAdam-SSM.
//!
//! `∞` appears exactly as in the paper when an algorithm never reaches the
//! target within the round budget (expected for the quantized baselines
//! and the weaker SSM variants).
//!
//! ```text
//! cargo run --release --example table1_convergence -- \
//!     [--model cnn_small] [--rounds 30] [--target 0.7] [--quick]
//! ```

use anyhow::Result;
use fedadam_ssm::algorithms::ALL_ALGORITHMS;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    let quick = cli.flag("quick");

    let mut base = ExperimentConfig::default();
    base.model = cli.opt_or("model", "cnn_small").to_string();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 8 } else { 30 });
    base.devices = cli.opt_parse("devices")?.unwrap_or(if quick { 3 } else { 8 });
    base.local_epochs = 2;
    base.train_samples = if quick { 512 } else { 2048 };
    base.test_samples = if quick { 128 } else { 512 };
    base.sparsity = 0.05;

    // Auto-target: fraction of the accuracy FedAdam-SSM itself reaches —
    // mirrors the paper's per-model target choice.
    let target_opt: Option<f64> = cli.opt_parse("target")?;

    std::fs::create_dir_all("results")?;
    let mut rows = String::from("setting,algorithm,target_acc,comm_mbit,ratio_vs_ssm\n");
    for &iid in &[true, false] {
        let setting = if iid { "IID" } else { "Non-IID" };
        let mut logs = Vec::new();
        for algo in ALL_ALGORITHMS {
            let mut cfg = base.clone();
            cfg.algorithm = algo.into();
            cfg.iid = iid;
            cfg.name = format!("table1_{setting}_{algo}");
            let mut coord = Coordinator::new(cfg, artifacts)?;
            logs.push(coord.run()?);
        }
        // target = 90% of SSM's best accuracy unless given.
        let ssm_best = logs
            .iter()
            .find(|l| l.algorithm == "fedadam-ssm")
            .unwrap()
            .best_accuracy();
        let target = target_opt.unwrap_or(ssm_best * 0.9);
        let ssm_comm = logs
            .iter()
            .find(|l| l.algorithm == "fedadam-ssm")
            .unwrap()
            .comm_to_accuracy(target);

        println!("\n=== Table I ({setting}) — target accuracy {target:.3} ===");
        println!("{:<18} {:>14} {:>12}", "algorithm", "Comm. (Mbit)", "ratio");
        for l in &logs {
            let comm = l.comm_to_accuracy(target);
            let (comm_s, ratio_s) = match (comm, ssm_comm) {
                (Some(c), Some(s)) => (format!("{c:.2}"), format!("{:.2}x", c / s)),
                (Some(c), None) => (format!("{c:.2}"), "-".into()),
                (None, _) => ("inf".into(), "inf".into()),
            };
            println!("{:<18} {:>14} {:>12}", l.algorithm, comm_s, ratio_s);
            rows.push_str(&format!(
                "{},{},{:.4},{},{}\n",
                setting,
                l.algorithm,
                target,
                comm.map(|c| format!("{c:.3}")).unwrap_or("inf".into()),
                match (comm, ssm_comm) {
                    (Some(c), Some(s)) => format!("{:.3}", c / s),
                    _ => "inf".into(),
                }
            ));
        }
    }
    std::fs::write("results/table1.csv", rows)?;
    println!("\nwrote results/table1.csv");
    Ok(())
}
