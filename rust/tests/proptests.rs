//! Property-based tests over the coordinator substrates (hand-rolled
//! generator loops — the offline build has no proptest crate; seeds are
//! fixed so failures reproduce exactly).

use fedadam_ssm::algorithms::wire::WireBody;
use fedadam_ssm::algorithms::{self, Aggregate, LocalDelta, Recon, Upload};
use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
use fedadam_ssm::coordinator::journal::{self, read_log, Event, Journal, JOURNAL_VERSION};
use fedadam_ssm::coordinator::sampler::{self, AvailabilitySampler, ParticipationSampler};
use fedadam_ssm::coordinator::{aggregate, aggregate_sharded, GlobalState, ShardedAccumulator};
use fedadam_ssm::quant::sparse_uniform::{
    reconstruct, sparse_uniform_compress, sparse_uniform_decompress, ssm_q_decode, ssm_q_encode,
    ssm_q_encode_fused,
};
use fedadam_ssm::quant::{onebit_compress, onebit_decompress, uniform_compress, uniform_decompress, ErrorFeedback};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::codec::{self, cost, index_bits};
use fedadam_ssm::sparse::{top_k_indices, top_k_threshold, SparseVec};
use fedadam_ssm::tensor;
use fedadam_ssm::transport::frame::{read_frame, write_frame, FrameBuffer, FRAME_HEADER_LEN};
use fedadam_ssm::transport::msg::{Assignment, Msg, Uplink};
use fedadam_ssm::util::bytes::{ByteReader, ByteWriter};

/// Random vector with occasional exact duplicates and zeros (tie stress).
fn gen_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    for _ in 0..d / 10 {
        let i = rng.below(d);
        let j = rng.below(d);
        v[i] = v[j]; // duplicate magnitude
    }
    for _ in 0..d / 20 {
        let i = rng.below(d);
        v[i] = 0.0;
    }
    v
}

#[test]
fn prop_topk_is_k_contraction() {
    // Definition 2: E||x - Top_k(x)||^2 <= (1 - k/d) ||x||^2 — for top-k it
    // holds deterministically, per input.
    let mut rng = Rng::new(101);
    for trial in 0..200 {
        let d = 2 + rng.below(400);
        let k = 1 + rng.below(d);
        let x = gen_vec(&mut rng, d);
        let idx = top_k_indices(&x, k);
        let kept = SparseVec::gather(&x, &idx).to_dense();
        let resid = tensor::sub(&x, &kept);
        let lhs = tensor::l2_norm_sq(&resid);
        let rhs = (1.0 - k as f64 / d as f64) * tensor::l2_norm_sq(&x);
        assert!(
            lhs <= rhs + 1e-6,
            "trial {trial}: d={d} k={k}: ||x-Top_k(x)||^2 = {lhs} > {rhs}"
        );
    }
}

#[test]
fn prop_topk_keeps_largest() {
    // Every kept magnitude >= every dropped magnitude.
    let mut rng = Rng::new(102);
    for _ in 0..100 {
        let d = 2 + rng.below(300);
        let k = 1 + rng.below(d);
        let x = gen_vec(&mut rng, d);
        let idx = top_k_indices(&x, k);
        assert_eq!(idx.len(), k);
        let mut kept = vec![false; d];
        for &i in &idx {
            kept[i as usize] = true;
        }
        let min_kept = idx
            .iter()
            .map(|&i| x[i as usize].abs())
            .fold(f32::INFINITY, f32::min);
        for i in 0..d {
            if !kept[i] {
                assert!(
                    x[i].abs() <= min_kept,
                    "dropped |x[{i}]|={} > min kept {min_kept}",
                    x[i].abs()
                );
            }
        }
        // Threshold consistency.
        assert_eq!(top_k_threshold(&x, k), min_kept);
    }
}

#[test]
fn prop_codec_roundtrip_any_k() {
    let mut rng = Rng::new(103);
    for _ in 0..100 {
        let d = 1 + rng.below(3000);
        let k = rng.below(d + 1);
        let x = gen_vec(&mut rng, d.max(1));
        let idx = top_k_indices(&x, k);
        let sv = SparseVec::gather(&x, &idx);
        let back = codec::decode(&codec::encode(&sv));
        assert_eq!(back, sv, "d={d} k={k}");
    }
}

#[test]
fn prop_cost_model_ordering() {
    // SSM <= Top <= Dense for every (d, k), with equality only at edges.
    let mut rng = Rng::new(104);
    for _ in 0..300 {
        let d = 2 + rng.below(2_000_000);
        let k = 1 + rng.below(d);
        let ssm = cost::fedadam_ssm(d, k);
        let top = cost::fedadam_top(d, k);
        let dense = cost::fedadam_dense(d);
        assert!(ssm <= top, "d={d} k={k}: ssm {ssm} > top {top}");
        assert!(top <= dense + 3 * d as u64, "d={d} k={k}");
        if (k as f64) < d as f64 * 0.3 {
            assert!(top < dense, "d={d} k={k}: top not cheaper than dense");
        }
    }
}

#[test]
fn prop_onebit_roundtrip_preserves_signs_and_scale() {
    let mut rng = Rng::new(105);
    for _ in 0..50 {
        let d = 1 + rng.below(5000);
        let x = gen_vec(&mut rng, d);
        let mut ef = ErrorFeedback::new(d);
        let p = onebit_compress(&x, &mut ef);
        let y = onebit_decompress(&p);
        assert_eq!(y.len(), d);
        // |y_i| == scale everywhere; EF residual = x - y exactly (first round).
        for i in 0..d {
            assert_eq!(y[i].abs(), p.scale);
            assert!((ef.residual[i] - (x[i] - y[i])).abs() < 1e-6);
        }
        // Mean magnitude preserved by construction.
        let mean_abs = x.iter().map(|v| v.abs() as f64).sum::<f64>() / d as f64;
        assert!((p.scale as f64 - mean_abs).abs() < 1e-4 * mean_abs.max(1.0));
    }
}

#[test]
fn prop_uniform_quant_error_within_half_bin() {
    let mut rng = Rng::new(106);
    for _ in 0..50 {
        let d = 1 + rng.below(4000);
        let x = gen_vec(&mut rng, d);
        let s = 2 + rng.below(255) as u32;
        let p = uniform_compress(&x, s);
        let y = uniform_decompress(&p);
        let bin = if p.scale > 0.0 {
            2.0 * p.scale / (s - 1) as f32
        } else {
            0.0
        };
        for (xi, yi) in x.iter().zip(&y) {
            assert!(
                (xi - yi).abs() <= bin / 2.0 + 1e-5,
                "s={s} err {} bin {bin}",
                (xi - yi).abs()
            );
        }
    }
}

#[test]
fn prop_sparse_uniform_roundtrip_error_within_half_bin() {
    // Quantized-SSM value lists: for every kept lane,
    // |x - dequant(quant(x))| <= bin/2 where bin = 2·scale/(s-1) — same
    // bound as the dense quantizer, restricted to the mask.
    let mut rng = Rng::new(111);
    for _ in 0..60 {
        let d = 1 + rng.below(2000);
        let k = 1 + rng.below(d);
        let x = gen_vec(&mut rng, d);
        let idx = top_k_indices(&x, k);
        let vals: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
        let s = 2 + rng.below(255) as u32;
        let p = sparse_uniform_compress(&vals, s);
        let y = sparse_uniform_decompress(&p);
        assert_eq!(y.len(), k);
        let bin = if p.scale > 0.0 {
            2.0 * p.scale / (s - 1) as f32
        } else {
            0.0
        };
        for (vi, yi) in vals.iter().zip(&y) {
            assert!(
                (vi - yi).abs() <= bin / 2.0 + 1e-5,
                "d={d} k={k} s={s}: err {} > half-bin {}",
                (vi - yi).abs(),
                bin / 2.0
            );
        }
    }
}

#[test]
fn prop_sparse_uniform_exact_zero_lanes_keep_indices() {
    // A kept lane whose value is exactly 0.0 must survive
    // quantize -> dequantize with its index intact: the reconstructed
    // SparseVec's support is the mask, never a non-zero recount.  When ALL
    // kept lanes are zero (scale 0) the values come back exactly 0.0 too.
    let mut rng = Rng::new(112);
    for trial in 0..60 {
        let d = 2 + rng.below(1000);
        let k = 1 + rng.below(d);
        let scores = gen_vec(&mut rng, d);
        let idx = top_k_indices(&scores, k);
        let all_zero = trial % 3 == 0;
        let vals: Vec<f32> = idx
            .iter()
            .map(|_| {
                if all_zero || rng.below(3) == 0 {
                    0.0
                } else {
                    rng.normal() as f32
                }
            })
            .collect();
        let s = 2 + rng.below(30) as u32;
        let p = sparse_uniform_compress(&vals, s);
        let sv = reconstruct(d, &idx, &p);
        assert_eq!(sv.indices, idx, "trial {trial}: support lost indices");
        assert_eq!(sv.nnz(), k, "trial {trial}: support shrank below priced k");
        if all_zero {
            assert_eq!(p.scale, 0.0);
            assert_eq!(sv.values, vec![0.0; k], "trial {trial}: zeros not exact");
        }
    }
}

#[test]
fn prop_ssm_q_packed_bits_equal_priced_ledger_formula() {
    // The encoded message's exact bit-length — coded mask + three packed
    // k·ceil(log2 s) payloads + three f32 scales — must equal
    // cost::fedadam_ssm_q(d, k, s) for random (d, k, s), and the packed
    // byte buffers must carry no more than one byte of slack each.
    let mut rng = Rng::new(114);
    for _ in 0..80 {
        let d = 1 + rng.below(5000);
        let k = 1 + rng.below(d);
        let s = 2 + rng.below(300) as u32;
        let x = gen_vec(&mut rng, d.max(1));
        let idx = top_k_indices(&x, k);
        let vals: Vec<f32> = idx.iter().map(|&i| x[i as usize]).collect();
        let msg = ssm_q_encode(d, &idx, &vals, &vals, &vals, s);
        assert_eq!(
            msg.wire_bits(),
            cost::fedadam_ssm_q(d, k, s as usize),
            "d={d} k={k} s={s}"
        );
        for packet in [&msg.w, &msg.m, &msg.v] {
            assert_eq!(packet.payload_bits(), k as u64 * index_bits(s as usize));
            assert_eq!(
                packet.codes.len(),
                (packet.payload_bits() as usize).div_ceil(8),
                "d={d} k={k} s={s}: packed payload has byte slack"
            );
        }
        // And the bits decode back to the exact dequantized triple.
        let (sw, sm, sv) = ssm_q_decode(&msg);
        assert_eq!(sw.indices, idx);
        assert_eq!(sw.values, sparse_uniform_decompress(&msg.w));
        assert_eq!(sm.values, sv.values, "same input values, same grid");
    }
}

#[test]
fn prop_fused_ssm_q_encode_is_byte_identical_to_staged_pipeline() {
    // PR 10 tentpole contract: the single-pass fused encoder
    // (sparsify→quantize→pack straight into the wire body) must produce
    // EXACTLY the bytes of the staged `ssm_q_encode` → `WireBody::SsmQ`
    // → `encode()` pipeline — and the same dequantized lane values — for
    // random (d, k, s) with exact-zero kept lanes, all-zero (scale-0)
    // vectors, and code widths that land on and off byte boundaries.
    let mut rng = Rng::new(5001);
    let mut cases = 0usize;
    for trial in 0..288 {
        let d = 1 + rng.below(4000);
        let k = 1 + rng.below(d);
        // Cycle forced widths (1-bit, 2-bit, 8-bit codes) with random s.
        let s = match trial % 4 {
            0 => 2u32,
            1 => 4,
            2 => 256,
            _ => 2 + rng.below(300) as u32,
        };
        let scores = gen_vec(&mut rng, d);
        let idx = top_k_indices(&scores, k);
        let all_zero = trial % 9 == 0;
        let mut gen_dense = |with_zero_lanes: bool| -> Vec<f32> {
            (0..d)
                .map(|_| {
                    if all_zero || (with_zero_lanes && rng.below(4) == 0) {
                        0.0
                    } else {
                        rng.normal() as f32
                    }
                })
                .collect()
        };
        let dw = gen_dense(true);
        let dm = gen_dense(false);
        let dv = gen_dense(true);

        let fused = ssm_q_encode_fused(d, &idx, &dw, &dm, &dv, s);
        let gather = |src: &[f32]| -> Vec<f32> { idx.iter().map(|&i| src[i as usize]).collect() };
        let staged = ssm_q_encode(d, &idx, &gather(&dw), &gather(&dm), &gather(&dv), s);
        assert_eq!(fused.bits, staged.wire_bits(), "trial {trial}: d={d} k={k} s={s}");
        let (sw, sm, sv) = ssm_q_decode(&staged);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&fused.w), bits(&sw.values), "trial {trial}: w recon");
        assert_eq!(bits(&fused.m), bits(&sm.values), "trial {trial}: m recon");
        assert_eq!(bits(&fused.v), bits(&sv.values), "trial {trial}: v recon");
        assert_eq!(
            fused.bytes,
            WireBody::SsmQ(staged).encode(),
            "trial {trial}: d={d} k={k} s={s}: fused bytes diverge from staged pack"
        );
        cases += 1;
    }
    assert!(cases >= 256, "property needs >= 256 cases, ran {cases}");
}

#[test]
fn prop_fused_shared_mask_wire_is_byte_identical_to_staged() {
    // The f32 SSM codec's fused path: `compress_wire` on fedadam-ssm
    // writes the SharedMask body in one pass (word-at-a-time bitmap +
    // verbatim f32 bits); it must match a hand-staged SharedMask encode
    // bit for bit, and price the same ledger bits.
    let mut rng = Rng::new(5002);
    for trial in 0..100 {
        let d = 2 + rng.below(2000);
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.devices = 1;
        cfg.sparsity = 0.01 + 0.6 * rng.uniform();
        let mut a = algorithms::build(&cfg, d).unwrap();
        let delta = LocalDelta {
            dw: gen_vec(&mut rng, d),
            dm: gen_vec(&mut rng, d),
            dv: gen_vec(&mut rng, d),
            weight: 1.0,
        };
        let wire = a.compress_wire(trial, 0, delta.clone()).unwrap();
        let k = wire.body.k();
        let idx = top_k_indices(&delta.dw, k);
        let gather = |src: &[f32]| -> Vec<f32> { idx.iter().map(|&i| src[i as usize]).collect() };
        let staged = WireBody::SharedMask {
            dim: d,
            indices: idx,
            w: gather(&delta.dw),
            m: gather(&delta.dm),
            v: gather(&delta.dv),
        };
        assert_eq!(staged.wire_bits(), wire.bits, "trial {trial}: d={d} k={k}");
        assert_eq!(
            staged.encode(),
            wire.encode_body().unwrap(),
            "trial {trial}: d={d} k={k}: fused SharedMask bytes diverge"
        );
    }
}

#[test]
fn prop_radix_topk_matches_sort_oracle_on_adversarial_inputs() {
    // PR 10: `top_k_indices` is an MSB-radix select over the monotone
    // u32 key of |x|.  Its contract is UNCHANGED from the scalar
    // quickselect: exactly the k largest by (|x| desc, index asc), output
    // ascending — checked against a brute-force total_cmp sort oracle on
    // adversarial inputs (all-equal, ±0.0, subnormals, tie-heavy small
    // alphabets, d up to 1e5), plus the k=0 ⇒ +inf threshold contract.
    let mut rng = Rng::new(5003);
    let mut cases = 0usize;
    for trial in 0..300 {
        let d = if trial % 25 == 0 {
            1 + rng.below(100_000)
        } else {
            1 + rng.below(3000)
        };
        let x: Vec<f32> = match trial % 5 {
            0 => vec![1.25f32; d], // all equal: pure index tie-break
            1 => (0..d).map(|i| if i % 2 == 0 { 0.0 } else { -0.0 }).collect(),
            2 => (0..d)
                .map(|_| match rng.below(5) {
                    0 => -0.0,
                    1 => 1.0e-42,  // subnormal
                    2 => -1.0e-45, // smallest-magnitude subnormal
                    3 => f32::MIN_POSITIVE,
                    _ => rng.normal() as f32,
                })
                .collect(),
            3 => (0..d)
                .map(|_| [0.0f32, 1.0, -1.0, 2.0][rng.below(4)])
                .collect(), // tie-heavy
            _ => gen_vec(&mut rng, d),
        };
        let k = match trial % 7 {
            0 => 0,
            1 => d,
            _ => rng.below(d + 1),
        };

        let idx = top_k_indices(&x, k);
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.sort_by(|&a, &b| {
            x[b as usize]
                .abs()
                .total_cmp(&x[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut want = order[..k].to_vec();
        want.sort_unstable();
        assert_eq!(idx, want, "trial {trial}: d={d} k={k}");

        let tau = top_k_threshold(&x, k);
        if k == 0 {
            assert_eq!(tau, f32::INFINITY, "trial {trial}: k=0 threshold");
        } else {
            let min_kept = idx
                .iter()
                .map(|&i| x[i as usize].abs())
                .fold(f32::INFINITY, f32::min);
            assert_eq!(
                tau.to_bits(),
                min_kept.to_bits(),
                "trial {trial}: d={d} k={k} threshold"
            );
        }
        cases += 1;
    }
    assert!(cases >= 256, "property needs >= 256 cases, ran {cases}");
}

#[test]
fn prop_sparse_axpy_equals_dense_axpy() {
    let mut rng = Rng::new(107);
    for _ in 0..100 {
        let d = 1 + rng.below(1000);
        let k = rng.below(d + 1);
        let x = gen_vec(&mut rng, d);
        let idx = top_k_indices(&x, k);
        let sv = SparseVec::gather(&x, &idx);
        let dense = sv.to_dense();
        let w = rng.uniform_in(-2.0, 2.0) as f32;
        let mut a = vec![1.0f32; d];
        let mut b = vec![1.0f32; d];
        sv.axpy_into(&mut a, w);
        tensor::axpy(&mut b, w, &dense);
        assert_eq!(a, b);
    }
}

/// Random sparse payload over `d` lanes with exact-zero stored values
/// mixed in (a kept lane whose value is exactly `0.0` is still support).
fn gen_sparse(rng: &mut Rng, d: usize) -> Recon {
    let k = rng.below(d + 1);
    let scores = gen_vec(rng, d);
    let indices = top_k_indices(&scores, k);
    let values: Vec<f32> = indices
        .iter()
        .map(|_| {
            if rng.below(5) == 0 {
                0.0 // exact-zero kept lane
            } else {
                rng.normal() as f32
            }
        })
        .collect();
    Recon::Sparse(SparseVec {
        dim: d,
        indices,
        values,
    })
}

fn gen_recon(rng: &mut Rng, d: usize) -> Recon {
    if rng.below(4) == 0 {
        Recon::Dense(gen_vec(rng, d))
    } else {
        gen_sparse(rng, d)
    }
}

/// Negate every stored value of a payload (builds cancelling pairs).
fn negated(r: &Recon) -> Recon {
    match r {
        Recon::Dense(v) => Recon::Dense(v.iter().map(|x| -x).collect()),
        Recon::Sparse(sv) => Recon::Sparse(SparseVec {
            dim: sv.dim,
            indices: sv.indices.clone(),
            values: sv.values.iter().map(|x| -x).collect(),
        }),
    }
}

#[test]
fn prop_sharded_aggregate_bit_identical_to_sequential() {
    // The tentpole determinism contract: `aggregate_sharded(u, d, s)` must
    // be bit-identical — values AND dw/dm/dv supports — to the 1-shard
    // reduce for any shard count, on random mixes of dense/sparse uploads
    // with exact-zero kept lanes and exactly-cancelling values.
    let mut rng = Rng::new(109);
    for trial in 0..80 {
        let d = 1 + rng.below(160);
        let n = 1 + rng.below(6);
        let mut uploads: Vec<Upload> = Vec::new();
        for _ in 0..n {
            let dw = gen_recon(&mut rng, d);
            let dm = (rng.below(2) == 0).then(|| gen_recon(&mut rng, d));
            let dv = (rng.below(2) == 0).then(|| gen_recon(&mut rng, d));
            let weight = rng.uniform() * 10.0;
            uploads.push(Upload {
                dw,
                dm,
                dv,
                weight,
                bits: 0,
            });
            // Occasionally append the exact negation at the same weight so
            // lane sums cancel to 0.0 while the wire support does not.
            if rng.below(3) == 0 {
                let last = uploads.last().unwrap();
                let twin = Upload {
                    dw: negated(&last.dw),
                    dm: last.dm.as_ref().map(negated),
                    dv: last.dv.as_ref().map(negated),
                    weight: last.weight,
                    bits: 0,
                };
                uploads.push(twin);
            }
        }

        let base = aggregate_sharded(&uploads, d, 1);
        let wrapper = aggregate(&uploads, d);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&wrapper.dw), bits(&base.dw), "trial {trial}: wrapper");

        for shards in [2usize, 3, 7, d] {
            let s = aggregate_sharded(&uploads, d, shards);
            assert_eq!(
                bits(&s.dw),
                bits(&base.dw),
                "trial {trial}: d={d} shards={shards}: dw values"
            );
            assert_eq!(
                s.dm.as_deref().map(bits),
                base.dm.as_deref().map(bits),
                "trial {trial}: d={d} shards={shards}: dm values"
            );
            assert_eq!(
                s.dv.as_deref().map(bits),
                base.dv.as_deref().map(bits),
                "trial {trial}: d={d} shards={shards}: dv values"
            );
            assert_eq!(
                (s.dw_support, s.dm_support, s.dv_support),
                (base.dw_support, base.dm_support, base.dv_support),
                "trial {trial}: d={d} shards={shards}: supports"
            );
        }
    }
}

#[test]
fn prop_streaming_accumulator_matches_batch_aggregate() {
    // PR 3 tentpole contract: folding a **random permutation** of a
    // cohort's uploads one-at-a-time through `ShardedAccumulator` (which
    // buffers early arrivals and folds in slot order) must produce bits —
    // values AND union supports — identical to `aggregate_sharded` on the
    // full batch, at any shard count.  The generator mixes dense/sparse
    // payloads, exact-zero kept lanes and exactly-cancelling twins.
    let mut rng = Rng::new(113);
    for trial in 0..60 {
        let d = 1 + rng.below(160);
        let n = 1 + rng.below(6);
        let mut uploads: Vec<Upload> = Vec::new();
        for _ in 0..n {
            let dw = gen_recon(&mut rng, d);
            let dm = (rng.below(2) == 0).then(|| gen_recon(&mut rng, d));
            let dv = (rng.below(2) == 0).then(|| gen_recon(&mut rng, d));
            let weight = rng.uniform() * 10.0;
            uploads.push(Upload {
                dw,
                dm,
                dv,
                weight,
                bits: 0,
            });
            // Occasionally append the exact negation at the same weight so
            // lane sums cancel to 0.0 while the wire support does not.
            if rng.below(3) == 0 {
                let last = uploads.last().unwrap();
                let twin = Upload {
                    dw: negated(&last.dw),
                    dm: last.dm.as_ref().map(negated),
                    dv: last.dv.as_ref().map(negated),
                    weight: last.weight,
                    bits: 0,
                };
                uploads.push(twin);
            }
        }
        let weights: Vec<f64> = uploads.iter().map(|u| u.weight).collect();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

        for shards in [1usize, 2, 3, 7, d] {
            let base = aggregate_sharded(&uploads, d, shards);
            let mut acc = ShardedAccumulator::new(d, shards, &weights);
            let mut order: Vec<usize> = (0..uploads.len()).collect();
            rng.shuffle(&mut order);
            for &slot in &order {
                acc.push(slot, uploads[slot].clone());
            }
            assert_eq!(acc.folded(), uploads.len(), "trial {trial}: fold count");
            let agg = acc.finalize();
            assert_eq!(
                bits(&agg.dw),
                bits(&base.dw),
                "trial {trial}: d={d} shards={shards}: streamed dw"
            );
            assert_eq!(
                agg.dm.as_deref().map(bits),
                base.dm.as_deref().map(bits),
                "trial {trial}: d={d} shards={shards}: streamed dm"
            );
            assert_eq!(
                agg.dv.as_deref().map(bits),
                base.dv.as_deref().map(bits),
                "trial {trial}: d={d} shards={shards}: streamed dv"
            );
            assert_eq!(
                (agg.dw_support, agg.dm_support, agg.dv_support),
                (base.dw_support, base.dm_support, base.dv_support),
                "trial {trial}: d={d} shards={shards}: streamed supports"
            );
        }
    }
}

#[test]
fn prop_weighted_mean_is_convex_combination() {
    let mut rng = Rng::new(108);
    for _ in 0..50 {
        let d = 1 + rng.below(200);
        let n = 1 + rng.below(8);
        let rows: Vec<Vec<f32>> = (0..n).map(|_| gen_vec(&mut rng, d)).collect();
        let weights: Vec<f64> = (0..n).map(|_| rng.uniform() + 0.01).collect();
        let pairs: Vec<(&[f32], f64)> = rows
            .iter()
            .map(|r| r.as_slice())
            .zip(weights.iter().cloned())
            .collect();
        let mut out = vec![0.0f32; d];
        tensor::weighted_mean_into(&mut out, &pairs);
        // Bounds: each lane within [min, max] of the inputs.
        for j in 0..d {
            let lo = rows.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
            let hi = rows.iter().map(|r| r[j]).fold(f32::NEG_INFINITY, f32::max);
            assert!(out[j] >= lo - 1e-4 && out[j] <= hi + 1e-4);
        }
    }
}

// ---------------------------------------------------------------------------
// Participation samplers (coordinator::sampler)
// ---------------------------------------------------------------------------

fn sampler_cfg(mode: ParticipationMode, seed: u64, participation: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.participation_mode = mode;
    cfg.participation = participation;
    cfg.seed = seed;
    cfg
}

#[test]
fn prop_uniform_sampler_replays_the_legacy_loop_bit_for_bit() {
    // The pre-sampler coordinator drew cohorts from Rng::new(seed ^
    // 0x5a3c_91f7) with shuffle/truncate/sort (consuming NO randomness on
    // full-participation rounds) and weighted uploads by data size.  The
    // uniform sampler must replay that stream exactly — this is the
    // "participation_mode=uniform is byte-identical to the pre-PR loop"
    // contract at its root.
    let mut rng = Rng::new(2024);
    for trial in 0..50 {
        let n = 1 + rng.below(12);
        let participation = 0.05 + 0.95 * rng.uniform();
        let seed = rng.next_u64();
        let weights: Vec<f64> = (0..n).map(|_| 1.0 + rng.below(64) as f64).collect();
        let lat = vec![0.0; n];
        let cfg = sampler_cfg(ParticipationMode::Uniform, seed, participation);
        let mut s = sampler::build(&cfg, &weights, &lat);
        let mut legacy = Rng::new(seed ^ 0x5a3c_91f7);
        for round in 0..8 {
            let m = ((n as f64 * participation).round() as usize).clamp(1, n);
            let expect: Vec<usize> = if m == n {
                (0..n).collect()
            } else {
                let mut idx: Vec<usize> = (0..n).collect();
                legacy.shuffle(&mut idx);
                idx.truncate(m);
                idx.sort_unstable();
                idx
            };
            let cohort = s.sample(round);
            assert_eq!(cohort.devices, expect, "trial {trial} round {round}");
            let want: Vec<f64> = expect.iter().map(|&i| weights[i]).collect();
            assert_eq!(cohort.weights, want, "trial {trial} round {round}");
        }
    }
}

#[test]
fn prop_importance_draws_are_deterministic_and_cover_every_device() {
    // Same seed ⇒ same cohort stream; and because every device has
    // nonzero data weight, every device has nonzero selection probability
    // per draw — over enough rounds each one must participate.
    let mut rng = Rng::new(2025);
    for trial in 0..20 {
        let n = 2 + rng.below(7);
        let participation = 0.05 + 0.95 * rng.uniform();
        let seed = rng.next_u64();
        // Bounded weight skew keeps the smallest p_i >= 1/(8n).
        let weights: Vec<f64> = (0..n).map(|_| 1.0 + rng.below(8) as f64).collect();
        let lat = vec![0.0; n];
        let cfg = sampler_cfg(ParticipationMode::Importance, seed, participation);
        let mut a = sampler::build(&cfg, &weights, &lat);
        let mut b = sampler::build(&cfg, &weights, &lat);
        let mut seen = vec![false; n];
        let mut rounds_until_covered = None;
        for round in 0..5000 {
            let ca = a.sample(round);
            assert!(!ca.is_empty(), "trial {trial} round {round}");
            assert!(
                ca.devices.windows(2).all(|w| w[0] < w[1]),
                "trial {trial} round {round}: cohort not sorted-unique"
            );
            if round < 32 {
                let cb = b.sample(round);
                assert_eq!(ca.devices, cb.devices, "trial {trial} round {round}");
                assert_eq!(ca.weights, cb.weights, "trial {trial} round {round}");
            }
            for &d in &ca.devices {
                seen[d] = true;
            }
            if seen.iter().all(|&s| s) {
                rounds_until_covered = Some(round);
                break;
            }
        }
        assert!(
            rounds_until_covered.is_some(),
            "trial {trial}: a positive-weight device was never sampled in 5000 rounds"
        );
    }
}

#[test]
fn prop_importance_reweighting_is_unbiased_on_cancelling_twins() {
    // Cancelling-twin fixture: devices 2j and 2j+1 share a data weight
    // and carry exactly opposite scalar updates, so the full-participation
    // FedAvg aggregate is exactly zero.  The sampler's 1/(m·p_i) cohort
    // weights must (a) sum to the full corpus weight every round — which
    // makes the aggregate path's weight/Sigma-weights normalization THE
    // unbiased estimator — and (b) drive the Monte-Carlo mean of the
    // realized aggregate to ~zero.
    let mut rng = Rng::new(2026);
    for trial in 0..5u64 {
        let pairs = 2 + rng.below(3);
        let n = 2 * pairs;
        let mut weights = Vec::with_capacity(n);
        let mut deltas = Vec::with_capacity(n);
        for _ in 0..pairs {
            let w = 1.0 + rng.below(16) as f64;
            let x = 0.5 + rng.uniform();
            weights.push(w);
            weights.push(w);
            deltas.push(x);
            deltas.push(-x);
        }
        let total: f64 = weights.iter().sum();
        let cfg = sampler_cfg(ParticipationMode::Importance, 1000 + trial, 0.5);
        let lat = vec![0.0; n];
        let mut s = sampler::build(&cfg, &weights, &lat);
        let rounds = 2000usize;
        let mut mean = 0.0f64;
        for round in 0..rounds {
            let cohort = s.sample(round);
            let wsum = cohort.total_weight();
            assert!(
                (wsum - total).abs() < 1e-9 * total,
                "trial {trial} round {round}: cohort weight {wsum} != corpus {total}"
            );
            let est: f64 = cohort
                .devices
                .iter()
                .zip(&cohort.weights)
                .map(|(&d, &w)| w * deltas[d])
                .sum::<f64>()
                / wsum;
            mean += est / rounds as f64;
        }
        // Per-round std <= ~1.5/sqrt(m); mean-of-2000 std <= ~0.024.
        assert!(
            mean.abs() < 0.1,
            "trial {trial}: biased importance estimator, Monte-Carlo mean {mean}"
        );
    }
}

#[test]
fn prop_availability_traces_never_yield_an_empty_cohort() {
    // Floor of 1: even pathological duty cycles (nearly always off) must
    // produce a cohort every round, deterministically, sorted-unique, and
    // only from on-duty devices (unless the all-off fallback fired).
    let mut rng = Rng::new(2027);
    for trial in 0..40 {
        let n = 1 + rng.below(10);
        let duty = 0.05 + 0.95 * rng.uniform();
        let over = 1.0 + 2.0 * rng.uniform();
        let participation = 0.05 + 0.95 * rng.uniform();
        let seed = rng.next_u64();
        let weights: Vec<f64> = (0..n).map(|_| 1.0 + rng.below(32) as f64).collect();
        let lat: Vec<f64> = (0..n).map(|_| rng.uniform() * 5.0).collect();
        let mut a =
            AvailabilitySampler::new(seed, participation, duty, over, weights.clone(), lat.clone());
        let mut b = AvailabilitySampler::new(seed, participation, duty, over, weights, lat);
        for round in 0..100 {
            let ca = a.sample(round);
            assert!(!ca.is_empty(), "trial {trial} round {round}: empty cohort");
            assert!(ca.len() <= n, "trial {trial} round {round}");
            assert!(
                ca.devices.windows(2).all(|w| w[0] < w[1]),
                "trial {trial} round {round}: cohort not sorted-unique"
            );
            if ca.len() > 1 {
                // More than the floor ⇒ every member came from the trace.
                for &d in &ca.devices {
                    assert!(
                        a.available(d, round),
                        "trial {trial} round {round}: off-duty device {d} selected"
                    );
                }
            }
            assert_eq!(ca, b.sample(round), "trial {trial} round {round}: nondeterministic");
        }
    }
}

// ---------------------------------------------------------------------------
// Event journal (coordinator::journal) and state snapshots
// ---------------------------------------------------------------------------

fn journal_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fedadam-prop-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One random journal event, every variant reachable.
fn gen_event(rng: &mut Rng) -> Event {
    match rng.below(10) {
        0 => Event::RunStarted {
            version: rng.next_u64() as u32,
            fingerprint: rng.next_u64(),
        },
        1 => Event::CohortSelected {
            round: rng.next_u64(),
            devices: (0..rng.below(6)).map(|_| rng.next_u64()).collect(),
            weights: (0..rng.below(6)).map(|_| rng.next_u64()).collect(),
        },
        2 => Event::Aggregated {
            round: rng.next_u64(),
            folded: rng.next_u64(),
            expected: rng.next_u64(),
            uplink_bits: rng.next_u64(),
        },
        3 => Event::Applied {
            round: rng.next_u64(),
            update_norm: rng.next_u64(),
            downlink_bits: rng.next_u64(),
        },
        4 => Event::EvalInline {
            round: rng.next_u64(),
            test_loss: rng.next_u64(),
            test_accuracy: rng.next_u64(),
        },
        5 => Event::EvalLaunched { round: rng.next_u64() },
        6 => Event::EvalSkipped { round: rng.next_u64() },
        7 => Event::EvalReaped {
            round: rng.next_u64(),
            test_loss: rng.next_u64(),
            test_accuracy: rng.next_u64(),
        },
        8 => Event::RoundDone {
            round: rng.next_u64(),
            train_loss: rng.next_u64(),
            sim_secs: rng.next_u64(),
        },
        _ => Event::SnapshotWritten { round: rng.next_u64() },
    }
}

#[test]
fn prop_journal_event_codec_roundtrips_any_event() {
    let mut rng = Rng::new(3001);
    for trial in 0..300 {
        let ev = gen_event(&mut rng);
        let bytes = ev.encode();
        assert_eq!(Event::decode(&bytes).unwrap(), ev, "trial {trial}");
        // Any strict prefix must error (every field is mandatory), never
        // silently mis-decode.
        let cut = rng.below(bytes.len());
        assert!(
            Event::decode(&bytes[..cut]).is_err(),
            "trial {trial}: truncated payload ({cut}/{}) decoded",
            bytes.len()
        );
        // Trailing garbage must be rejected too.
        let mut padded = bytes.clone();
        padded.push(rng.below(256) as u8);
        assert!(padded.len() == bytes.len() + 1 && Event::decode(&padded).is_err());
    }
}

#[test]
fn prop_journal_log_roundtrips_random_sequences() {
    let mut rng = Rng::new(3002);
    for trial in 0..20 {
        let dir = journal_dir(&format!("log-{trial}"));
        let fp = rng.next_u64();
        let mut j = Journal::create(&dir, fp).unwrap();
        let evs: Vec<Event> = (0..rng.below(40)).map(|_| gen_event(&mut rng)).collect();
        for ev in &evs {
            j.record(ev).unwrap();
        }
        drop(j);
        let contents = read_log(&dir).unwrap();
        assert_eq!(
            contents.events[0],
            Event::RunStarted {
                version: JOURNAL_VERSION,
                fingerprint: fp
            },
            "trial {trial}"
        );
        assert_eq!(&contents.events[1..], evs.as_slice(), "trial {trial}");
        // The stored payloads (the replay oracle's comparands) are the
        // exact encodings.
        for (ev, p) in contents.events.iter().zip(&contents.payloads) {
            assert_eq!(&ev.encode(), p, "trial {trial}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn prop_torn_tail_recovers_the_longest_valid_prefix() {
    // Cutting the log at ANY byte offset must recover exactly the records
    // whose full frame fits before the cut, and report `valid_len` at the
    // last surviving frame's end — nothing before a tear is ever lost,
    // nothing past it is ever trusted.
    let mut rng = Rng::new(3003);
    for trial in 0..15 {
        let dir = journal_dir(&format!("torn-{trial}"));
        let fp = rng.next_u64();
        let mut j = Journal::create(&dir, fp).unwrap();
        let evs: Vec<Event> = (0..1 + rng.below(12)).map(|_| gen_event(&mut rng)).collect();
        for ev in &evs {
            j.record(ev).unwrap();
        }
        drop(j);
        let full = std::fs::read(journal::log_path(&dir)).unwrap();
        // Frame end offsets, header record included.
        let mut ends = Vec::new();
        let mut pos = 0usize;
        let header = Event::RunStarted {
            version: JOURNAL_VERSION,
            fingerprint: fp,
        };
        for ev in std::iter::once(&header).chain(evs.iter()) {
            pos += 8 + ev.encode().len();
            ends.push(pos);
        }
        assert_eq!(pos, full.len(), "trial {trial}: frame accounting is off");
        for _ in 0..12 {
            let cut = rng.below(full.len() + 1);
            std::fs::write(journal::log_path(&dir), &full[..cut]).unwrap();
            let got = read_log(&dir).unwrap();
            let survive = ends.iter().filter(|&&e| e <= cut).count();
            assert_eq!(got.events.len(), survive, "trial {trial} cut {cut}");
            let expect_len = if survive == 0 { 0 } else { ends[survive - 1] };
            assert_eq!(got.valid_len, expect_len as u64, "trial {trial} cut {cut}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn prop_global_state_snapshot_is_bit_exact() {
    // The snapshot codec must round-trip every f32 bit pattern the
    // optimizer can produce: -0.0, subnormals, infinities included.
    let mut rng = Rng::new(3004);
    for trial in 0..40 {
        let d = 1 + rng.below(300);
        let mut gs = GlobalState::new(gen_vec(&mut rng, d));
        gs.m = gen_vec(&mut rng, d);
        gs.v = gen_vec(&mut rng, d);
        gs.w[rng.below(d)] = -0.0;
        gs.m[rng.below(d)] = f32::from_bits(1); // smallest subnormal
        gs.v[rng.below(d)] = f32::INFINITY;
        let mut w = ByteWriter::new();
        gs.save_state(&mut w);
        let bytes = w.into_inner();
        let mut back = GlobalState::new(vec![0.0; d]);
        let mut r = ByteReader::new(&bytes);
        back.load_state(&mut r).unwrap();
        r.finish().unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&gs.w), bits(&back.w), "trial {trial}: w");
        assert_eq!(bits(&gs.m), bits(&back.m), "trial {trial}: m");
        assert_eq!(bits(&gs.v), bits(&back.v), "trial {trial}: v");
    }
}

fn recon_dense(r: &Recon) -> Vec<f32> {
    match r {
        Recon::Dense(v) => v.clone(),
        Recon::Sparse(sv) => sv.to_dense(),
    }
}

#[test]
fn prop_algorithm_state_roundtrip_preserves_future_uploads() {
    // For every stateful algorithm (per-device EF memories, server-side
    // EF): warm the state up with a few compress rounds, snapshot it, load
    // into a freshly built twin, and check the NEXT round's uploads (and
    // the next broadcast postprocess) are bit-identical — the property the
    // resume path depends on.
    let mut rng = Rng::new(3005);
    for algo in ["fedadam-ssm-ef", "fedadam-ssm-qef", "onebit-adam", "efficient-adam"] {
        let d = 64;
        let devices = 3;
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = algo.into();
        cfg.devices = devices;
        cfg.sparsity = 0.1;
        cfg.quant_levels = 4;
        cfg.warmup_rounds = 1;
        let mut a = algorithms::build(&cfg, d).unwrap();
        for t in 0..3 {
            for dev in 0..devices {
                let delta = LocalDelta {
                    dw: gen_vec(&mut rng, d),
                    dm: gen_vec(&mut rng, d),
                    dv: gen_vec(&mut rng, d),
                    weight: 1.0,
                };
                let _ = a.compress(t, dev, delta);
            }
        }
        let mut w = ByteWriter::new();
        a.save_state(&mut w);
        let bytes = w.into_inner();
        let mut b = algorithms::build(&cfg, d).unwrap();
        let mut r = ByteReader::new(&bytes);
        b.load_state(&mut r).unwrap();
        r.finish().unwrap_or_else(|e| panic!("{algo}: snapshot has trailing bytes: {e}"));
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        for dev in 0..devices {
            let delta = LocalDelta {
                dw: gen_vec(&mut rng, d),
                dm: gen_vec(&mut rng, d),
                dv: gen_vec(&mut rng, d),
                weight: 1.0,
            };
            let ua = a.compress(3, dev, delta.clone());
            let ub = b.compress(3, dev, delta);
            assert_eq!(ua.bits, ub.bits, "{algo} device {dev}: wire bits");
            assert_eq!(
                bits(&recon_dense(&ua.dw)),
                bits(&recon_dense(&ub.dw)),
                "{algo} device {dev}: dw after state restore"
            );
        }
        // Server-side state (efficient-adam's downlink EF) must survive too.
        let mk_agg = |dw: Vec<f32>| Aggregate {
            dw,
            dm: None,
            dv: None,
            dw_support: d,
            dm_support: 0,
            dv_support: 0,
        };
        let broadcast = gen_vec(&mut rng, d);
        let mut agg_a = mk_agg(broadcast.clone());
        let mut agg_b = mk_agg(broadcast);
        a.postprocess(&mut agg_a);
        b.postprocess(&mut agg_b);
        assert_eq!(bits(&agg_a.dw), bits(&agg_b.dw), "{algo}: postprocess after restore");
    }
}

// ---------------------------------------------------------------------------
// Wire transport under hostile bytes: frames, messages and codec bodies
// damaged at arbitrary offsets must error (or wait for more bytes) — they
// may NEVER panic and NEVER silently decode to something different.
// ---------------------------------------------------------------------------

/// Random frame payload, including the empty one.
fn gen_payload(rng: &mut Rng) -> Vec<u8> {
    let n = rng.below(200);
    (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
}

#[test]
fn prop_frame_mutation_never_panics_or_silently_misdecodes() {
    // The CRC-32 detects every burst error up to 32 bits, so a single
    // flipped bit anywhere in the header or payload is always caught; a
    // flipped length prefix either under-reads (checksum mismatch),
    // over-reads (EOF mid-frame) or trips the allocation cap.  The only
    // acceptable `Ok` from damaged bytes is the EXACT original payload.
    let mut rng = Rng::new(4001);
    for trial in 0..200 {
        let payload = gen_payload(&mut rng);
        let mut framed = Vec::new();
        write_frame(&mut framed, &payload).unwrap();
        assert_eq!(framed.len(), FRAME_HEADER_LEN + payload.len(), "trial {trial}");

        // Clean bytes roundtrip through both read paths.
        let back = read_frame(&mut &framed[..])
            .unwrap_or_else(|e| panic!("trial {trial}: clean frame failed: {e}"));
        assert_eq!(back, payload, "trial {trial}: blocking read");
        let mut fb = FrameBuffer::new();
        fb.extend(&framed);
        assert_eq!(fb.pop().unwrap(), Some(payload.clone()), "trial {trial}: buffered read");
        assert!(fb.pop().unwrap().is_none(), "trial {trial}: phantom second frame");

        // Truncation at a random offset: the blocking read errors, the
        // incremental buffer errors or keeps waiting — neither may ever
        // surface a payload from a partial frame.
        let cut = rng.below(framed.len());
        assert!(
            read_frame(&mut &framed[..cut]).is_err(),
            "trial {trial}: truncation to {cut} bytes decoded"
        );
        let mut fb = FrameBuffer::new();
        fb.extend(&framed[..cut]);
        if let Ok(Some(p)) = fb.pop() {
            panic!(
                "trial {trial}: truncated frame ({cut} of {} bytes) popped a {}-byte payload",
                framed.len(),
                p.len()
            );
        }

        // One flipped bit at a random offset: Err, or the exact original.
        let at = rng.below(framed.len());
        let mut evil = framed.clone();
        evil[at] ^= 1u8 << rng.below(8);
        if let Ok(p) = read_frame(&mut &evil[..]) {
            assert_eq!(p, payload, "trial {trial}: flip at byte {at} misdecoded");
        }
        let mut fb = FrameBuffer::new();
        fb.extend(&evil);
        if let Ok(Some(p)) = fb.pop() {
            assert_eq!(p, payload, "trial {trial}: flip at byte {at} misdecoded (buffered)");
        }
    }
}

/// Random transport message, weighted toward the structurally rich ones.
fn gen_msg(rng: &mut Rng) -> Msg {
    fn f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }
    match rng.below(6) {
        0 => Msg::Hello {
            version: rng.next_u64() as u32,
            fingerprint: rng.next_u64(),
            agent: rng.below(8) as u32,
        },
        1 => Msg::HelloAck {
            agents: 1 + rng.below(8) as u32,
            dim: rng.next_u64() % 1000,
        },
        2 => Msg::Shutdown,
        3 | 4 => {
            let d = 1 + rng.below(40);
            Msg::RoundStart {
                round: rng.next_u64() % 100,
                w: f32s(rng, d),
                m: if rng.below(2) == 0 { Some(f32s(rng, d)) } else { None },
                v: if rng.below(2) == 0 { Some(f32s(rng, d)) } else { None },
                assignments: (0..rng.below(6))
                    .map(|s| Assignment {
                        slot: s as u32,
                        device: rng.below(32) as u32,
                        weight: rng.uniform() * 200.0,
                    })
                    .collect(),
            }
        }
        _ => Msg::Uplink(Uplink {
            round: rng.next_u64() % 100,
            slot: rng.below(16) as u32,
            device: rng.below(64) as u32,
            mean_loss: rng.normal(),
            weight: rng.uniform() * 200.0,
            kind: rng.below(9) as u8,
            k: rng.next_u64() % 500,
            levels: rng.below(32) as u32,
            bits: rng.next_u64() % 10_000,
            body: (0..rng.below(64)).map(|_| (rng.next_u64() & 0xFF) as u8).collect(),
        }),
    }
}

#[test]
fn prop_msg_mutation_decodes_to_error_or_a_byte_faithful_message() {
    // The message codec has no checksum of its own (the frame layer owns
    // integrity), but it IS canonical: fixed-width little-endian fields,
    // raw-bit floats, strict bools, allocation-guarded length prefixes and
    // a no-trailing-bytes check mean every byte string `Msg::decode`
    // accepts re-encodes to exactly itself.  So a mutated payload either
    // errors or decodes to a message that re-serializes to the mutated
    // bytes verbatim — a silent misparse is impossible, and a truncated
    // payload never decodes at all.
    let mut rng = Rng::new(4002);
    for trial in 0..300 {
        let msg = gen_msg(&mut rng);
        let bytes = msg.encode();
        let back = Msg::decode(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: clean decode failed: {e}\n{msg:?}"));
        assert_eq!(back, msg, "trial {trial}: roundtrip");

        let cut = rng.below(bytes.len());
        assert!(
            Msg::decode(&bytes[..cut]).is_err(),
            "trial {trial}: truncation to {cut} of {} bytes decoded",
            bytes.len()
        );

        let at = rng.below(bytes.len());
        let mut evil = bytes.clone();
        evil[at] ^= 1u8 << rng.below(8);
        if let Ok(m) = Msg::decode(&evil) {
            assert_eq!(
                m.encode(),
                evil,
                "trial {trial}: flip at byte {at} decoded non-canonically to {m:?}"
            );
        }
    }
}

#[test]
fn prop_wire_body_mutation_preserves_support_or_errors() {
    // Codec bodies from every real compressor: truncate or bit-flip the
    // encoded bitstream and `try_decode` against the ORIGINAL header.
    // Truncation must always error (the byte length is pinned to
    // ceil(bits/8)).  A bit flip must either error or decode to a body
    // that is structurally sound — exact support size `k`, identical
    // header fields — and canonical (re-encodes to the mutated bytes;
    // padding bits are verified zero, so even a padding flip cannot
    // smuggle in an unfaithful decode).
    let mut rng = Rng::new(4003);
    let d = 300;
    for algo in algorithms::ALL_WITH_EXTENSIONS {
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = algo.into();
        cfg.devices = 2;
        cfg.sparsity = 0.1;
        cfg.quant_levels = 8;
        cfg.warmup_rounds = 1;
        let mut a = algorithms::build(&cfg, d).unwrap();
        for round in 0..3 {
            let delta = LocalDelta {
                dw: gen_vec(&mut rng, d),
                dm: gen_vec(&mut rng, d),
                dv: gen_vec(&mut rng, d),
                weight: 1.0,
            };
            let wire = a.compress_wire(round, 0, delta).unwrap();
            let (kind, k, levels, bits) =
                (wire.body.kind(), wire.body.k(), wire.body.levels(), wire.bits);
            let bytes = wire.encode_body().unwrap();
            assert_eq!(bytes.len() as u64, bits.div_ceil(8), "{algo} round {round}: framed bytes");

            // Clean decode is canonical and support-exact.
            let body = WireBody::try_decode(kind, d, k, levels, bits, &bytes)
                .unwrap_or_else(|e| panic!("{algo} round {round}: clean decode failed: {e}"));
            assert_eq!(body.k(), k, "{algo} round {round}: clean support");
            assert_eq!(body.encode(), bytes, "{algo} round {round}: clean canonicality");

            // Truncation always errors.
            let cut = rng.below(bytes.len());
            assert!(
                WireBody::try_decode(kind, d, k, levels, bits, &bytes[..cut]).is_err(),
                "{algo} round {round}: truncation to {cut} of {} bytes decoded",
                bytes.len()
            );

            // Bit flips, several per body: error or faithful-and-sound.
            for _ in 0..8 {
                let at = rng.below(bytes.len());
                let mut evil = bytes.clone();
                evil[at] ^= 1u8 << rng.below(8);
                match WireBody::try_decode(kind, d, k, levels, bits, &evil) {
                    Err(_) => {}
                    Ok(b) => {
                        assert_eq!(b.kind(), kind, "{algo} round {round}: flip at {at} changed kind");
                        assert_eq!(b.k(), k, "{algo} round {round}: flip at {at} changed support size");
                        assert_eq!(
                            b.encode(),
                            evil,
                            "{algo} round {round}: flip at byte {at} decoded non-canonically"
                        );
                    }
                }
            }
        }
    }
}

// ---- residual store: spilling is placement, never semantics ----------------

/// Values chosen to break any non-bit-exact round-trip: signed zero,
/// subnormals, the smallest normal, and a payload-carrying NaN.
fn nasty_f32(rng: &mut Rng) -> f32 {
    match rng.below(6) {
        0 => -0.0,
        1 => 1.0e-42,            // subnormal
        2 => -1.0e-45,           // smallest-magnitude subnormal, negative
        3 => f32::MIN_POSITIVE,
        4 => f32::from_bits(0x7fc0_1234), // NaN with a payload
        _ => rng.normal() as f32,
    }
}

#[test]
fn prop_residual_store_any_interleaving_matches_the_dense_oracle() {
    use fedadam_ssm::algorithms::residual_store::ResidualStore;
    use std::collections::BTreeMap;

    let dir = std::env::temp_dir().join(format!("fedadam-prop-rstore-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spill_dir = dir.to_string_lossy().into_owned();

    let mut rng = Rng::new(911);
    for trial in 0..40u64 {
        let dim = 1 + rng.below(9);
        let cap = rng.below(4); // 0 = unbounded (dense-equivalent)
        let spill = if cap == 0 { "" } else { spill_dir.as_str() };
        let mut store = ResidualStore::new(dim, cap, spill);
        // The dense oracle: what a Vec<Memory> keyed by id would hold.
        let mut oracle: BTreeMap<u64, Vec<f32>> = BTreeMap::new();
        // Ids far above any resident cap, clustered and colliding.
        let ids = [
            0u64,
            1,
            2,
            3,
            999_983,
            u64::MAX - 7,
            trial * 1_000_003,
        ];

        for step in 0..200 {
            match rng.below(4) {
                0 => {
                    // Touch (materializing / rehydrating) then overwrite
                    // some lanes with hostile values.  Touching past the
                    // cap evicts the LRU entry to disk.
                    let id = ids[rng.below(ids.len())];
                    let expect = oracle.entry(id).or_insert_with(|| vec![0.0; dim]);
                    let entry = store.get_mut(id);
                    for (lane, (got, want)) in entry.iter().zip(expect.iter()).enumerate() {
                        assert_eq!(
                            got.to_bits(),
                            want.to_bits(),
                            "trial {trial} step {step}: id {id} lane {lane} diverged on touch"
                        );
                    }
                    for lane in 0..dim {
                        if rng.below(2) == 0 {
                            let v = nasty_f32(&mut rng);
                            entry[lane] = v;
                            expect[lane] = v;
                        }
                    }
                }
                1 => {
                    // Non-promoting read from whichever tier holds it.
                    let id = ids[rng.below(ids.len())];
                    let got = store.peek(id);
                    let want = oracle.get(&id);
                    match (got, want) {
                        (None, None) => {}
                        (Some(g), Some(w)) => {
                            let gb: Vec<u32> = g.iter().map(|x| x.to_bits()).collect();
                            let wb: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
                            assert_eq!(gb, wb, "trial {trial} step {step}: peek({id})");
                        }
                        (g, w) => panic!(
                            "trial {trial} step {step}: peek({id}) presence {} vs oracle {}",
                            g.is_some(),
                            w.is_some()
                        ),
                    }
                }
                2 => {
                    // Snapshot → restore in place (what a journal resume
                    // does mid-run).
                    let mut w = ByteWriter::new();
                    store.save_state(&mut w);
                    let bytes = w.into_inner();
                    let mut r = ByteReader::new(&bytes);
                    store.load_state(&mut r).unwrap();
                    r.finish().unwrap();
                }
                _ => {
                    // Snapshot → restore into a store with a DIFFERENT
                    // resident cap: tiering is placement, the snapshot
                    // must be cap-agnostic.
                    let mut w = ByteWriter::new();
                    store.save_state(&mut w);
                    let bytes = w.into_inner();
                    let cap2 = rng.below(4);
                    let spill2 = if cap2 == 0 { "" } else { spill_dir.as_str() };
                    let mut fresh = ResidualStore::new(dim, cap2, spill2);
                    let mut r = ByteReader::new(&bytes);
                    fresh.load_state(&mut r).unwrap();
                    r.finish().unwrap();
                    store = fresh;
                }
            }
        }

        // Every touched id reads back bit-identical to the dense oracle.
        assert_eq!(store.touched(), oracle.len(), "trial {trial}: touched-set size");
        for (id, want) in &oracle {
            let got = store.peek(*id).unwrap_or_else(|| panic!("trial {trial}: id {id} lost"));
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "trial {trial}: final read of id {id}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
