//! # FedAdam-SSM
//!
//! Production reproduction of *"Towards Communication-efficient Federated
//! Learning via Sparse and Aligned Adaptive Optimization"* (TSP 2025):
//! a federated-Adam framework where devices sparsify the updates of local
//! model parameters **and** both moment estimates with one **Shared Sparse
//! Mask** (the top-k mask of `|ΔW|`), cutting uplink cost from `O(3dq)` to
//! `O(3kq + d)`.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! - **Layer 1** (build time): Pallas kernels — fused Adam, SSM sparsify,
//!   quantizers (`python/compile/kernels/`).
//! - **Layer 2** (build time): JAX models + local training programs,
//!   AOT-lowered to HLO text (`python/compile/`).
//! - **Layer 3** (this crate): the federated runtime — device/server
//!   coordination, sparse + quantized transport with bit-accurate
//!   accounting, sharded aggregation, pool-parallel eval, experiment
//!   harness. Python is never on the runtime path: the binary executes
//!   the AOT artifacts via PJRT (or, for offline tests/benches, the
//!   pure-Rust [`runtime::ReferenceExecutor`]).  Determinism contract:
//!   aggregation is shard-order-fixed and eval is batch-order-fixed, so
//!   results are byte-identical at any `num_workers` / `agg_shards`.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fedadam_ssm::config::ExperimentConfig;
//! use fedadam_ssm::coordinator::Coordinator;
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.model = "cnn_small".into();
//! cfg.algorithm = "fedadam-ssm".into();
//! cfg.rounds = 20;
//! let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
//! let log = coord.run().unwrap();
//! println!("final accuracy {:.3}", log.rounds.last().unwrap().test_accuracy);
//! ```

pub mod algorithms;
pub mod benchlib;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod simtime;
pub mod sparse;
pub mod tensor;
pub mod theory;
pub mod transport;
pub mod util;



