//! The algorithm zoo: FedAdam-SSM (the paper's contribution) and every
//! baseline from §VII-A, behind one [`Algorithm`] trait.
//!
//! Division of labour with the coordinator: the coordinator owns local
//! training (via the PJRT engine), delta computation, FedAvg aggregation
//! and bookkeeping; an [`Algorithm`] owns *what goes on the wire* — how a
//! device's `(ΔW, ΔM, ΔV)` is compressed, what it costs in bits, what the
//! server reconstructs, and which global state is updated.
//!
//! The canonical eleven-id cost table (`q = 32`, `k = round(α·d)`,
//! `b = ceil(log2 s)`) — mirrored by README, `docs/ARCHITECTURE.md` and
//! `benches/comm_cost.rs` (which asserts its id set against
//! [`CONFORMANCE_ZOO`]); the conformance suite pins every id's per-round
//! ledger to the matching `sparse::codec::cost` function:
//!
//! | id                | uplink per device/round                 | moments    |
//! |-------------------|------------------------------------------|------------|
//! | `fedadam`         | `3dq` dense                              | aggregated |
//! | `fedadam-top`     | `min{3(kq+d), 3k(q+log2 d)}`             | aggregated |
//! | `fedadam-ssm`     | `min{3kq+d, k(3q+log2 d)}` (mask of ΔW)  | aggregated |
//! | `fedadam-ssm-m`   | same cost (mask of ΔM)                   | aggregated |
//! | `fedadam-ssm-v`   | same cost (mask of ΔV)                   | aggregated |
//! | `fairness-top`    | same cost (mask of the normalized union) | aggregated |
//! | `fedadam-ssm-q`   | `min{3kb+d, k(3b+log2 d)} + 3q`          | aggregated |
//! | `fedadam-ssm-qef` | same cost (+ per-device pre-mask EF)     | aggregated |
//! | `onebit-adam`     | warmup `3dq`, then `d + q`               | local      |
//! | `efficient-adam`  | `d·b + q`                                | local      |
//! | `fedsgd`          | `dq` dense                               | none       |
//!
//! (`fedadam-ssm-ef`, the un-quantized EF extension, prices like
//! `fedadam-ssm`; the accuracy/bit frontier the quantized pair opens is
//! swept by `benches/frontier.rs`.)

pub mod centralized;
pub mod efficient;
pub mod fairness;
pub mod fedadam;
pub mod fedsgd;
pub mod onebit;
pub mod residual_store;
pub mod ssm;
pub mod ssm_ef;
pub mod ssm_q;
pub mod topk;
pub mod wire;

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::sparse::SparseVec;
use crate::util::bytes::{ByteReader, ByteWriter};

/// How devices train locally this round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalMode {
    /// Full local Adam (eq. 3-5).
    Adam,
    /// Plain SGD (FedSGD baseline).
    Sgd,
}

/// Who owns the moment estimates between rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentumPolicy {
    /// Devices start every round from the aggregated global (M, V)
    /// (Algorithm 2 — the up-to-date moments the paper argues for).
    Aggregated,
    /// Each device keeps its own (m, v) across rounds; the server never
    /// sees them (the staleness the paper criticizes in [27]-[29]).
    DeviceLocal,
}

/// One device's raw update for a round (weight = |D̃_n| for FedAvg).
#[derive(Clone, Debug)]
pub struct LocalDelta {
    pub dw: Vec<f32>,
    pub dm: Vec<f32>,
    pub dv: Vec<f32>,
    pub weight: f64,
}

/// A reconstructed per-vector payload as the server will see it.
#[derive(Clone, Debug)]
pub enum Recon {
    Dense(Vec<f32>),
    Sparse(SparseVec),
}

impl Recon {
    /// Accumulate `coef * self` into a dense buffer (server reduce).
    pub fn axpy_into(&self, out: &mut [f32], coef: f32) {
        match self {
            Recon::Dense(v) => crate::tensor::axpy(out, coef, v),
            Recon::Sparse(sv) => sv.axpy_into(out, coef),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            Recon::Dense(v) => v.len(),
            Recon::Sparse(sv) => sv.nnz(),
        }
    }
}

/// What one device uploads after compression.
#[derive(Clone, Debug)]
pub struct Upload {
    pub dw: Recon,
    pub dm: Option<Recon>,
    pub dv: Option<Recon>,
    /// FedAvg weight.
    pub weight: f64,
    /// Exact uplink cost of this message.
    pub bits: u64,
}

/// Aggregated (already FedAvg'd) global updates for a round.
///
/// Carries the **union support sizes** of the uploads alongside the summed
/// vectors: downlink pricing must use these, not a recount of non-zeros of
/// the sums — device contributions can cancel to exact `0.0` (and a masked
/// lane can legitimately carry a true zero), which would silently shrink
/// the priced support below what the broadcast wire actually encodes.
#[derive(Clone, Debug)]
pub struct Aggregate {
    pub dw: Vec<f32>,
    pub dm: Option<Vec<f32>>,
    pub dv: Option<Vec<f32>>,
    /// `|∪ support(ΔW_n)|` over the uploads (a dense upload ⇒ all `d`).
    pub dw_support: usize,
    /// `|∪ support(ΔM_n)|` over uploads that carried ΔM (0 when none did).
    pub dm_support: usize,
    /// `|∪ support(ΔV_n)|` over uploads that carried ΔV (0 when none did).
    pub dv_support: usize,
}

/// Strategy interface — one instance per experiment run.
pub trait Algorithm: Send {
    /// Stable id (matches `ExperimentConfig::algorithm`).
    fn name(&self) -> &'static str;

    /// Local optimizer for the current round.
    fn local_mode(&self, round: usize) -> LocalMode {
        let _ = round;
        LocalMode::Adam
    }

    /// Moment ownership for the current round.
    fn momentum_policy(&self, round: usize) -> MomentumPolicy {
        let _ = round;
        MomentumPolicy::Aggregated
    }

    /// Compress one device's delta into its uplink message.
    ///
    /// Takes the delta by value so dense algorithms can move the vectors
    /// straight onto the wire without copying (§Perf L3).
    fn compress(&mut self, round: usize, device: usize, delta: LocalDelta) -> Upload;

    /// Compress one device's delta into its **transport** form — the
    /// actual bytes-on-the-wire message a remote device agent sends.
    ///
    /// Must be observationally identical to [`Algorithm::compress`]: the
    /// decoded [`wire::WireBody`] reconstructs the same [`Upload`]
    /// bit-for-bit, mutates any per-device state (EF memory) exactly
    /// once, and prices the same ledger bits.  The default derives the
    /// body from the upload payloads, which is correct for the dense and
    /// sparse-f32 families; quantized algorithms override it to ship
    /// their raw code packets instead of f32 re-encodings.
    fn compress_wire(
        &mut self,
        round: usize,
        device: usize,
        delta: LocalDelta,
    ) -> Result<wire::WireUpload> {
        wire::WireUpload::from_upload(self.compress(round, device, delta))
    }

    /// Downlink bits for broadcasting `agg` to ONE device.
    fn downlink_bits(&self, agg: &Aggregate) -> u64;

    /// Server-side transform of the aggregate before it is applied
    /// (e.g. Efficient-Adam re-quantizes the broadcast). Default: identity.
    fn postprocess(&mut self, agg: &mut Aggregate) {
        let _ = agg;
    }

    /// Serialize all cross-round mutable state (per-device EF residual
    /// memories, server-side EF, …) into a coordinator snapshot.
    /// Stateless algorithms write nothing (the default).
    fn save_state(&self, out: &mut ByteWriter) {
        let _ = out;
    }

    /// Restore exactly what [`Algorithm::save_state`] wrote — must consume
    /// the same bytes, bit-exactly, so a resumed run replays the original
    /// byte for byte.  Default: nothing to restore.
    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let _ = input;
        Ok(())
    }
}

/// Instantiate an algorithm by its config id.
pub fn build(cfg: &ExperimentConfig, dim: usize) -> Result<Box<dyn Algorithm>> {
    let k = cfg.k_for(dim);
    Ok(match cfg.algorithm.as_str() {
        "fedadam" => Box::new(fedadam::FedAdam::new(dim)),
        "fedadam-top" => Box::new(topk::FedAdamTop::new(dim, k)),
        "fedadam-ssm" => Box::new(ssm::FedAdamSsm::new(dim, k, ssm::MaskSource::W)),
        "fedadam-ssm-m" => Box::new(ssm::FedAdamSsm::new(dim, k, ssm::MaskSource::M)),
        "fedadam-ssm-v" => Box::new(ssm::FedAdamSsm::new(dim, k, ssm::MaskSource::V)),
        "fairness-top" => Box::new(fairness::FairnessTop::new(dim, k)),
        "fedadam-ssm-ef" => Box::new(ssm_ef::FedAdamSsmEf::new(
            dim,
            k,
            cfg.residual_resident_cap,
            &cfg.residual_spill_dir,
        )),
        "fedadam-ssm-q" => Box::new(ssm_q::FedAdamSsmQ::new(dim, k, cfg.quant_levels as u32)),
        "fedadam-ssm-qef" => Box::new(ssm_q::FedAdamSsmQEf::new(
            dim,
            k,
            cfg.quant_levels as u32,
            cfg.residual_resident_cap,
            &cfg.residual_spill_dir,
        )),
        "onebit-adam" => Box::new(onebit::OneBitAdam::new(
            dim,
            cfg.warmup_rounds,
            cfg.residual_resident_cap,
            &cfg.residual_spill_dir,
        )),
        "efficient-adam" => Box::new(efficient::EfficientAdam::new(
            dim,
            cfg.quant_levels as u32,
            cfg.residual_resident_cap,
            &cfg.residual_spill_dir,
        )),
        "fedsgd" => Box::new(fedsgd::FedSgd::new(dim)),
        other => bail!(
            "unknown algorithm {other:?}; known: fedadam, fedadam-top, fedadam-ssm, \
             fedadam-ssm-ef, fedadam-ssm-m, fedadam-ssm-v, fairness-top, fedadam-ssm-q, \
             fedadam-ssm-qef, onebit-adam, efficient-adam, fedsgd"
        ),
    })
}

/// Ids whose wire format depends on the `quant_levels` knob `s` — config
/// validation rejects `s < 2` for these by name before a run starts.
pub fn uses_quant_levels(id: &str) -> bool {
    matches!(id, "efficient-adam" | "fedadam-ssm-q" | "fedadam-ssm-qef")
}

/// The paper's §VII algorithms (experiment sweeps iterate this).
pub const ALL_ALGORITHMS: [&str; 9] = [
    "fedadam-ssm",
    "fedadam-top",
    "fairness-top",
    "fedadam-ssm-m",
    "fedadam-ssm-v",
    "fedadam",
    "onebit-adam",
    "efficient-adam",
    "fedsgd",
];

/// The eleven-id conformance zoo: the paper's nine plus the quantized-SSM
/// composition pair (`benches/frontier.rs` sweeps the frontier they open).
pub const CONFORMANCE_ZOO: [&str; 11] = [
    "fedadam",
    "fedadam-top",
    "fedadam-ssm",
    "fedadam-ssm-m",
    "fedadam-ssm-v",
    "fairness-top",
    "fedadam-ssm-q",
    "fedadam-ssm-qef",
    "onebit-adam",
    "efficient-adam",
    "fedsgd",
];

/// Everything buildable, including the EF and quantized-SSM extensions.
pub const ALL_WITH_EXTENSIONS: [&str; 12] = [
    "fedadam-ssm",
    "fedadam-ssm-ef",
    "fedadam-ssm-q",
    "fedadam-ssm-qef",
    "fedadam-top",
    "fairness-top",
    "fedadam-ssm-m",
    "fedadam-ssm-v",
    "fedadam",
    "onebit-adam",
    "efficient-adam",
    "fedsgd",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_ids() {
        let mut cfg = ExperimentConfig::default();
        for id in ALL_WITH_EXTENSIONS {
            cfg.algorithm = id.into();
            let algo = build(&cfg, 1000).unwrap();
            assert_eq!(algo.name(), id);
        }
        cfg.algorithm = "bogus".into();
        assert!(build(&cfg, 1000).is_err());
    }

    #[test]
    fn conformance_zoo_is_buildable_and_quant_ids_flagged() {
        let cfg = ExperimentConfig::default();
        for id in CONFORMANCE_ZOO {
            assert!(
                ALL_WITH_EXTENSIONS.contains(&id),
                "{id} in zoo but not buildable set"
            );
            let mut c = cfg.clone();
            c.algorithm = id.into();
            assert_eq!(build(&c, 500).unwrap().name(), id);
        }
        for id in ["efficient-adam", "fedadam-ssm-q", "fedadam-ssm-qef"] {
            assert!(uses_quant_levels(id), "{id}");
        }
        for id in ["fedadam-ssm", "fedadam", "onebit-adam", "fedsgd"] {
            assert!(!uses_quant_levels(id), "{id}");
        }
    }

    #[test]
    fn recon_axpy_dense_and_sparse() {
        let mut out = vec![0.0f32; 4];
        Recon::Dense(vec![1.0, 2.0, 3.0, 4.0]).axpy_into(&mut out, 0.5);
        assert_eq!(out, vec![0.5, 1.0, 1.5, 2.0]);
        let sv = SparseVec {
            dim: 4,
            indices: vec![0, 3],
            values: vec![2.0, 2.0],
        };
        Recon::Sparse(sv).axpy_into(&mut out, 1.0);
        assert_eq!(out, vec![2.5, 1.0, 1.5, 4.0]);
    }
}
