//! Engine-pool scaling bench: wall-clock of one full FL round at
//! 1 / 2 / 4 / 8 pool workers, same config otherwise.
//!
//! The round's compute is dominated by per-device local training, which the
//! coordinator dispatches concurrently across the pool — round latency
//! should fall monotonically from 1 to (about) core-count workers, while
//! every logged number stays bit-identical (see `coordinator_e2e`).
//!
//! Requires `make artifacts`. Run: `cargo bench --bench pool_scaling`.

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() {
    let mut bench = from_env();
    // One round is ~100ms-scale; cap iterations regardless of budget.
    bench.max_iters = 20;

    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);

    for workers in [1usize, 2, 4, 8] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "cnn_small".into();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.rounds = usize::MAX; // stepped manually
        cfg.devices = 8;
        cfg.local_epochs = 1;
        cfg.max_batches_per_epoch = 2;
        cfg.train_samples = 1024;
        cfg.test_samples = 64;
        cfg.eval_every = usize::MAX - 1; // exclude eval from the round cost
        cfg.num_workers = workers;
        let mut coord = match Coordinator::new(cfg, "artifacts") {
            Ok(c) => c,
            Err(e) => {
                eprintln!("skipping pool-scaling bench: {e}");
                return;
            }
        };
        bench.run(
            format!("round: fedadam-ssm, 8 dev, {workers} workers ({cores} cores)"),
            || {
                black_box(coord.step_round().unwrap());
            },
        );
    }

    bench.report("engine-pool scaling (one FL round)");
    println!("\n{}", bench.to_csv());

    // Monotonicity check on the acceptance range (1 -> 4 workers), advisory
    // when the host has too few cores to show scaling.
    let mean = |i: usize| bench.results[i].mean_ns;
    if cores >= 4 {
        if mean(0) > mean(1) && mean(1) > mean(2) {
            println!("scaling OK: {:.1}ms -> {:.1}ms -> {:.1}ms (1/2/4 workers)",
                mean(0) / 1e6, mean(1) / 1e6, mean(2) / 1e6);
        } else {
            println!("WARNING: round latency not monotonically decreasing 1 -> 4 workers");
        }
    } else {
        println!("note: only {cores} cores; scaling curve not meaningful");
    }
}
