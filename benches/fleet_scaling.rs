//! Fleet-scaling bench: per-round cost must be O(cohort), not O(fleet).
//!
//! A registered fleet of 10⁶ devices is allowed to cost O(fleet) exactly
//! once — at registration (corpus synthesis, [`ShardPlan`] build, alias
//! table, latency model).  Every *round* after that may only touch the
//! sampled cohort: lazy device synthesis from the shard plan, lazily
//! materialized residual/moment entries, O(1) alias draws.  This bench
//! pins that contract on the pure-Rust reference backend:
//!
//! 1. **Scaling sweep** — identical per-round workload (importance
//!    sampling, ~8-device cohort, 1 sample per device, simtime on) at
//!    fleet sizes 10³ / 10⁵ (and 10⁶ unless `FEDADAM_BENCH_QUICK=1`),
//!    timing `step_round` only (construction is untimed registration).
//!    Asserts the median per-round wall-clock at every larger fleet stays
//!    under 1.25× the 10³ figure (both sides floored at 200 µs so timer
//!    noise on a sub-100 µs round cannot fake a regression), and that
//!    resident-memory growth across the timed rounds stays flat (8 MB
//!    allocator-noise floor — an O(fleet) dense-state regression at 10⁶
//!    devices allocates hundreds of MB and cannot hide under it).
//!
//! 2. **Conformance leg** — at fleet 10³, every `CONFORMANCE_ZOO` id
//!    (plus `fedadam-ssm-ef`) runs the full round loop twice: residuals
//!    dense in RAM (`residual_resident_cap = 0`) vs a 2-entry cap
//!    spilling to disk.  Final weights and every logged metric outside
//!    `wall_secs` must be bit-identical — spilling is a memory placement,
//!    never a semantics change.
//!
//! Run: `cargo bench --bench fleet_scaling`.
//!
//! **JSON mode** (`-- --json`) — the CI pin: emits the per-fleet medians,
//! RSS readings and flatness ratios as `BENCH_fleet_scaling.json`
//! (`--json-out PATH` to redirect).  With `--baseline PATH` fresh medians
//! are compared against a checked-in file and any >10% regression prints
//! a `WARN:` line (informational — absolute numbers are host-dependent,
//! so the comparison never fails the build).

use std::collections::BTreeMap;

use fedadam_ssm::algorithms::CONFORMANCE_ZOO;
use fedadam_ssm::benchlib::{black_box, from_env, Bench};
use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool};
use fedadam_ssm::util::json::{self, Value};

const INPUT: [usize; 3] = [4, 4, 1]; // row 16; dim = 10 * (16 + 1) = 170
const CLASSES: usize = 10; // matches SyntheticSpec::for_input_shape
/// Target cohort size at every fleet size — the per-round workload.
const COHORT: usize = 8;
/// Wall-clock flatness bound between 10³ and the largest fleet.
const FLAT_RATIO: f64 = 1.25;
/// Median floor (ns): below this, timer noise dominates signal.
const FLOOR_NS: f64 = 200_000.0;
/// RSS-growth allocator-noise floor (KiB).
const RSS_FLOOR_KB: f64 = 8_192.0;

/// One sample per device, IID, ~8-device cohorts regardless of fleet
/// size: the per-round *work* is constant, so any wall-clock growth in
/// `fleet` is an O(fleet) term leaking into the round path.
fn fleet_cfg(fleet: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("fleet-{fleet}");
    cfg.model = "reference-linear".into();
    cfg.algorithm = "fedadam-ssm-ef".into(); // per-device EF residuals
    cfg.rounds = usize::MAX; // stepped manually
    cfg.devices = fleet;
    cfg.train_samples = fleet;
    cfg.test_samples = 64;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 1;
    cfg.eval_every = usize::MAX - 1; // exclude eval from the round cost
    cfg.participation = COHORT as f64 / fleet as f64;
    cfg.participation_mode = ParticipationMode::Importance; // O(1) draws
    cfg.simtime = true;
    cfg.seed = 97;
    cfg.num_workers = 2;
    cfg
}

fn build_coord(cfg: ExperimentConfig) -> Coordinator {
    let meta = reference_meta(&INPUT, CLASSES, 8, 32, 1);
    let pool = reference_pool(meta, cfg.num_workers).expect("reference pool");
    Coordinator::with_pool(cfg, pool).expect("coordinator")
}

/// Resident set size in KiB (`None` off Linux / unreadable procfs).
fn rss_kb() -> Option<f64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = text.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse::<f64>().ok()
}

struct FleetCase {
    fleet: usize,
    median_round_ns: f64,
    rss_after_build_kb: Option<f64>,
    rss_round_growth_kb: Option<f64>,
    cohort_devices: u64,
}

/// Build (untimed — registration is allowed O(fleet)), then time
/// `step_round` and meter RSS growth across the timed rounds.
fn measure_fleet(bench: &mut Bench, fleet: usize) -> FleetCase {
    let mut coord = build_coord(fleet_cfg(fleet));
    let rss_after_build = rss_kb();
    let result = bench.run(format!("per-round @ fleet={fleet}"), || {
        black_box(coord.step_round().expect("round"));
    });
    let median_round_ns = result.p50_ns;
    let rss_after_rounds = rss_kb();
    let growth = match (rss_after_build, rss_after_rounds) {
        (Some(a), Some(b)) => Some((b - a).max(0.0)),
        _ => None,
    };
    let cohort_devices = coord
        .log()
        .rounds
        .last()
        .map(|r| r.cohort_devices)
        .unwrap_or(0);
    FleetCase {
        fleet,
        median_round_ns,
        rss_after_build_kb: rss_after_build,
        rss_round_growth_kb: growth,
        cohort_devices,
    }
}

/// Full run of `algorithm` at fleet 10³ with the given residual tiering.
fn conformance_run(algorithm: &str, cap: usize, spill: &str) -> (ExperimentLog, Vec<f32>) {
    let mut cfg = fleet_cfg(1_000);
    cfg.name = format!("zoo-{algorithm}-cap{cap}");
    cfg.algorithm = algorithm.into();
    cfg.rounds = 3;
    cfg.eval_every = 2;
    cfg.participation_mode = ParticipationMode::Uniform; // legacy stream
    cfg.warmup_rounds = 1; // onebit reaches its DeviceLocal phase
    cfg.residual_resident_cap = cap;
    cfg.residual_spill_dir = spill.into();
    let mut coord = build_coord(cfg);
    let log = coord.run().expect("run");
    let w = coord.global().w.clone();
    (log, w)
}

/// Every logged field outside `wall_secs` must match to the bit.
fn assert_logs_bit_identical(id: &str, dense: &ExperimentLog, spilled: &ExperimentLog) {
    assert_eq!(dense.rounds.len(), spilled.rounds.len(), "{id}: row count");
    for (a, b) in dense.rounds.iter().zip(&spilled.rounds) {
        let r = a.round;
        assert_eq!(a.round, b.round, "{id}");
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{id} r{r}");
        assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{id} r{r}");
        assert_eq!(
            a.test_accuracy.to_bits(),
            b.test_accuracy.to_bits(),
            "{id} r{r}"
        );
        assert_eq!(a.uplink_bits, b.uplink_bits, "{id} r{r}");
        assert_eq!(a.downlink_bits, b.downlink_bits, "{id} r{r}");
        assert_eq!(a.sim_secs.to_bits(), b.sim_secs.to_bits(), "{id} r{r}");
        assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits(), "{id} r{r}");
        assert_eq!(a.fleet_devices, b.fleet_devices, "{id} r{r}");
        assert_eq!(a.cohort_devices, b.cohort_devices, "{id} r{r}");
    }
}

/// The spill-tiering conformance leg; returns the ids exercised.
fn run_conformance() -> usize {
    let spill = std::env::temp_dir().join(format!("fedadam-fleet-spill-{}", std::process::id()));
    std::fs::create_dir_all(&spill).expect("spill dir");
    let spill_s = spill.to_string_lossy().into_owned();
    let mut ids: Vec<&str> = CONFORMANCE_ZOO.to_vec();
    if !ids.contains(&"fedadam-ssm-ef") {
        ids.push("fedadam-ssm-ef");
    }
    for id in &ids {
        let (dense_log, dense_w) = conformance_run(id, 0, "");
        let (spill_log, spill_w) = conformance_run(id, 2, &spill_s);
        assert_eq!(
            dense_w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            spill_w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{id}: final weights diverged under residual spilling"
        );
        assert_logs_bit_identical(id, &dense_log, &spill_log);
    }
    let _ = std::fs::remove_dir_all(&spill);
    ids.len()
}

fn flatness_asserts(cases: &[FleetCase]) -> BTreeMap<String, f64> {
    let base = &cases[0];
    let mut ratios = BTreeMap::new();
    for c in &cases[1..] {
        let ratio =
            c.median_round_ns.max(FLOOR_NS) / base.median_round_ns.max(FLOOR_NS);
        ratios.insert(format!("wall_{}_over_{}", c.fleet, base.fleet), ratio);
        assert!(
            ratio < FLAT_RATIO,
            "per-round wall-clock is not flat in fleet size: {} at fleet {} vs {} at fleet {} ({ratio:.2}x >= {FLAT_RATIO}x)",
            c.median_round_ns,
            c.fleet,
            base.median_round_ns,
            base.fleet,
        );
        if let (Some(g), Some(g0)) = (c.rss_round_growth_kb, base.rss_round_growth_kb) {
            let bound = (g0 * FLAT_RATIO).max(RSS_FLOOR_KB);
            assert!(
                g <= bound,
                "resident memory grew {g:.0} KiB across rounds at fleet {} (bound {bound:.0} KiB) — O(fleet) state is leaking into the round path",
                c.fleet,
            );
        }
    }
    ratios
}

/// Warn (never fail) when a fresh median regresses >10% vs `path`.
fn compare_with_baseline(path: &str, medians: &BTreeMap<String, f64>) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("no baseline at {path}: {e}");
            return;
        }
    };
    let base = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("unparseable baseline {path}: {e}");
            return;
        }
    };
    let Some(base_cases) = base.get("cases").and_then(|c| c.as_arr()) else {
        eprintln!("baseline {path} has no cases array");
        return;
    };
    let mut warned = false;
    for c in base_cases {
        let name = c.get("name").and_then(|v| v.as_str());
        let old = c.get("median_round_ns").and_then(|v| v.as_f64());
        let (Some(name), Some(old)) = (name, old) else {
            continue;
        };
        let Some(&new) = medians.get(name) else {
            continue;
        };
        let ratio = new / old.max(1.0);
        if ratio > 1.10 {
            warned = true;
            println!(
                "WARN: {name}: median round {:.2} ms vs baseline {:.2} ms (+{:.0}%)",
                new / 1e6,
                old / 1e6,
                (ratio - 1.0) * 100.0
            );
        } else {
            println!("ok: {name}: {ratio:.2}x baseline");
        }
    }
    if !warned {
        println!("no >10% wall-clock regressions vs {path}");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_on = args.iter().any(|a| a == "--json");
    let opt = |flag: &str| {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };

    let quick = std::env::var("FEDADAM_BENCH_QUICK").is_ok();
    let mut bench = from_env();
    bench.max_iters = 300;

    // ---- Scaling sweep (10⁶ is local-only: ~100 MB corpus + O(fleet)
    // registration make it too heavy for the CI lane) ----
    let mut fleets = vec![1_000usize, 100_000];
    if !quick {
        fleets.push(1_000_000);
    }
    let cases: Vec<FleetCase> = fleets
        .iter()
        .map(|&fleet| measure_fleet(&mut bench, fleet))
        .collect();
    for c in &cases {
        assert_eq!(
            c.cohort_devices, COHORT as u64,
            "fleet {}: cohort drifted from the constant workload",
            c.fleet
        );
    }
    let ratios = flatness_asserts(&cases);

    // ---- Spill-tiering conformance at fleet 10³ ----
    let zoo_ids = run_conformance();
    println!(
        "conformance: {zoo_ids} algorithm ids bit-identical dense vs spilled residuals"
    );

    bench.report("fleet scaling (reference backend)");
    for (name, r) in &ratios {
        println!("{name}: {r:.3}x");
    }

    if json_on {
        let out_path = opt("--json-out").unwrap_or_else(|| "BENCH_fleet_scaling.json".into());
        let baseline = opt("--baseline");
        let mut medians: BTreeMap<String, f64> = BTreeMap::new();
        let mut case_vals: Vec<Value> = Vec::new();
        for c in &cases {
            let name = format!("fleet-{}", c.fleet);
            medians.insert(name.clone(), c.median_round_ns);
            let mut obj = BTreeMap::new();
            obj.insert("name".into(), Value::Str(name));
            obj.insert("fleet".into(), Value::Num(c.fleet as f64));
            obj.insert("cohort".into(), Value::Num(c.cohort_devices as f64));
            obj.insert("median_round_ns".into(), Value::Num(c.median_round_ns));
            obj.insert(
                "rss_after_build_kb".into(),
                c.rss_after_build_kb.map(Value::Num).unwrap_or(Value::Null),
            );
            obj.insert(
                "rss_round_growth_kb".into(),
                c.rss_round_growth_kb.map(Value::Num).unwrap_or(Value::Null),
            );
            case_vals.push(Value::Obj(obj));
        }
        let mut flat = BTreeMap::new();
        for (name, r) in &ratios {
            flat.insert(name.clone(), Value::Num(*r));
        }
        let mut conf = BTreeMap::new();
        conf.insert("fleet".into(), Value::Num(1_000.0));
        conf.insert("ids".into(), Value::Num(zoo_ids as f64));
        conf.insert("bit_identical".into(), Value::Bool(true));
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Value::Str("fleet_scaling".into()));
        root.insert("backend".into(), Value::Str("reference-linear".into()));
        root.insert("algorithm".into(), Value::Str("fedadam-ssm-ef".into()));
        root.insert(
            "participation_mode".into(),
            Value::Str("importance".into()),
        );
        root.insert("flat_ratio_bound".into(), Value::Num(FLAT_RATIO));
        root.insert("cases".into(), Value::Arr(case_vals));
        root.insert("flatness".into(), Value::Obj(flat));
        root.insert("conformance".into(), Value::Obj(conf));
        let doc = Value::Obj(root);
        std::fs::write(&out_path, doc.render() + "\n").expect("writing bench json");
        println!("wrote {out_path}");
        if let Some(bp) = baseline {
            compare_with_baseline(&bp, &medians);
        }
    }
}
