//! Simulated wall-clock: deterministic *virtual* time for heterogeneous
//! federated rounds.
//!
//! The paper's pitch for sparse uplinks is ultimately **time-to-accuracy**
//! on bandwidth-constrained devices, a metric the real `wall_secs` column
//! (host CPU time of a CPU-scale reproduction) cannot measure.  This
//! module prices each round in *simulated seconds* instead:
//!
//! - **compute latency** per device: proportional to the samples one local
//!   round walks through (`batches/epoch × batch × local_epochs`) divided
//!   by a baseline throughput (`sim_samples_per_sec`), times a per-device
//!   slowdown factor drawn log-uniformly from `[1, sim_hetero]` — the
//!   stragglers;
//! - **uplink latency** per device: the compressed message's exact
//!   `wire_bits` divided by `sim_bandwidth_mbps` — this is where the SSM
//!   family's smaller uplinks shift the accuracy-vs-seconds frontier;
//! - **eval latency**: test-set size over the baseline throughput.
//!
//! A round finishes when its slowest participant's `compute + upload` has
//! landed ([`SimClock::advance_round`]); under the overlapped schedule
//! (`pipeline_depth >= 2`) an eval-due round's eval runs concurrently
//! with the next round's training, exactly mirroring the real pipelined
//! loop in [`crate::coordinator`].
//!
//! ## Determinism
//!
//! Virtual time is a pure function of the config, the data partition and
//! the per-round uplink bits — it **never reads the host clock**, so the
//! simulated column is byte-identical at any `num_workers` / `agg_shards`
//! (and across the barrier/streaming depths `0` and `1`, which share one
//! schedule).  The per-device slowdown factors come from their own
//! [`crate::rng::Rng`] stream seeded by `cfg.seed`, so a worker-count
//! change cannot perturb them.
//!
//! ```
//! use fedadam_ssm::simtime::SimClock;
//!
//! let mut barrier = SimClock::new(0);
//! let mut overlap = SimClock::new(2);
//! for _ in 0..3 {
//!     barrier.advance_round(2.0, Some(1.0));
//!     overlap.advance_round(2.0, Some(1.0));
//! }
//! assert_eq!(barrier.now(), 9.0); // train+upload and eval in series
//! assert_eq!(overlap.now(), 6.0); // evals hidden under the next round
//! assert_eq!(overlap.drain(), 7.0); // ... except the last one
//! ```

use crate::config::ExperimentConfig;
use crate::rng::Rng;

/// Stream tag for the per-device slowdown factors (domain-separated from
/// every other consumer of `cfg.seed`).
const SPEED_STREAM: u64 = 0x51b7_73a9_0c2d_4e01;

/// Deterministic per-device latency model (always constructed; the
/// `simtime` knob only gates the [`SimClock`] and the logged column).
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// Seconds of local compute per round, per device (slowdown applied).
    compute_secs: Vec<f64>,
    /// Uplink seconds per wire bit.
    secs_per_bit: f64,
    /// Seconds of one full test-set evaluation.
    eval_secs: f64,
}

impl LatencyModel {
    /// Build the model: `samples_per_round[i]` is the number of training
    /// samples device `i` walks through in one local round
    /// (`batches/epoch × batch × local_epochs`).
    pub fn new(
        cfg: &ExperimentConfig,
        samples_per_round: &[usize],
        test_samples: usize,
    ) -> LatencyModel {
        let mut rng = Rng::new(cfg.seed ^ SPEED_STREAM);
        let ln_hetero = cfg.sim_hetero.max(1.0).ln();
        let compute_secs = samples_per_round
            .iter()
            .map(|&samples| {
                // Log-uniform slowdown in [1, sim_hetero]: half the fleet
                // within sqrt(hetero) of the fastest, a heavy straggler tail.
                let slowdown = (rng.uniform() * ln_hetero).exp();
                samples as f64 * slowdown / cfg.sim_samples_per_sec
            })
            .collect();
        LatencyModel {
            compute_secs,
            secs_per_bit: 1.0 / (cfg.sim_bandwidth_mbps * 1e6),
            eval_secs: test_samples as f64 / cfg.sim_samples_per_sec,
        }
    }

    /// Seconds device `device` spends on one local training round.
    pub fn compute_secs(&self, device: usize) -> f64 {
        self.compute_secs[device]
    }

    /// Seconds one device spends uploading a `bits`-bit message.
    pub fn upload_secs(&self, bits: u64) -> f64 {
        bits as f64 * self.secs_per_bit
    }

    /// Seconds of one full test-set evaluation.
    pub fn eval_secs(&self) -> f64 {
        self.eval_secs
    }

    /// Every device's per-round compute seconds (the availability
    /// sampler's deadline ranking reads this).
    pub fn device_compute_secs(&self) -> &[f64] {
        &self.compute_secs
    }
}

/// The virtual round clock.
///
/// Two schedules, mirroring the real loop's `pipeline_depth` semantics:
///
/// - **barrier / streaming** (`depth <= 1`): eval runs inline, so an
///   eval-due round costs `train_upload + eval`;
/// - **overlapped** (`depth >= 2`): round `t`'s eval runs concurrently
///   with round `t+1`'s training, so each round costs
///   `max(train_upload, previous pending eval)` and the final pending
///   eval is folded in by [`Self::drain`].
#[derive(Clone, Debug)]
pub struct SimClock {
    now: f64,
    pending_eval: f64,
    overlap: bool,
}

impl SimClock {
    /// A clock for the given `pipeline_depth` (`>= 2` = overlapped).
    pub fn new(pipeline_depth: usize) -> SimClock {
        SimClock {
            now: 0.0,
            pending_eval: 0.0,
            overlap: pipeline_depth >= 2,
        }
    }

    /// Virtual seconds elapsed since round 0.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// `(now, pending_eval)` — the clock's full mutable state, for a
    /// coordinator snapshot (`overlap` is re-derived from the config).
    pub fn state(&self) -> (f64, f64) {
        (self.now, self.pending_eval)
    }

    /// Rebuild a clock at an exact saved state (inverse of
    /// [`SimClock::state`]; `pipeline_depth` must come from the same
    /// config the snapshot was taken under).
    pub fn from_state(pipeline_depth: usize, now: f64, pending_eval: f64) -> SimClock {
        SimClock {
            now,
            pending_eval,
            overlap: pipeline_depth >= 2,
        }
    }

    /// Advance over one round: `train_upload_secs` is the slowest
    /// participant's `compute + upload`; `eval` is `Some(secs)` on
    /// eval-due rounds.
    pub fn advance_round(&mut self, train_upload_secs: f64, eval: Option<f64>) {
        if self.overlap {
            self.now += train_upload_secs.max(self.pending_eval);
            self.pending_eval = eval.unwrap_or(0.0);
        } else {
            self.now += train_upload_secs + eval.unwrap_or(0.0);
        }
    }

    /// Fold in any still-pending overlapped eval (the run's last one has
    /// no next round to hide under); returns the final clock.
    pub fn drain(&mut self) -> f64 {
        self.now += self.pending_eval;
        self.pending_eval = 0.0;
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = 11;
        cfg.sim_samples_per_sec = 1000.0;
        cfg.sim_bandwidth_mbps = 1.0;
        cfg.sim_hetero = 4.0;
        cfg
    }

    #[test]
    fn latency_model_is_deterministic_and_bounded() {
        let samples = vec![500usize, 1000, 250, 800];
        let a = LatencyModel::new(&cfg(), &samples, 100);
        let b = LatencyModel::new(&cfg(), &samples, 100);
        for i in 0..samples.len() {
            assert_eq!(a.compute_secs(i).to_bits(), b.compute_secs(i).to_bits());
            // slowdown in [1, hetero]: compute in [samples/sps, hetero * that]
            let base = samples[i] as f64 / 1000.0;
            assert!(a.compute_secs(i) >= base, "device {i}");
            assert!(a.compute_secs(i) <= base * 4.0 + 1e-12, "device {i}");
        }
        assert_eq!(a.device_compute_secs().len(), samples.len());
        // 1 Mbit at 1 Mbit/s = 1 second.
        assert!((a.upload_secs(1_000_000) - 1.0).abs() < 1e-12);
        assert!((a.eval_secs() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_fleet_has_unit_slowdown() {
        let mut c = cfg();
        c.sim_hetero = 1.0;
        let m = LatencyModel::new(&c, &[100, 100], 10);
        assert_eq!(m.compute_secs(0).to_bits(), m.compute_secs(1).to_bits());
        assert!((m.compute_secs(0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn different_seeds_draw_different_stragglers() {
        let samples = vec![1000usize; 8];
        let a = LatencyModel::new(&cfg(), &samples, 10);
        let mut c2 = cfg();
        c2.seed = 12;
        let b = LatencyModel::new(&c2, &samples, 10);
        assert!(
            (0..8).any(|i| a.compute_secs(i) != b.compute_secs(i)),
            "seed must steer the straggler draw"
        );
    }

    #[test]
    fn barrier_clock_serializes_eval() {
        let mut c = SimClock::new(0);
        c.advance_round(2.0, Some(0.5));
        assert_eq!(c.now(), 2.5);
        c.advance_round(3.0, None);
        assert_eq!(c.now(), 5.5);
        assert_eq!(c.drain(), 5.5, "barrier never has a pending eval");
        // depth 1 (streaming aggregation) shares the barrier schedule.
        let mut s = SimClock::new(1);
        s.advance_round(2.0, Some(0.5));
        assert_eq!(s.now(), 2.5);
    }

    #[test]
    fn overlapped_clock_hides_eval_under_training() {
        let mut c = SimClock::new(2);
        c.advance_round(2.0, Some(1.5)); // eval pends
        assert_eq!(c.now(), 2.0);
        c.advance_round(1.0, Some(0.5)); // prev eval (1.5) gates this round
        assert_eq!(c.now(), 3.5);
        c.advance_round(2.0, None); // train (2.0) > pending (0.5)
        assert_eq!(c.now(), 5.5);
        assert_eq!(c.drain(), 5.5);
        // A still-pending last eval is drained, not dropped.
        let mut d = SimClock::new(3);
        d.advance_round(1.0, Some(2.0));
        assert_eq!(d.drain(), 3.0);
    }

    #[test]
    fn clock_state_roundtrips_bit_exact() {
        let mut c = SimClock::new(2);
        c.advance_round(2.0, Some(1.5));
        c.advance_round(0.3, Some(0.7));
        let (now, pending) = c.state();
        let mut restored = SimClock::from_state(2, now, pending);
        c.advance_round(1.0, None);
        restored.advance_round(1.0, None);
        assert_eq!(c.now().to_bits(), restored.now().to_bits());
        assert_eq!(c.drain().to_bits(), restored.drain().to_bits());
    }

    #[test]
    fn overlap_is_never_slower_than_barrier() {
        // Same per-round costs: the overlapped schedule's total is <= the
        // barrier total (max(a, b) <= a + b for non-negative costs).
        let rounds = [(2.0, Some(0.7)), (1.0, None), (3.0, Some(0.7)), (0.5, Some(0.7))];
        let mut barrier = SimClock::new(0);
        let mut overlap = SimClock::new(2);
        for &(t, e) in &rounds {
            barrier.advance_round(t, e);
            overlap.advance_round(t, e);
        }
        assert!(overlap.drain() <= barrier.drain());
    }
}
