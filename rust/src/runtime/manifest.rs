//! `artifacts/manifest.json` — the contract between `compile/aot.py` and
//! the rust runtime: model dimensions, program shapes, artifact files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Value};

/// Adam constants baked into the artifacts (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// One exported model's metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    /// Flat parameter count `d`.
    pub dim: usize,
    /// `[h, w, c]`.
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    /// Train/sgd/grads batch size `B`.
    pub batch: usize,
    /// Eval program batch size `E`.
    pub eval_batch: usize,
    /// Batches per `epoch` program invocation.
    pub epoch_batches: usize,
    /// program name -> artifact file name.
    pub artifacts: BTreeMap<String, String>,
}

impl ModelMeta {
    /// Image element count `h*w*c`.
    pub fn row(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Path of one program's HLO text.
    pub fn artifact_path(&self, dir: &Path, prog: &str) -> Result<PathBuf> {
        let f = self
            .artifacts
            .get(prog)
            .ok_or_else(|| anyhow!("model {} has no program {prog:?}", self.name))?;
        Ok(dir.join(f))
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub adam: AdamConfig,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts` first", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let adam_v = root.expect("adam").map_err(|e| anyhow!("{e}"))?;
        let adam = AdamConfig {
            beta1: field_f64(adam_v, "beta1")?,
            beta2: field_f64(adam_v, "beta2")?,
            eps: field_f64(adam_v, "eps")?,
        };

        let mut models = BTreeMap::new();
        let models_v = root
            .expect("models")
            .map_err(|e| anyhow!("{e}"))?
            .as_obj()
            .ok_or_else(|| anyhow!("models is not an object"))?;
        for (name, mv) in models_v {
            let artifacts = mv
                .expect("artifacts")
                .map_err(|e| anyhow!("{name}: {e}"))?
                .as_obj()
                .ok_or_else(|| anyhow!("{name}: artifacts not an object"))?
                .iter()
                .map(|(prog, av)| {
                    let file = av
                        .get("file")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("{name}/{prog}: missing file"))?;
                    Ok((prog.clone(), file.to_string()))
                })
                .collect::<Result<BTreeMap<_, _>>>()?;
            let input_shape = mv
                .expect("input_shape")
                .map_err(|e| anyhow!("{name}: {e}"))?
                .as_arr()
                .ok_or_else(|| anyhow!("{name}: input_shape not an array"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("{name}: bad dim")))
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    dim: field_usize(mv, name, "dim")?,
                    input_shape,
                    num_classes: field_usize(mv, name, "num_classes")?,
                    batch: field_usize(mv, name, "batch")?,
                    eval_batch: field_usize(mv, name, "eval_batch")?,
                    epoch_batches: field_usize(mv, name, "epoch_batches")?,
                    artifacts,
                },
            );
        }
        Ok(Manifest { dir, adam, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest (have: {:?}); re-run `make artifacts MODELS=...`",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn field_f64(v: &Value, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Value::as_f64)
        .ok_or_else(|| anyhow!("missing/invalid {key}"))
}

fn field_usize(v: &Value, model: &str, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("{model}: missing/invalid {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("fedadam-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "format": "hlo-text/v1",
              "adam": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-06},
              "models": {
                "m": {
                  "dim": 10, "input_shape": [2,2,1], "num_classes": 10,
                  "batch": 4, "eval_batch": 8, "epoch_batches": 2,
                  "params": [],
                  "artifacts": {"train": {"file": "train_m.hlo.txt", "sha256": "x", "bytes": 1}}
                }
              }
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!((m.adam.beta2 - 0.999).abs() < 1e-12);
        let meta = m.model("m").unwrap();
        assert_eq!(meta.dim, 10);
        assert_eq!(meta.row(), 4);
        assert!(meta
            .artifact_path(&m.dir, "train")
            .unwrap()
            .ends_with("train_m.hlo.txt"));
        assert!(meta.artifact_path(&m.dir, "nope").is_err());
        assert!(m.model("absent").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
