//! Server-side aggregation (FedAvg over possibly-sparse uploads) and
//! global state management (Algorithm 2, server lines).
//!
//! Two reduction paths share one determinism contract:
//! - [`aggregate_sharded`] — the batch path: all uploads present, lane
//!   shards reduced on scoped threads;
//! - [`ShardedAccumulator`] — the streaming path: uploads folded into
//!   per-shard partial sums **one at a time as they land**, with the
//!   per-lane association order fixed by device slot (out-of-order
//!   arrivals are buffered until their turn), so the finalized
//!   [`Aggregate`] is bit-identical to the batch path on the full cohort.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::algorithms::{Aggregate, Recon, Upload};
use crate::tensor;
use crate::util::bytes::{ByteReader, ByteWriter};

/// The server's global model + moment estimates.
#[derive(Clone, Debug)]
pub struct GlobalState {
    pub w: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl GlobalState {
    pub fn new(w0: Vec<f32>) -> Self {
        let d = w0.len();
        GlobalState {
            w: w0,
            m: vec![0.0; d],
            v: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Apply the aggregated round update (`W += ΔŴ` etc.; moments only
    /// when the algorithm aggregated them).
    pub fn apply(&mut self, agg: &Aggregate) {
        tensor::add_assign(&mut self.w, &agg.dw);
        if let Some(dm) = &agg.dm {
            tensor::add_assign(&mut self.m, dm);
        }
        if let Some(dv) = &agg.dv {
            tensor::add_assign(&mut self.v, dv);
        }
    }

    /// Serialize `(W, M, V)` bit-exactly into a journal snapshot.
    pub fn save_state(&self, out: &mut ByteWriter) {
        out.put_f32s(&self.w);
        out.put_f32s(&self.m);
        out.put_f32s(&self.v);
    }

    /// Restore the triple written by [`Self::save_state`].
    pub fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let dim = self.dim();
        self.w = input.take_f32s()?;
        self.m = input.take_f32s()?;
        self.v = input.take_f32s()?;
        ensure!(
            self.w.len() == dim && self.m.len() == dim && self.v.len() == dim,
            "snapshot global state dim {} != model dim {dim}",
            self.w.len()
        );
        Ok(())
    }
}

/// Size of the union of the given payloads' supports restricted to the
/// lane range `[lo, hi)`.
///
/// A dense payload covers every lane in the range.  A sparse payload's
/// support is its **stored index set** — including lanes whose stored
/// value is exactly `0.0`, because those lanes were transmitted (and
/// priced) on the wire.
fn union_support_range<'a>(
    lo: usize,
    hi: usize,
    recons: impl Iterator<Item = &'a Recon>,
) -> usize {
    let mut seen = vec![false; hi - lo];
    let mut count = 0usize;
    for r in recons {
        match r {
            Recon::Dense(_) => return hi - lo,
            Recon::Sparse(sv) => {
                let (a, b) = sv.index_range(lo as u32, hi as u32);
                for &i in &sv.indices[a..b] {
                    let j = i as usize - lo;
                    if !seen[j] {
                        seen[j] = true;
                        count += 1;
                    }
                }
            }
        }
    }
    count
}

/// `out[i - lo] += coef * r[i]` for every stored lane `i ∈ [lo, hi)`.
fn axpy_range(r: &Recon, out: &mut [f32], coef: f32, lo: usize, hi: usize) {
    match r {
        Recon::Dense(v) => {
            for (o, x) in out.iter_mut().zip(&v[lo..hi]) {
                *o += coef * x;
            }
        }
        Recon::Sparse(sv) => {
            let (a, b) = sv.index_range(lo as u32, hi as u32);
            for t in a..b {
                out[sv.indices[t] as usize - lo] += coef * sv.values[t];
            }
        }
    }
}

/// One lane shard's accumulated segment + support counts.
struct ShardAgg {
    dw: Vec<f32>,
    dm: Option<Vec<f32>>,
    dv: Option<Vec<f32>>,
    dw_support: usize,
    dm_support: usize,
    dv_support: usize,
}

/// Reduce the uploads over the lane range `[lo, hi)` only.
///
/// Per lane, the accumulation order is exactly the upload order — the
/// same association order as the 1-shard reduce — so stitching shard
/// segments back in ascending lane order reproduces the sequential
/// result bit for bit.
fn reduce_shard(
    uploads: &[Upload],
    coefs: &[f32],
    lo: usize,
    hi: usize,
    any_m: bool,
    any_v: bool,
) -> ShardAgg {
    let n = hi - lo;
    let mut dw = vec![0.0f32; n];
    let mut dm = if any_m { Some(vec![0.0f32; n]) } else { None };
    let mut dv = if any_v { Some(vec![0.0f32; n]) } else { None };
    for (u, &coef) in uploads.iter().zip(coefs) {
        axpy_range(&u.dw, &mut dw, coef, lo, hi);
        if let (Some(acc), Some(r)) = (dm.as_deref_mut(), u.dm.as_ref()) {
            axpy_range(r, acc, coef, lo, hi);
        }
        if let (Some(acc), Some(r)) = (dv.as_deref_mut(), u.dv.as_ref()) {
            axpy_range(r, acc, coef, lo, hi);
        }
    }
    ShardAgg {
        dw_support: union_support_range(lo, hi, uploads.iter().map(|u| &u.dw)),
        dm_support: union_support_range(lo, hi, uploads.iter().filter_map(|u| u.dm.as_ref())),
        dv_support: union_support_range(lo, hi, uploads.iter().filter_map(|u| u.dv.as_ref())),
        dw,
        dm,
        dv,
    }
}

/// Weighted FedAvg over uploads (sparse uploads accumulate sparsely —
/// the reduce is `O(Σ nnz)` not `O(N·d)`).  Single-shard convenience
/// wrapper around [`aggregate_sharded`].
///
/// The returned [`Aggregate`] also carries the union support size of each
/// vector so downlink pricing survives exact-zero cancellations.
pub fn aggregate(uploads: &[Upload], dim: usize) -> Aggregate {
    aggregate_sharded(uploads, dim, 1)
}

/// Sharded weighted FedAvg: partition the lane space `[0, dim)` into
/// `shards` fixed contiguous ranges, reduce each range on its own scoped
/// thread, then stitch the segments back in ascending lane order.
///
/// Determinism contract: every f32 lane sum has a fixed association order
/// (upload order, per lane), independent of `shards` and of scheduling —
/// the result is **bit-identical** to the sequential reduce at any shard
/// count.  `shards` is clamped to `[1, dim]`; `1` runs inline with no
/// thread spawn.
pub fn aggregate_sharded(uploads: &[Upload], dim: usize, shards: usize) -> Aggregate {
    let total: f64 = uploads.iter().map(|u| u.weight).sum();
    let coefs: Vec<f32> = uploads
        .iter()
        .map(|u| if total > 0.0 { (u.weight / total) as f32 } else { 0.0 })
        .collect();
    let any_m = uploads.iter().any(|u| u.dm.is_some());
    let any_v = uploads.iter().any(|u| u.dv.is_some());
    let shards = shards.clamp(1, dim.max(1));

    let parts: Vec<ShardAgg> = if shards == 1 {
        vec![reduce_shard(uploads, &coefs, 0, dim, any_m, any_v)]
    } else {
        // Balanced contiguous ranges: shard s covers
        // [s·dim/shards, (s+1)·dim/shards).
        let bounds: Vec<(usize, usize)> = (0..shards)
            .map(|s| (s * dim / shards, (s + 1) * dim / shards))
            .collect();
        // Strided shard→thread assignment; which thread reduces a shard
        // cannot change its bits, only its schedule.
        let nthreads = shards
            .min(
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(1),
            )
            .max(1);
        let mut slots: Vec<Option<ShardAgg>> = (0..shards).map(|_| None).collect();
        std::thread::scope(|scope| {
            let bounds = &bounds;
            let coefs = &coefs;
            let handles: Vec<_> = (0..nthreads)
                .map(|t| {
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut s = t;
                        while s < shards {
                            let (lo, hi) = bounds[s];
                            out.push((s, reduce_shard(uploads, coefs, lo, hi, any_m, any_v)));
                            s += nthreads;
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                let results = h.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
                for (s, sa) in results {
                    slots[s] = Some(sa);
                }
            }
        });
        slots
            .into_iter()
            .map(|s| s.expect("every shard reduced"))
            .collect()
    };

    // Stitch in ascending lane order.
    let mut dw = Vec::with_capacity(dim);
    let mut dm = if any_m { Some(Vec::with_capacity(dim)) } else { None };
    let mut dv = if any_v { Some(Vec::with_capacity(dim)) } else { None };
    let (mut dw_support, mut dm_support, mut dv_support) = (0usize, 0usize, 0usize);
    for part in parts {
        dw.extend_from_slice(&part.dw);
        if let (Some(acc), Some(seg)) = (dm.as_mut(), part.dm) {
            acc.extend_from_slice(&seg);
        }
        if let (Some(acc), Some(seg)) = (dv.as_mut(), part.dv) {
            acc.extend_from_slice(&seg);
        }
        dw_support += part.dw_support;
        dm_support += part.dm_support;
        dv_support += part.dv_support;
    }
    Aggregate {
        dw,
        dm,
        dv,
        dw_support,
        dm_support,
        dv_support,
    }
}

/// Incremental union-support bitmap: one seen-flag segment per lane shard.
struct SupportTracker {
    seen: Vec<Vec<bool>>,
    count: usize,
}

impl SupportTracker {
    fn new(bounds: &[(usize, usize)]) -> SupportTracker {
        SupportTracker {
            seen: bounds.iter().map(|&(lo, hi)| vec![false; hi - lo]).collect(),
            count: 0,
        }
    }

    /// Mark `r`'s stored lanes within shard `s` = `[lo, hi)`.  A dense
    /// payload covers the whole range; a sparse payload's support is its
    /// stored index set, including exact-`0.0` values (they were
    /// transmitted and priced) — the same rule as [`union_support_range`].
    fn mark(&mut self, s: usize, lo: usize, hi: usize, r: &Recon) {
        let seen = &mut self.seen[s];
        let mut added = 0usize;
        match r {
            Recon::Dense(_) => {
                for flag in seen.iter_mut() {
                    if !*flag {
                        *flag = true;
                        added += 1;
                    }
                }
            }
            Recon::Sparse(sv) => {
                let (a, b) = sv.index_range(lo as u32, hi as u32);
                for &i in &sv.indices[a..b] {
                    let flag = &mut seen[i as usize - lo];
                    if !*flag {
                        *flag = true;
                        added += 1;
                    }
                }
            }
        }
        self.count += added;
    }
}

/// Streaming sharded FedAvg: the same weighted reduce as
/// [`aggregate_sharded`], but folded **one upload at a time** into
/// per-shard partial sums, so the server can aggregate while later
/// devices are still training.
///
/// Determinism contract: per lane, the fold order is the device **slot**
/// order (`0..n`, the position in the round's participant list) — exactly
/// the upload order of the batch reduce.  Uploads may be pushed in any
/// order; an early arrival is buffered until every lower slot has been
/// folded.  FedAvg coefficients come from the cohort weights given at
/// construction (known before any training finishes), computed with the
/// identical `f64`-sum-then-`f32`-cast as the batch path.  The finalized
/// [`Aggregate`] — values and union supports — is therefore
/// **bit-identical** to `aggregate_sharded(&uploads, dim, shards)` on the
/// full cohort, at any shard count and any arrival order.
pub struct ShardedAccumulator {
    /// Fixed contiguous lane ranges, ascending (shard `s` covers
    /// `[s·dim/shards, (s+1)·dim/shards)`).
    bounds: Vec<(usize, usize)>,
    /// Cohort FedAvg weights by slot; `coefs[i] = (weights[i] / Σw) as f32`.
    weights: Vec<f64>,
    coefs: Vec<f32>,
    /// Slots `[0, next)` are folded.
    next: usize,
    /// Early arrivals waiting for their fold turn, keyed by slot.
    pending: BTreeMap<usize, Upload>,
    /// Per-shard running segment sums (`ΔM̂`/`ΔV̂` allocated lazily on the
    /// first upload that carries them — earlier uploads without moments
    /// contribute nothing, so late zero-init is bit-neutral).
    dw: Vec<Vec<f32>>,
    dm: Option<Vec<Vec<f32>>>,
    dv: Option<Vec<Vec<f32>>>,
    support_w: SupportTracker,
    support_m: SupportTracker,
    support_v: SupportTracker,
}

impl ShardedAccumulator {
    /// Build an accumulator for a cohort of `weights.len()` uploads over
    /// lane space `[0, dim)` split into `shards` contiguous ranges
    /// (clamped to `[1, dim]`, like [`aggregate_sharded`]).
    pub fn new(dim: usize, shards: usize, weights: &[f64]) -> ShardedAccumulator {
        let shards = shards.clamp(1, dim.max(1));
        let total: f64 = weights.iter().sum();
        let coefs: Vec<f32> = weights
            .iter()
            .map(|&w| if total > 0.0 { (w / total) as f32 } else { 0.0 })
            .collect();
        let bounds: Vec<(usize, usize)> = (0..shards)
            .map(|s| (s * dim / shards, (s + 1) * dim / shards))
            .collect();
        ShardedAccumulator {
            dw: bounds.iter().map(|&(lo, hi)| vec![0.0f32; hi - lo]).collect(),
            dm: None,
            dv: None,
            support_w: SupportTracker::new(&bounds),
            support_m: SupportTracker::new(&bounds),
            support_v: SupportTracker::new(&bounds),
            bounds,
            weights: weights.to_vec(),
            coefs,
            next: 0,
            pending: BTreeMap::new(),
        }
    }

    /// Cohort size this accumulator was built for.
    pub fn expected(&self) -> usize {
        self.weights.len()
    }

    /// Uploads folded so far (buffered early arrivals not included).
    pub fn folded(&self) -> usize {
        self.next
    }

    /// Hand over slot `slot`'s upload.  Folds it immediately when every
    /// lower slot has already been folded, otherwise buffers it; then
    /// drains any buffered successors that became ready.
    ///
    /// Panics on an out-of-range or duplicate slot — both are coordinator
    /// bugs that would silently corrupt the reduce.
    pub fn push(&mut self, slot: usize, upload: Upload) {
        assert!(
            slot < self.weights.len(),
            "slot {slot} out of range for a {}-upload cohort",
            self.weights.len()
        );
        assert!(
            slot >= self.next && !self.pending.contains_key(&slot),
            "slot {slot} pushed twice"
        );
        debug_assert_eq!(
            upload.weight.to_bits(),
            self.weights[slot].to_bits(),
            "slot {slot}: upload weight drifted from the cohort weight"
        );
        self.pending.insert(slot, upload);
        while let Some(u) = self.pending.remove(&self.next) {
            let coef = self.coefs[self.next];
            self.fold(&u, coef);
            self.next += 1;
        }
    }

    /// `segments[s] += coef * u[bounds[s]]` for every shard, plus support
    /// marking — the same per-lane association order as [`reduce_shard`].
    fn fold(&mut self, u: &Upload, coef: f32) {
        if u.dm.is_some() && self.dm.is_none() {
            self.dm = Some(
                self.bounds
                    .iter()
                    .map(|&(lo, hi)| vec![0.0f32; hi - lo])
                    .collect(),
            );
        }
        if u.dv.is_some() && self.dv.is_none() {
            self.dv = Some(
                self.bounds
                    .iter()
                    .map(|&(lo, hi)| vec![0.0f32; hi - lo])
                    .collect(),
            );
        }
        for s in 0..self.bounds.len() {
            let (lo, hi) = self.bounds[s];
            axpy_range(&u.dw, &mut self.dw[s], coef, lo, hi);
            self.support_w.mark(s, lo, hi, &u.dw);
            if let (Some(segs), Some(r)) = (self.dm.as_mut(), u.dm.as_ref()) {
                axpy_range(r, &mut segs[s], coef, lo, hi);
                self.support_m.mark(s, lo, hi, r);
            }
            if let (Some(segs), Some(r)) = (self.dv.as_mut(), u.dv.as_ref()) {
                axpy_range(r, &mut segs[s], coef, lo, hi);
                self.support_v.mark(s, lo, hi, r);
            }
        }
    }

    /// Stitch the shard segments back in ascending lane order.
    ///
    /// Panics unless every slot of the cohort has been folded — finalizing
    /// a partial round would silently drop device updates.
    pub fn finalize(self) -> Aggregate {
        assert_eq!(
            self.next,
            self.weights.len(),
            "finalize with {}/{} uploads folded",
            self.next,
            self.weights.len()
        );
        let dim = self.bounds.last().map(|&(_, hi)| hi).unwrap_or(0);
        fn stitch(dim: usize, segments: Vec<Vec<f32>>) -> Vec<f32> {
            let mut out = Vec::with_capacity(dim);
            for seg in segments {
                out.extend_from_slice(&seg);
            }
            out
        }
        Aggregate {
            dw: stitch(dim, self.dw),
            dm: self.dm.map(|segs| stitch(dim, segs)),
            dv: self.dv.map(|segs| stitch(dim, segs)),
            dw_support: self.support_w.count,
            dm_support: self.support_m.count,
            dv_support: self.support_v.count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Recon;
    use crate::sparse::SparseVec;

    #[test]
    fn weighted_fedavg_dense() {
        let uploads = vec![
            Upload {
                dw: Recon::Dense(vec![1.0, 1.0]),
                dm: Some(Recon::Dense(vec![2.0, 0.0])),
                dv: None,
                weight: 3.0,
                bits: 0,
            },
            Upload {
                dw: Recon::Dense(vec![0.0, 2.0]),
                dm: Some(Recon::Dense(vec![0.0, 2.0])),
                dv: None,
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 2);
        assert!((agg.dw[0] - 0.75).abs() < 1e-6);
        assert!((agg.dw[1] - 1.25).abs() < 1e-6);
        let dm = agg.dm.as_ref().unwrap();
        assert!((dm[0] - 1.5).abs() < 1e-6);
        assert!((dm[1] - 0.5).abs() < 1e-6);
        assert!(agg.dv.is_none());
        // Dense uploads cover every lane; no ΔV was uploaded at all.
        assert_eq!(agg.dw_support, 2);
        assert_eq!(agg.dm_support, 2);
        assert_eq!(agg.dv_support, 0);
    }

    #[test]
    fn sparse_uploads_aggregate() {
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 4,
                indices: i,
                values: v,
            })
        };
        let uploads = vec![
            Upload {
                dw: sv(vec![0], vec![4.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![0, 3], vec![2.0, 2.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 4);
        assert_eq!(agg.dw, vec![3.0, 0.0, 0.0, 1.0]);
        assert_eq!(agg.dw_support, 2); // union {0, 3}
    }

    #[test]
    fn support_survives_exact_cancellation() {
        // Two devices upload lane 1 with values that cancel exactly, and
        // device 0 stores a true-zero payload at lane 2.  The summed vector
        // is non-zero only at lane 0, but THREE lanes went over the wire —
        // the broadcast support must price all of them.
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 4,
                indices: i,
                values: v,
            })
        };
        let uploads = vec![
            Upload {
                dw: sv(vec![0, 1, 2], vec![1.0, 1.0, 0.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![1], vec![-1.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 4);
        assert_eq!(agg.dw, vec![0.5, 0.0, 0.0, 0.0]);
        let recount = agg.dw.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(recount, 1, "cancellation collapses the naive recount");
        assert_eq!(agg.dw_support, 3, "wire support must survive it");
    }

    #[test]
    fn apply_updates_state() {
        let mut gs = GlobalState::new(vec![1.0, 1.0]);
        gs.apply(&Aggregate {
            dw: vec![0.5, -0.5],
            dm: Some(vec![1.0, 0.0]),
            dv: None,
            dw_support: 2,
            dm_support: 2,
            dv_support: 0,
        });
        assert_eq!(gs.w, vec![1.5, 0.5]);
        assert_eq!(gs.m, vec![1.0, 0.0]);
        assert_eq!(gs.v, vec![0.0, 0.0]);
    }

    #[test]
    fn sharded_reduce_is_bit_identical_to_sequential() {
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 9,
                indices: i,
                values: v,
            })
        };
        // Mixed dense/sparse, exact-zero stored lanes, cancelling values,
        // uneven weights — the stress mix the property tests randomize.
        let uploads = vec![
            Upload {
                dw: sv(vec![0, 4, 5], vec![1.0, 0.0, 2.5]),
                dm: Some(Recon::Dense(vec![0.1; 9])),
                dv: None,
                weight: 2.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![4, 8], vec![-3.0, 7.0]),
                dm: Some(sv(vec![2], vec![0.0])),
                dv: Some(sv(vec![6], vec![1.0])),
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: Recon::Dense((0..9).map(|i| i as f32 * 0.3).collect()),
                dm: None,
                dv: Some(Recon::Dense(vec![-0.5; 9])),
                weight: 0.5,
                bits: 0,
            },
        ];
        let base = aggregate_sharded(&uploads, 9, 1);
        for shards in [2usize, 3, 4, 7, 9, 100] {
            let s = aggregate_sharded(&uploads, 9, shards);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&s.dw), bits(&base.dw), "{shards} shards: dw");
            assert_eq!(
                s.dm.as_deref().map(bits),
                base.dm.as_deref().map(bits),
                "{shards} shards: dm"
            );
            assert_eq!(
                s.dv.as_deref().map(bits),
                base.dv.as_deref().map(bits),
                "{shards} shards: dv"
            );
            assert_eq!(s.dw_support, base.dw_support, "{shards} shards");
            assert_eq!(s.dm_support, base.dm_support, "{shards} shards");
            assert_eq!(s.dv_support, base.dv_support, "{shards} shards");
        }
        // Dense upload present ⇒ dw support covers every lane.
        assert_eq!(base.dw_support, 9);
        // dm came from one dense + one sparse upload ⇒ also full.
        assert_eq!(base.dm_support, 9);
        // dv union: lane 6 sparse ∪ dense = full.
        assert_eq!(base.dv_support, 9);
    }

    #[test]
    fn zero_total_weight_is_safe() {
        let uploads = vec![Upload {
            dw: Recon::Dense(vec![1.0]),
            dm: None,
            dv: None,
            weight: 0.0,
            bits: 0,
        }];
        let agg = aggregate(&uploads, 1);
        assert_eq!(agg.dw, vec![0.0]);
        assert_eq!(agg.dw_support, 1);
    }

    /// The streaming-path stress cohort: mixed dense/sparse, exact-zero
    /// stored lanes, cancelling values, a moments-free first upload (lazy
    /// ΔM̂/ΔV̂ allocation), uneven weights.
    fn stream_uploads() -> Vec<Upload> {
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 9,
                indices: i,
                values: v,
            })
        };
        vec![
            Upload {
                dw: sv(vec![0, 4, 5], vec![1.0, 0.0, 2.5]),
                dm: None,
                dv: None,
                weight: 2.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![4, 8], vec![-3.0, 7.0]),
                dm: Some(sv(vec![2], vec![0.0])),
                dv: Some(sv(vec![6], vec![1.0])),
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: Recon::Dense((0..9).map(|i| i as f32 * 0.3).collect()),
                dm: None,
                dv: Some(Recon::Dense(vec![-0.5; 9])),
                weight: 0.5,
                bits: 0,
            },
            Upload {
                dw: sv(vec![0, 4], vec![-1.0, 3.0]), // cancels slot 1's lane 4
                dm: Some(Recon::Dense(vec![0.25; 9])),
                dv: None,
                weight: 1.5,
                bits: 0,
            },
        ]
    }

    fn assert_same_bits(a: &Aggregate, b: &Aggregate, tag: &str) {
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.dw), bits(&b.dw), "{tag}: dw");
        assert_eq!(
            a.dm.as_deref().map(bits),
            b.dm.as_deref().map(bits),
            "{tag}: dm"
        );
        assert_eq!(
            a.dv.as_deref().map(bits),
            b.dv.as_deref().map(bits),
            "{tag}: dv"
        );
        assert_eq!(a.dw_support, b.dw_support, "{tag}: dw support");
        assert_eq!(a.dm_support, b.dm_support, "{tag}: dm support");
        assert_eq!(a.dv_support, b.dv_support, "{tag}: dv support");
    }

    #[test]
    fn accumulator_in_order_matches_batch_aggregate() {
        let uploads = stream_uploads();
        let weights: Vec<f64> = uploads.iter().map(|u| u.weight).collect();
        for shards in [1usize, 2, 3, 7, 9, 100] {
            let base = aggregate_sharded(&uploads, 9, shards);
            let mut acc = ShardedAccumulator::new(9, shards, &weights);
            assert_eq!(acc.expected(), uploads.len());
            for (slot, u) in uploads.iter().enumerate() {
                acc.push(slot, u.clone());
                assert_eq!(acc.folded(), slot + 1, "in-order push folds eagerly");
            }
            assert_same_bits(&acc.finalize(), &base, &format!("{shards} shards"));
        }
    }

    #[test]
    fn accumulator_buffers_out_of_order_arrivals() {
        let uploads = stream_uploads();
        let weights: Vec<f64> = uploads.iter().map(|u| u.weight).collect();
        let base = aggregate_sharded(&uploads, 9, 1);
        // Worst-case arrival order: last device lands first.
        let mut acc = ShardedAccumulator::new(9, 3, &weights);
        for slot in (0..uploads.len()).rev() {
            let before = acc.folded();
            acc.push(slot, uploads[slot].clone());
            if slot > 0 {
                assert_eq!(acc.folded(), before, "early slot {slot} must buffer");
            }
        }
        assert_eq!(acc.folded(), uploads.len(), "slot 0 drains the buffer");
        assert_same_bits(&acc.finalize(), &base, "reverse arrival");
    }

    #[test]
    #[should_panic(expected = "pushed twice")]
    fn accumulator_rejects_duplicate_slot() {
        let uploads = stream_uploads();
        let weights: Vec<f64> = uploads.iter().map(|u| u.weight).collect();
        let mut acc = ShardedAccumulator::new(9, 2, &weights);
        acc.push(1, uploads[1].clone());
        acc.push(1, uploads[1].clone());
    }

    #[test]
    #[should_panic(expected = "uploads folded")]
    fn accumulator_rejects_partial_finalize() {
        let uploads = stream_uploads();
        let weights: Vec<f64> = uploads.iter().map(|u| u.weight).collect();
        let mut acc = ShardedAccumulator::new(9, 2, &weights);
        acc.push(0, uploads[0].clone());
        let _ = acc.finalize();
    }

    #[test]
    fn accumulator_zero_total_weight_is_safe() {
        let upload = Upload {
            dw: Recon::Dense(vec![1.0, 2.0]),
            dm: None,
            dv: None,
            weight: 0.0,
            bits: 0,
        };
        let mut acc = ShardedAccumulator::new(2, 1, &[0.0]);
        acc.push(0, upload);
        let agg = acc.finalize();
        assert_eq!(agg.dw, vec![0.0, 0.0]);
        assert_eq!(agg.dw_support, 2);
    }
}
