//! §IV communication-model bench + verification table.
//!
//! Prints the paper's uplink cost for every scheme across models and α,
//! verifying the headline `O(3dq) → O(3kq+3d) → O(3kq+d)` reduction, and
//! times the real wire codecs (encode+decode round trips).
//!
//! Run: `cargo bench --bench comm_cost`.

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::codec::{self, cost};
use fedadam_ssm::sparse::{top_k_indices, SparseVec};

fn main() {
    // --- cost table (exact, no timing) ----------------------------------
    println!("=== §IV uplink bits per device/round (q = 32) ===");
    println!(
        "{:>10} {:>7} {:>14} {:>14} {:>14} {:>14} {:>12} {:>14}",
        "d", "alpha", "FedAdam", "FedAdam-Top", "FedAdam-SSM", "SSM-Q(16)", "1-bit", "Efficient(16)"
    );
    for &d in &[54_314usize, 176_778, 1_663_370, 9_750_922] {
        for &alpha in &[0.01f64, 0.05, 0.2] {
            let k = (d as f64 * alpha) as usize;
            println!(
                "{:>10} {:>7} {:>14} {:>14} {:>14} {:>14} {:>12} {:>14}",
                d,
                alpha,
                cost::fedadam_dense(d),
                cost::fedadam_top(d, k),
                cost::fedadam_ssm(d, k),
                cost::fedadam_ssm_q(d, k, 16),
                cost::onebit(d),
                cost::uniform(d, 16),
            );
            assert!(cost::fedadam_ssm_q(d, k, 16) < cost::fedadam_ssm(d, k));
            assert!(cost::fedadam_ssm(d, k) < cost::fedadam_top(d, k));
            assert!(cost::fedadam_top(d, k) < cost::fedadam_dense(d));
        }
    }
    println!("(SSM-Q < SSM < Top < dense verified at every point)");

    // --- codec timing ----------------------------------------------------
    let mut bench = from_env();
    let mut rng = Rng::new(1);
    let d = 176_778;
    for &alpha in &[0.01f64, 0.05, 0.5] {
        let k = (d as f64 * alpha) as usize;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let idx = top_k_indices(&x, k);
        let sv = SparseVec::gather(&x, &idx);
        bench.run(format!("encode d={d} alpha={alpha}"), || {
            black_box(codec::encode(&sv));
        });
        let es = codec::encode(&sv);
        bench.run(format!("decode d={d} alpha={alpha} ({:?})", es.encoding), || {
            black_box(codec::decode(&es));
        });
    }
    bench.report("wire codec");
    println!("\n{}", bench.to_csv());
}
