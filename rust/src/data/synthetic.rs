//! Class-structured synthetic image generation.
//!
//! Stand-ins for the paper's corpora (DESIGN.md §Substitutions): each class
//! is a smooth random prototype in the target tensor shape; a sample is
//! `prototype + per-sample Gaussian noise`, with a small label-noise rate so
//! the task is not linearly trivial.  What the FL algorithms consume is
//! gradients and update deltas, so preserving shape/size/class structure
//! (plus Dirichlet skew, see [`super::partition`]) preserves the
//! comparisons the paper makes.

use super::Dataset;
use crate::rng::Rng;

/// Shape + difficulty knobs for a synthetic task.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub train: usize,
    pub test: usize,
    /// Per-sample noise std relative to prototype contrast (1.0 = hard).
    pub noise: f64,
    /// Fraction of labels flipped uniformly.
    pub label_noise: f64,
}

impl SyntheticSpec {
    /// Fashion-MNIST stand-in: 28x28x1, 60k/10k.
    pub fn fashion_mnist_like(train: usize, test: usize) -> Self {
        SyntheticSpec {
            height: 28,
            width: 28,
            channels: 1,
            num_classes: 10,
            train,
            test,
            noise: 0.8,
            label_noise: 0.02,
        }
    }

    /// CIFAR-10 stand-in: 32x32x3.
    pub fn cifar10_like(train: usize, test: usize) -> Self {
        SyntheticSpec {
            height: 32,
            width: 32,
            channels: 3,
            num_classes: 10,
            train,
            test,
            noise: 1.0,
            label_noise: 0.02,
        }
    }

    /// SVHN stand-in: 32x32x3 (house-number crops are noisier).
    pub fn svhn_like(train: usize, test: usize) -> Self {
        SyntheticSpec {
            height: 32,
            width: 32,
            channels: 3,
            num_classes: 10,
            train,
            test,
            noise: 1.1,
            label_noise: 0.03,
        }
    }

    /// Pick by the input shape recorded in the AOT manifest.
    pub fn for_input_shape(shape: &[usize], train: usize, test: usize) -> Self {
        match shape {
            [28, 28, 1] => Self::fashion_mnist_like(train, test),
            [32, 32, 3] => Self::cifar10_like(train, test),
            [h, w, c] => SyntheticSpec {
                height: *h,
                width: *w,
                channels: *c,
                num_classes: 10,
                train,
                test,
                noise: 0.8,
                label_noise: 0.02,
            },
            _ => panic!("unsupported input shape {shape:?}"),
        }
    }

    pub fn row(&self) -> usize {
        self.height * self.width * self.channels
    }
}

/// A generated task: train + test splits drawn from the same prototypes.
#[derive(Clone, Debug)]
pub struct SyntheticTask {
    pub train: Dataset,
    pub test: Dataset,
}

/// Generate a task deterministically from `seed`.
pub fn generate(spec: &SyntheticSpec, seed: u64) -> SyntheticTask {
    let mut rng = Rng::new(seed ^ 0x5e5e_5e5e_0001);
    let row = spec.row();

    // Smooth prototypes: low-frequency mixture of 2-D cosines per channel,
    // so conv layers have real spatial structure to exploit.
    let mut prototypes = vec![0.0f32; spec.num_classes * row];
    for c in 0..spec.num_classes {
        let proto = &mut prototypes[c * row..(c + 1) * row];
        for ch in 0..spec.channels {
            // 3 random cosine components per channel.
            let comps: Vec<(f64, f64, f64, f64)> = (0..3)
                .map(|_| {
                    (
                        rng.uniform_in(0.5, 3.0),  // fx cycles
                        rng.uniform_in(0.5, 3.0),  // fy cycles
                        rng.uniform_in(0.0, std::f64::consts::TAU), // phase
                        rng.uniform_in(0.4, 1.0),  // amplitude
                    )
                })
                .collect();
            for y in 0..spec.height {
                for x in 0..spec.width {
                    let mut v = 0.0;
                    for &(fx, fy, ph, amp) in &comps {
                        let t = std::f64::consts::TAU
                            * (fx * x as f64 / spec.width as f64
                                + fy * y as f64 / spec.height as f64)
                            + ph;
                        v += amp * t.cos();
                    }
                    proto[(y * spec.width + x) * spec.channels + ch] = v as f32;
                }
            }
        }
    }

    let mut make_split = |n: usize, tag: u64| {
        let mut r = rng.fork(tag);
        let mut images = Vec::with_capacity(n * row);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let class = r.below(spec.num_classes);
            let proto = &prototypes[class * row..(class + 1) * row];
            for &p in proto {
                images.push(p + (r.normal() * spec.noise) as f32);
            }
            let label = if r.uniform() < spec.label_noise {
                r.below(spec.num_classes) as i32
            } else {
                class as i32
            };
            labels.push(label);
        }
        Dataset {
            images,
            labels,
            row,
            num_classes: spec.num_classes,
        }
    };

    SyntheticTask {
        train: make_split(spec.train, 1),
        test: make_split(spec.test, 2),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = SyntheticSpec::fashion_mnist_like(128, 64);
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.train.len(), 128);
        assert_eq!(a.test.len(), 64);
        assert_eq!(a.train.row, 28 * 28);
        assert_eq!(a.train.images, b.train.images);
        assert_eq!(a.train.labels, b.train.labels);
        let c = generate(&spec, 8);
        assert_ne!(a.train.images, c.train.images);
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // Nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin, otherwise the task is unlearnable.
        let spec = SyntheticSpec {
            noise: 0.5,
            label_noise: 0.0,
            ..SyntheticSpec::fashion_mnist_like(400, 1)
        };
        let t = generate(&spec, 3);
        // Estimate prototypes from the train set itself (class means).
        let row = t.train.row;
        let mut means = vec![0.0f64; 10 * row];
        let mut counts = [0usize; 10];
        for i in 0..t.train.len() {
            let l = t.train.labels[i] as usize;
            counts[l] += 1;
            for (j, &v) in t.train.image(i).iter().enumerate() {
                means[l * row + j] += v as f64;
            }
        }
        for l in 0..10 {
            for j in 0..row {
                means[l * row + j] /= counts[l].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..t.train.len() {
            let img = t.train.image(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = img
                        .iter()
                        .enumerate()
                        .map(|(j, &v)| (v as f64 - means[a * row + j]).powi(2))
                        .sum();
                    let db: f64 = img
                        .iter()
                        .enumerate()
                        .map(|(j, &v)| (v as f64 - means[b * row + j]).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == t.train.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / t.train.len() as f64;
        assert!(acc > 0.8, "nearest-prototype accuracy too low: {acc}");
    }

    #[test]
    fn all_classes_present() {
        let spec = SyntheticSpec::cifar10_like(500, 100);
        let t = generate(&spec, 1);
        let hist = t.train.class_histogram();
        assert!(hist.iter().all(|&c| c > 10), "{hist:?}");
    }
}
