"""The paper's Fashion-MNIST CNN (§VII-A) and a tiny MLP for unit tests.

Paper description: "two 5x5 convolutional layers (each followed by ReLU
activation and a 2x2 max pooling layer), two fully connected layers, and a
final softmax output layer."  Channel widths are not given; we use the
conventional 32/64 + 512-hidden configuration for ``cnn`` and an 8/16 +
64-hidden configuration for the CPU-scale ``cnn_small``.
"""

from __future__ import annotations

import jax

from compile.models.common import (
    Model,
    ParamSpec,
    conv2d,
    dense,
    max_pool,
    softmax_xent,  # noqa: F401  (re-exported for tests)
)


def make_cnn(width=(32, 64), hidden=512, name="cnn", input_shape=(28, 28, 1), classes=10):
    """Build the 2-conv CNN over ``input_shape`` images."""
    c1, c2 = width
    h, w, cin = input_shape
    # Two 2x2 max-pools halve H and W twice (SAME conv keeps size).
    fh, fw = h // 4, w // 4
    feat = fh * fw * c2
    specs = (
        ParamSpec("conv1/kernel", (5, 5, cin, c1), "he"),
        ParamSpec("conv1/bias", (c1,), "zeros"),
        ParamSpec("conv2/kernel", (5, 5, c1, c2), "he"),
        ParamSpec("conv2/bias", (c2,), "zeros"),
        ParamSpec("fc1/kernel", (feat, hidden), "he"),
        ParamSpec("fc1/bias", (hidden,), "zeros"),
        ParamSpec("fc2/kernel", (hidden, classes), "he"),
        ParamSpec("fc2/bias", (classes,), "zeros"),
    )

    def apply(flat, x):
        model = _self[0]
        k1, b1, k2, b2, f1k, f1b, f2k, f2b = model.unflatten(flat)
        y = jax.nn.relu(conv2d(x, k1, b1))
        y = max_pool(y)
        y = jax.nn.relu(conv2d(y, k2, b2))
        y = max_pool(y)
        y = y.reshape(y.shape[0], -1)
        y = jax.nn.relu(dense(y, f1k, f1b))
        return dense(y, f2k, f2b)

    model = Model(name=name, specs=specs, apply=apply, input_shape=input_shape, num_classes=classes)
    _self = [model]
    return model


def make_mlp_tiny(name="mlp_tiny", input_shape=(8, 8, 1), classes=10, hidden=32):
    """Small MLP: the fast path for unit tests and the theory harness."""
    h, w, c = input_shape
    feat = h * w * c
    specs = (
        ParamSpec("fc1/kernel", (feat, hidden), "he"),
        ParamSpec("fc1/bias", (hidden,), "zeros"),
        ParamSpec("fc2/kernel", (hidden, classes), "he"),
        ParamSpec("fc2/bias", (classes,), "zeros"),
    )

    def apply(flat, x):
        model = _self[0]
        f1k, f1b, f2k, f2b = model.unflatten(flat)
        y = x.reshape(x.shape[0], -1)
        y = jax.nn.relu(dense(y, f1k, f1b))
        return dense(y, f2k, f2b)

    model = Model(name=name, specs=specs, apply=apply, input_shape=input_shape, num_classes=classes)
    _self = [model]
    return model
