//! End-to-end FL round bench: one full communication round per algorithm
//! (local training + compression + aggregation + apply), the number the
//! §Perf pass optimizes.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench e2e_round`.

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() {
    let mut bench = from_env();
    // One round is already ~100ms-scale; cap iterations regardless of budget.
    bench.max_iters = 20;

    for algo in [
        "fedadam-ssm",
        "fedadam-top",
        "fairness-top",
        "fedadam",
        "onebit-adam",
        "efficient-adam",
        "fedsgd",
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "cnn_small".into();
        cfg.algorithm = algo.into();
        cfg.rounds = usize::MAX; // stepped manually
        cfg.devices = 4;
        cfg.local_epochs = 1;
        cfg.max_batches_per_epoch = 2;
        cfg.train_samples = 512;
        cfg.test_samples = 64;
        cfg.eval_every = usize::MAX - 1; // exclude eval from the round cost
        cfg.warmup_rounds = 0; // bench the compression phase of onebit
        let mut coord = match Coordinator::new(cfg, "artifacts") {
            Ok(c) => c,
            Err(e) => {
                eprintln!("skipping e2e bench: {e}");
                return;
            }
        };
        bench.run(format!("round: {algo} (cnn_small, 4 dev, 2 batches)"), || {
            black_box(coord.step_round().unwrap());
        });
    }

    bench.report("end-to-end FL round");
    println!("\n{}", bench.to_csv());
}
