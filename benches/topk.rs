//! Top-k selection microbench (the SSM hot path, DESIGN.md §Perf L3).
//!
//! Compares the MSB-radix select (`sparse::topk`, PR 10) against a full
//! sort baseline at the paper's α = 0.05 across model dimensions, plus α
//! scaling at fixed d.  Outside every timed region the radix output is
//! re-asserted identical to the sort oracle.
//!
//! Run: `cargo bench --bench topk` (env `FEDADAM_BENCH_QUICK=1` for CI).
//!
//! **JSON mode** (`-- --json`) — the CI perf pin: radix select and the
//! sort baseline at the small and large model scales, emitting per-case
//! `median_ns` plus the derived select-vs-sort speedups as
//! `BENCH_topk.json` (`--json-out PATH` to redirect).  With `--baseline
//! PATH` any >10% regression against the checked-in pin prints a `WARN:`
//! line (informational — absolute numbers are host-dependent).

use std::collections::BTreeMap;

use fedadam_ssm::benchlib::{black_box, from_env, pin};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::top_k_indices;
use fedadam_ssm::util::json::Value;

fn sort_baseline(x: &[f32], k: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..x.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        x[b as usize]
            .abs()
            .total_cmp(&x[a as usize].abs())
            .then(a.cmp(&b))
    });
    let mut out: Vec<u32> = idx[..k].to_vec();
    out.sort_unstable();
    out
}

/// `--json` mode: the machine-readable perf pin (see the module docs).
fn json_mode(args: &[String]) {
    let out_path = pin::opt(args, "--json-out").unwrap_or_else(|| "BENCH_topk.json".into());
    let baseline = pin::opt(args, "--baseline");

    let mut bench = from_env();
    let mut rng = Rng::new(42);
    let mut cases: Vec<Value> = Vec::new();
    let mut medians: BTreeMap<String, f64> = BTreeMap::new();
    let mut speedups = BTreeMap::new();
    for &d in &[54_314usize, 1_663_370] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let k = d / 20;
        let mut timed = BTreeMap::new();
        let sel = format!("radix-select-d{d}");
        timed.insert(
            sel.clone(),
            bench
                .run(sel.clone(), || {
                    black_box(top_k_indices(&x, k));
                })
                .p50_ns,
        );
        let srt = format!("sort-baseline-d{d}");
        timed.insert(
            srt.clone(),
            bench
                .run(srt.clone(), || {
                    black_box(sort_baseline(&x, k));
                })
                .p50_ns,
        );
        // Correctness outside the timed region: radix == sort oracle.
        assert_eq!(
            top_k_indices(&x, k),
            sort_baseline(&x, k),
            "d={d} k={k}: radix select diverged from the sort oracle"
        );
        speedups.insert(
            format!("d{d}"),
            Value::Num(timed[&srt] / timed[&sel].max(1.0)),
        );
        for (name, med) in timed {
            medians.insert(name.clone(), med);
            let mut extra = BTreeMap::new();
            extra.insert("dim".into(), Value::Num(d as f64));
            extra.insert("k".into(), Value::Num(k as f64));
            cases.push(pin::case(&name, "median_ns", med, extra));
        }
    }

    let mut extra = BTreeMap::new();
    extra.insert("select_speedup_vs_sort".into(), Value::Obj(speedups));
    pin::write(
        "topk",
        "maintainer-machine pin; regenerate with: cargo bench --bench topk -- --json \
         --json-out BENCH_topk.json (PR 10 replaced the scalar quickselect with an exact \
         MSB-radix select — identical output, pinned here at >=2x below the retired \
         quickselect's medians of ~410us at d=54314 and ~14.9ms at d=1663370; medians are \
         host-dependent, so ci_local.sh only WARNS on >10% regressions)",
        &out_path,
        cases,
        extra,
    );

    if let Some(bp) = baseline {
        pin::compare_with_baseline(&bp, "median_ns", &medians);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--json") {
        json_mode(&args);
        return;
    }
    let mut bench = from_env();
    let mut rng = Rng::new(42);

    // d sweep at alpha = 0.05 (paper default): the three model scales.
    for &d in &[54_314usize, 176_778, 1_663_370] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let k = d / 20;
        bench.run(format!("radix-select d={d} k={k}"), || {
            black_box(top_k_indices(&x, k));
        });
        bench.run(format!("sort-baseline d={d} k={k}"), || {
            black_box(sort_baseline(&x, k));
        });
        assert_eq!(
            top_k_indices(&x, k),
            sort_baseline(&x, k),
            "d={d}: radix select diverged from the sort oracle"
        );
    }

    // alpha sweep at cnn_small's d.
    let d = 54_314;
    let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    for &alpha in &[0.01f64, 0.05, 0.2, 0.5] {
        let k = ((d as f64 * alpha) as usize).max(1);
        bench.run(format!("radix-select d={d} alpha={alpha}"), || {
            black_box(top_k_indices(&x, k));
        });
    }

    bench.report("top-k selection");
    println!("\n{}", bench.to_csv());
}
