//! Wire encodings and the paper's bit-cost model (§IV, §VII-A).
//!
//! Positions of non-zeros can be sent either as a `d`-bit **bitmask** or as
//! `k` indices of `ceil(log2 d)` bits each; the experiments use
//! `min{...}` of the two (paper §VII-A *Implementation*).  Values are `q`
//! = 32-bit floats.  This module provides both the **cost model** (used by
//! every algorithm's accounting) and real encoders/decoders so the wire
//! format is exercised, not just priced.

use super::SparseVec;

/// Floating-point precision `q` in bits (paper uses f32).
pub const Q: u64 = 32;

/// `ceil(log2 d)` — bits to address one coordinate.
pub fn index_bits(dim: usize) -> u64 {
    if dim <= 1 {
        1
    } else {
        (usize::BITS - (dim - 1).leading_zeros()) as u64
    }
}

/// Which position encoding `min{}` picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskEncoding {
    /// `d` bits, one per coordinate.
    Bitmap,
    /// `k * ceil(log2 d)` bits.
    IndexList,
}

/// Why an untrusted buffer failed to decode.
///
/// The `try_` decode paths ([`BitUnpacker::try_pull`],
/// [`try_decode_positions`], [`try_decode`], and the quantizer/transport
/// decoders built on them) return this instead of panicking — bytes that
/// crossed a socket are attacker-controlled, so every structural invariant
/// the infallible in-process paths assume is checked and rejected with a
/// typed reason here.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// The buffer ran out before the declared content did.
    Truncated { needed_bits: usize, have_bits: usize },
    /// A section's byte length disagrees with what its header implies.
    PayloadSize { expected: usize, got: usize },
    /// A decoded position is out of range for the declared dimension.
    BadIndex { index: u32, dim: usize },
    /// Index-list positions must be strictly increasing (sorted, unique).
    NonIncreasing { prev: u32, next: u32 },
    /// The decoded support size disagrees with the header `k`.
    CountMismatch { expected: usize, got: usize },
    /// A field holds a value outside its domain (nonzero padding bits, a
    /// quantizer code above `levels`, a non-finite scale, a non-canonical
    /// encoding choice, ...).
    BadValue(&'static str),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated {
                needed_bits,
                have_bits,
            } => write!(f, "truncated buffer: need {needed_bits} bits, have {have_bits}"),
            DecodeError::PayloadSize { expected, got } => {
                write!(f, "payload size mismatch: expected {expected} bytes, got {got}")
            }
            DecodeError::BadIndex { index, dim } => {
                write!(f, "position {index} out of range for dim {dim}")
            }
            DecodeError::NonIncreasing { prev, next } => {
                write!(f, "positions not strictly increasing: {prev} then {next}")
            }
            DecodeError::CountMismatch { expected, got } => {
                write!(f, "support size mismatch: header says {expected}, decoded {got}")
            }
            DecodeError::BadValue(what) => write!(f, "bad field value: {what}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Cost in bits of transmitting the positions of `k` non-zeros out of `d`.
pub fn mask_bits(dim: usize, k: usize) -> (u64, MaskEncoding) {
    let bitmap = dim as u64;
    let index = k as u64 * index_bits(dim);
    if bitmap <= index {
        (bitmap, MaskEncoding::Bitmap)
    } else {
        (index, MaskEncoding::IndexList)
    }
}

/// Uplink bits for ONE device/round under each scheme of §IV + §VII-A.
pub mod cost {
    use super::{index_bits, Q};

    /// Standard FedAdam (Algorithm 1): three dense vectors — `3dq`.
    pub fn fedadam_dense(d: usize) -> u64 {
        3 * d as u64 * Q
    }

    /// FedAdam-Top: three sparse vectors, three masks —
    /// `min{3(kq+d), 3k(q+log2 d)}`.
    pub fn fedadam_top(d: usize, k: usize) -> u64 {
        let bitmap = 3 * (k as u64 * Q + d as u64);
        let index = 3 * k as u64 * (Q + index_bits(d));
        bitmap.min(index)
    }

    /// SSM family (FedAdam-SSM / SSM_M / SSM_V / Fairness-Top): three sparse
    /// value lists, ONE mask — `min{3kq+d, k(3q+log2 d)}`.
    pub fn fedadam_ssm(d: usize, k: usize) -> u64 {
        let bitmap = 3 * k as u64 * Q + d as u64;
        let index = k as u64 * (3 * Q + index_bits(d));
        bitmap.min(index)
    }

    /// FedSGD: one dense vector — `dq`.
    pub fn fedsgd_dense(d: usize) -> u64 {
        d as u64 * Q
    }

    /// 1-bit Adam compression phase: 1 bit per lane + one f32 scale.
    pub fn onebit(d: usize) -> u64 {
        d as u64 + Q
    }

    /// Efficient-Adam with `s`-level uniform quantization:
    /// `ceil(log2 s)` bits per lane + one f32 scale.
    pub fn uniform(d: usize, s_levels: usize) -> u64 {
        d as u64 * index_bits(s_levels) + Q
    }

    /// Quantized SSM (FedAdam-SSM-Q / -QEF): three `s`-level-quantized
    /// value lists under ONE shared mask, plus one f32 scale per vector —
    /// `min{3k·ceil(log₂ s) + d, k(3·ceil(log₂ s) + log₂ d)} + 3q`.
    ///
    /// The value payload (`3k·ceil(log₂ s)` bits) and the three scales are
    /// common to both branches, so the `min{}` reduces to the same
    /// bitmap-vs-index-list choice [`super::mask_bits`] makes — the
    /// encoded [`crate::quant::SsmQUplink`] is bit-for-bit this size.
    pub fn fedadam_ssm_q(d: usize, k: usize, s_levels: usize) -> u64 {
        let b = index_bits(s_levels);
        let bitmap = 3 * k as u64 * b + d as u64;
        let index = k as u64 * (3 * b + index_bits(d));
        bitmap.min(index) + 3 * Q
    }
}

/// A bit-exact encoded sparse vector (positions + f32 payloads).
#[derive(Clone, Debug)]
pub struct EncodedSparse {
    pub dim: usize,
    pub encoding: MaskEncoding,
    /// Packed position bits (bitmap or index list).
    pub positions: Vec<u8>,
    /// Raw little-endian f32 payloads, `k` of them.
    pub payload: Vec<u8>,
    pub k: usize,
}

impl EncodedSparse {
    /// Total size on the wire in bits.
    pub fn wire_bits(&self) -> u64 {
        let (pos_bits, _) = mask_bits_for(self.encoding, self.dim, self.k);
        pos_bits + self.payload.len() as u64 * 8
    }
}

fn mask_bits_for(enc: MaskEncoding, dim: usize, k: usize) -> (u64, MaskEncoding) {
    match enc {
        MaskEncoding::Bitmap => (dim as u64, enc),
        MaskEncoding::IndexList => (k as u64 * index_bits(dim), enc),
    }
}

/// Push the canonical `min{bitmap, index-list}` position coding for
/// `indices` (sorted unique, `< dim`) into an open contiguous stream —
/// bit-for-bit the coding [`encode_positions`] produces, minus its byte
/// padding.  This is the shared mid-stream form every wire body uses
/// (`algorithms::wire` and the fused device-side encoders).
///
/// The bitmap branch emits whole 64-lane words (`push(word, ≤64)`), not
/// one bit per lane: the LSB-first stream order makes the word write
/// byte-identical to `d` single-bit pushes while costing `O(k + d/64)`
/// instead of `O(d)` packer calls — this coding is on the device hot path
/// once per round per device.
pub fn pack_positions(p: &mut BitPacker, dim: usize, indices: &[u32]) {
    let (_, enc) = mask_bits(dim, indices.len());
    match enc {
        MaskEncoding::Bitmap => {
            let mut next = indices.iter().peekable();
            let mut base = 0usize;
            while base < dim {
                let n = (dim - base).min(64);
                let mut word = 0u64;
                while let Some(&&i) = next.peek() {
                    let off = (i as usize).wrapping_sub(base);
                    if off >= n {
                        break;
                    }
                    word |= 1u64 << off;
                    next.next();
                }
                p.push(word, n as u64);
                base += n;
            }
        }
        MaskEncoding::IndexList => {
            let bits = index_bits(dim);
            for &i in indices {
                p.push(i as u64, bits);
            }
        }
    }
}

/// Pack `indices` (sorted unique lanes of `[0, dim)`) with the cheaper
/// position encoding — the shared front half of every sparse wire format
/// (f32 [`encode`] and the quantized [`crate::quant::SsmQUplink`] alike).
pub fn encode_positions(dim: usize, indices: &[u32]) -> (MaskEncoding, Vec<u8>) {
    let (_, enc) = mask_bits(dim, indices.len());
    let bytes = match enc {
        MaskEncoding::Bitmap => {
            let mut bytes = vec![0u8; dim.div_ceil(8)];
            for &i in indices {
                bytes[i as usize / 8] |= 1 << (i % 8);
            }
            bytes
        }
        MaskEncoding::IndexList => {
            let bits = index_bits(dim);
            let mut packer = BitPacker::with_capacity(indices.len() * bits as usize);
            for &i in indices {
                packer.push(i as u64, bits);
            }
            packer.finish()
        }
    };
    (enc, bytes)
}

/// Recover the `k` sorted indices packed by [`encode_positions`].
///
/// Trusted in-process path: the bytes came from [`encode_positions`] in
/// this address space, so validation failures are programming errors and
/// panic.  Transport-facing callers must use [`try_decode_positions`].
pub fn decode_positions(enc: MaskEncoding, dim: usize, k: usize, bytes: &[u8]) -> Vec<u32> {
    try_decode_positions(enc, dim, k, bytes).expect("trusted in-process positions must decode")
}

/// Fallible [`decode_positions`] for untrusted bytes: never panics, and
/// only accepts the canonical output of [`encode_positions`] — exactly
/// `k` strictly-increasing indices `< dim`, an exact byte length, and
/// zero padding bits.
pub fn try_decode_positions(
    enc: MaskEncoding,
    dim: usize,
    k: usize,
    bytes: &[u8],
) -> Result<Vec<u32>, DecodeError> {
    match enc {
        MaskEncoding::Bitmap => {
            let expected = dim.div_ceil(8);
            if bytes.len() != expected {
                return Err(DecodeError::PayloadSize {
                    expected,
                    got: bytes.len(),
                });
            }
            let mut out = Vec::with_capacity(k.min(dim));
            for i in 0..dim {
                if bytes[i / 8] & (1 << (i % 8)) != 0 {
                    out.push(i as u32);
                }
            }
            for i in dim..expected * 8 {
                if bytes[i / 8] & (1 << (i % 8)) != 0 {
                    return Err(DecodeError::BadValue("nonzero bitmap padding bits"));
                }
            }
            if out.len() != k {
                return Err(DecodeError::CountMismatch {
                    expected: k,
                    got: out.len(),
                });
            }
            Ok(out)
        }
        MaskEncoding::IndexList => {
            let bits = index_bits(dim);
            let total_bits = k * bits as usize;
            let expected = total_bits.div_ceil(8);
            if bytes.len() != expected {
                return Err(DecodeError::PayloadSize {
                    expected,
                    got: bytes.len(),
                });
            }
            let mut unpacker = BitUnpacker::new(bytes);
            let mut out = Vec::with_capacity(k);
            let mut prev: Option<u32> = None;
            for _ in 0..k {
                let i = unpacker.try_pull(bits)? as u32;
                if i as usize >= dim {
                    return Err(DecodeError::BadIndex { index: i, dim });
                }
                if let Some(p) = prev {
                    if i <= p {
                        return Err(DecodeError::NonIncreasing { prev: p, next: i });
                    }
                }
                prev = Some(i);
                out.push(i);
            }
            let pad = (expected * 8 - total_bits) as u64;
            if pad > 0 && unpacker.try_pull(pad)? != 0 {
                return Err(DecodeError::BadValue("nonzero index-list padding bits"));
            }
            Ok(out)
        }
    }
}

/// Encode with the cheaper position encoding.
pub fn encode(sv: &SparseVec) -> EncodedSparse {
    let (enc, positions) = encode_positions(sv.dim, &sv.indices);
    let mut payload = Vec::with_capacity(sv.nnz() * 4);
    for &v in &sv.values {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    EncodedSparse {
        dim: sv.dim,
        encoding: enc,
        positions,
        payload,
        k: sv.nnz(),
    }
}

/// Decode back to a [`SparseVec`].
///
/// Trusted in-process path (the message came from [`encode`] in this
/// address space); transport-facing callers must use [`try_decode`].
pub fn decode(es: &EncodedSparse) -> SparseVec {
    try_decode(es).expect("trusted in-process sparse message must decode")
}

/// Fallible [`decode`] for untrusted bytes: never panics, and only
/// accepts the canonical output of [`encode`] — the `min{}`-cheaper
/// position encoding for `(dim, k)`, a valid support, and exactly
/// `k` f32 payloads.
pub fn try_decode(es: &EncodedSparse) -> Result<SparseVec, DecodeError> {
    let (_, canonical) = mask_bits(es.dim, es.k);
    if es.encoding != canonical {
        return Err(DecodeError::BadValue("non-canonical position encoding"));
    }
    let indices = try_decode_positions(es.encoding, es.dim, es.k, &es.positions)?;
    let expected = es.k * 4;
    if es.payload.len() != expected {
        return Err(DecodeError::PayloadSize {
            expected,
            got: es.payload.len(),
        });
    }
    let values = es
        .payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(SparseVec {
        dim: es.dim,
        indices,
        values,
    })
}

/// LSB-first bit packer used by the index-list encoding and quantizers.
pub struct BitPacker {
    bytes: Vec<u8>,
    bitpos: usize,
}

impl BitPacker {
    pub fn with_capacity(bits: usize) -> Self {
        BitPacker {
            bytes: Vec::with_capacity(bits.div_ceil(8)),
            bitpos: 0,
        }
    }

    /// Append the low `n` bits of `v` (byte-at-a-time, not bit-at-a-time —
    /// the quantizer hot path packs d×log₂s bits per upload; §Perf L3).
    pub fn push(&mut self, v: u64, n: u64) {
        debug_assert!(n <= 64);
        let mut v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        let mut remaining = n;
        while remaining > 0 {
            let off = self.bitpos % 8;
            if off == 0 {
                self.bytes.push(0);
            }
            let take = (8 - off).min(remaining as usize) as u64;
            let last = self.bytes.last_mut().unwrap();
            *last |= ((v & ((1u64 << take) - 1)) as u8) << off;
            v >>= take;
            self.bitpos += take as usize;
            remaining -= take;
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.bytes
    }
}

/// Matching LSB-first unpacker.
pub struct BitUnpacker<'a> {
    bytes: &'a [u8],
    bitpos: usize,
}

impl<'a> BitUnpacker<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitUnpacker { bytes, bitpos: 0 }
    }

    /// Bits left in the buffer past the read cursor.
    pub fn remaining_bits(&self) -> usize {
        self.bytes.len() * 8 - self.bitpos
    }

    /// Fallible [`BitUnpacker::pull`] for untrusted bytes: checks the
    /// buffer holds `n` more bits before reading, instead of panicking
    /// on a short buffer.
    pub fn try_pull(&mut self, n: u64) -> Result<u64, DecodeError> {
        debug_assert!(n <= 64);
        if n as usize > self.remaining_bits() {
            return Err(DecodeError::Truncated {
                needed_bits: self.bitpos + n as usize,
                have_bits: self.bytes.len() * 8,
            });
        }
        Ok(self.pull(n))
    }

    /// Read the next `n` bits (byte-at-a-time, mirroring `push`).
    ///
    /// Trusted in-process path: panics if the buffer is too short.
    /// Transport-facing callers must use [`BitUnpacker::try_pull`].
    pub fn pull(&mut self, n: u64) -> u64 {
        debug_assert!(n <= 64);
        let mut out = 0u64;
        let mut got = 0u64;
        while got < n {
            let off = self.bitpos % 8;
            let take = (8 - off).min((n - got) as usize) as u64;
            let byte = self.bytes[self.bitpos / 8] as u64;
            let bits = (byte >> off) & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            self.bitpos += take as usize;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::top_k_indices;

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(1024), 10);
        assert_eq!(index_bits(1025), 11);
    }

    #[test]
    fn mask_encoding_crossover() {
        // Small k: index list wins. Large k: bitmap wins.
        let d = 1 << 20;
        let (_, enc_small) = mask_bits(d, 10);
        assert_eq!(enc_small, MaskEncoding::IndexList);
        let (_, enc_large) = mask_bits(d, d / 2);
        assert_eq!(enc_large, MaskEncoding::Bitmap);
    }

    #[test]
    fn ssm_cheaper_than_top_cheaper_than_dense() {
        // The paper's headline: O(3dq) -> O(3kq+3d) -> O(3kq+d).
        for &(d, alpha) in &[(100_000usize, 0.05f64), (1_000_000, 0.01)] {
            let k = (d as f64 * alpha) as usize;
            let dense = cost::fedadam_dense(d);
            let top = cost::fedadam_top(d, k);
            let ssm = cost::fedadam_ssm(d, k);
            assert!(ssm < top, "ssm {ssm} !< top {top}");
            assert!(top < dense, "top {top} !< dense {dense}");
        }
    }

    #[test]
    fn encode_decode_roundtrip_both_encodings() {
        let mut rng = Rng::new(11);
        for &d in &[64usize, 1000, 4096] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            for &k in &[1usize, d / 100 + 1, d / 2, d] {
                let idx = top_k_indices(&x, k);
                let sv = SparseVec::gather(&x, &idx);
                let es = encode(&sv);
                let back = decode(&es);
                assert_eq!(back, sv, "d={d} k={k} enc={:?}", es.encoding);
            }
        }
    }

    #[test]
    fn wire_bits_matches_cost_model() {
        let d = 10_000;
        let k = 500;
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let idx = top_k_indices(&x, k);
        let sv = SparseVec::gather(&x, &idx);
        let es = encode(&sv);
        let (pos_bits, _) = mask_bits(d, k);
        assert_eq!(es.wire_bits(), pos_bits + k as u64 * Q);
    }

    #[test]
    fn bitpacker_roundtrip() {
        let mut p = BitPacker::with_capacity(0);
        let vals = [(5u64, 3u64), (1023, 10), (0, 1), (77, 7)];
        for &(v, n) in &vals {
            p.push(v, n);
        }
        let bytes = p.finish();
        let mut u = BitUnpacker::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(u.pull(n), v);
        }
    }

    #[test]
    fn bitpacker_payload_ending_on_byte_boundary() {
        // Regression: a payload whose bit-length is an exact multiple of 8
        // must produce exactly bits/8 bytes (no trailing padding byte) and
        // round-trip losslessly — the quantized-SSM wire format hits this
        // whenever `k * ceil(log2 s) % 8 == 0`.
        for &(width, count) in &[(4u64, 8usize), (8, 3), (2, 12), (3, 8), (5, 8), (1, 16)] {
            assert_eq!((width as usize * count) % 8, 0, "case must end on a byte");
            let mut p = BitPacker::with_capacity(width as usize * count);
            let vals: Vec<u64> = (0..count as u64).map(|i| i % (1 << width)).collect();
            for &v in &vals {
                p.push(v, width);
            }
            let bytes = p.finish();
            assert_eq!(
                bytes.len(),
                width as usize * count / 8,
                "width {width} x {count}: byte-boundary payload grew a pad byte"
            );
            let mut u = BitUnpacker::new(&bytes);
            for &v in &vals {
                assert_eq!(u.pull(width), v, "width {width}");
            }
        }
    }

    #[test]
    fn bitpacker_non_power_of_two_level_widths() {
        // `ceil(log2 s)` for non-power-of-two s: s = 3 -> 2 bits,
        // s = 5 -> 3 bits.  Every representable code must survive packing
        // at that width, including runs that straddle byte boundaries.
        for &s in &[3usize, 5, 6, 7, 9] {
            let width = index_bits(s);
            assert!((1u64 << width) >= s as u64 && (1u64 << (width - 1)) < s as u64);
            let codes: Vec<u64> = (0..64u64).map(|i| i % s as u64).collect();
            let mut p = BitPacker::with_capacity(codes.len() * width as usize);
            for &c in &codes {
                p.push(c, width);
            }
            let bytes = p.finish();
            assert_eq!(bytes.len(), (codes.len() * width as usize).div_ceil(8));
            let mut u = BitUnpacker::new(&bytes);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(u.pull(width), c, "s={s} code #{i}");
            }
        }
    }

    #[test]
    fn ssm_q_cost_below_ssm_and_above_mask_only() {
        // Quantizing the three value lists can only shrink the SSM uplink
        // (for s < 2^32); the mask + scales are a hard floor.
        for &(d, k) in &[(100_000usize, 5_000usize), (1_000_000, 10_000), (170, 8)] {
            for &s in &[2usize, 3, 4, 5, 16, 256] {
                let q = cost::fedadam_ssm_q(d, k, s);
                assert!(q < cost::fedadam_ssm(d, k), "d={d} k={k} s={s}");
                let (mask, _) = mask_bits(d, k);
                assert!(q >= mask + 3 * Q, "d={d} k={k} s={s}");
                // Exact composition: mask + 3k·ceil(log2 s) + 3 scales.
                assert_eq!(q, mask + 3 * k as u64 * index_bits(s) + 3 * Q);
            }
        }
        // More levels never cost fewer bits.
        assert!(cost::fedadam_ssm_q(1000, 50, 16) >= cost::fedadam_ssm_q(1000, 50, 4));
    }

    #[test]
    fn try_pull_rejects_short_buffers() {
        let bytes = [0xABu8, 0xCD];
        let mut u = BitUnpacker::new(&bytes);
        assert_eq!(u.try_pull(12).unwrap(), 0xDAB);
        assert_eq!(u.remaining_bits(), 4);
        assert!(matches!(
            u.try_pull(5),
            Err(DecodeError::Truncated {
                needed_bits: 17,
                have_bits: 16
            })
        ));
        // The failed pull must not move the cursor.
        assert_eq!(u.try_pull(4).unwrap(), 0xC);
    }

    #[test]
    fn try_decode_positions_rejects_malformed_supports() {
        // Bitmap: popcount must equal k, padding must be zero, length exact.
        let d = 10usize;
        let (enc, bytes) = encode_positions(d, &[1, 3, 9]);
        assert_eq!(enc, MaskEncoding::Bitmap);
        assert_eq!(try_decode_positions(enc, d, 3, &bytes).unwrap(), vec![1, 3, 9]);
        assert!(matches!(
            try_decode_positions(enc, d, 2, &bytes),
            Err(DecodeError::CountMismatch { expected: 2, got: 3 })
        ));
        let mut padded = bytes.clone();
        padded[1] |= 1 << 7; // bit 15 >= dim
        assert!(matches!(
            try_decode_positions(enc, d, 3, &padded),
            Err(DecodeError::BadValue(_))
        ));
        assert!(matches!(
            try_decode_positions(enc, d, 3, &bytes[..1]),
            Err(DecodeError::PayloadSize { expected: 2, got: 1 })
        ));

        // Index list: in-range, strictly increasing, exact length, zero pad.
        let d = 1 << 16;
        let (enc, bytes) = encode_positions(d, &[7, 9, 4096]);
        assert_eq!(enc, MaskEncoding::IndexList);
        assert_eq!(
            try_decode_positions(enc, d, 3, &bytes).unwrap(),
            vec![7, 9, 4096]
        );
        assert!(matches!(
            try_decode_positions(enc, d, 3, &bytes[..5]),
            Err(DecodeError::PayloadSize { .. })
        ));
        let (_, dup) = encode_positions(d, &[7, 9, 9]);
        assert!(matches!(
            try_decode_positions(enc, d, 3, &dup),
            Err(DecodeError::NonIncreasing { prev: 9, next: 9 })
        ));
        let (_, unsorted) = encode_positions(d, &[9, 7, 4096]);
        assert!(matches!(
            try_decode_positions(enc, d, 3, &unsorted),
            Err(DecodeError::NonIncreasing { .. })
        ));
        // Out-of-range: hand-pack an index >= dim at a smaller declared dim.
        let small = 100usize;
        let bits = index_bits(small);
        let mut p = BitPacker::with_capacity(bits as usize);
        p.push(100, bits);
        assert!(matches!(
            try_decode_positions(MaskEncoding::IndexList, small, 1, &p.finish()),
            Err(DecodeError::BadIndex { index: 100, dim: 100 })
        ));
    }

    #[test]
    fn try_decode_rejects_truncated_payload_and_wrong_encoding() {
        let sv = SparseVec {
            dim: 1 << 16,
            indices: vec![3, 70, 4099],
            values: vec![1.0, -2.5, 0.25],
        };
        let es = encode(&sv);
        assert_eq!(try_decode(&es).unwrap(), sv);

        let mut short = es.clone();
        short.payload.truncate(short.payload.len() - 1);
        assert!(matches!(
            try_decode(&short),
            Err(DecodeError::PayloadSize { .. })
        ));

        let mut wrong_enc = es.clone();
        wrong_enc.encoding = MaskEncoding::Bitmap;
        assert!(matches!(
            try_decode(&wrong_enc),
            Err(DecodeError::BadValue("non-canonical position encoding"))
        ));

        let mut short_pos = es;
        short_pos.positions.truncate(1);
        assert!(matches!(
            try_decode(&short_pos),
            Err(DecodeError::PayloadSize { .. })
        ));
    }

    #[test]
    fn pack_positions_is_byte_identical_to_encode_positions() {
        // The mid-stream packer (word-at-a-time bitmap) must write exactly
        // the bits `encode_positions` does — same coding choice, same
        // order, same zero padding once the stream ends on the boundary.
        let mut rng = Rng::new(41);
        for &d in &[1usize, 7, 8, 63, 64, 65, 100, 1000, 4096] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            for k in [0usize, 1, d / 7 + 1, d / 2, d.saturating_sub(1), d] {
                let idx = top_k_indices(&x, k);
                let (_, staged) = encode_positions(d, &idx);
                let mut p = BitPacker::with_capacity(d);
                pack_positions(&mut p, d, &idx);
                assert_eq!(p.finish(), staged, "d={d} k={k}");
            }
        }
    }

    #[test]
    fn position_helpers_roundtrip_both_encodings() {
        let d = 1 << 12;
        for k in [1usize, 7, 100, d / 2, d] {
            let indices: Vec<u32> = (0..k as u32).map(|i| i * (d / k) as u32).collect();
            let (enc, bytes) = encode_positions(d, &indices);
            assert_eq!(decode_positions(enc, d, k, &bytes), indices, "k={k} {enc:?}");
        }
    }
}
