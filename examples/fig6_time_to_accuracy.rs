//! Time-to-accuracy under a simulated heterogeneous fleet: the frontier
//! the sparse uplinks actually buy.
//!
//! Runs FedAdam (dense), FedAdam-SSM and FedAdam-SSM-Q on the pure-Rust
//! reference backend (no PJRT artifacts — runs offline) with the
//! simulated wall-clock enabled: per-device compute latency is
//! heterogeneous (`sim_hetero` straggler spread), uplink latency is the
//! exact wire bits over a constrained `sim_bandwidth_mbps`, and the
//! clock advances per round under the configured schedule.  On a
//! bandwidth-bound fleet the dense `3dq` upload dominates each round, so
//! the SSM family reaches the common accuracy target in far less
//! simulated time — the x-axis Fig. 2 can't show.
//!
//! Emits `results/fig6/time_to_accuracy.csv`
//! (`algorithm,round,sim_secs,cum_uplink_mbit,test_accuracy`) plus a
//! time-to-target summary table.
//!
//! ```text
//! cargo run --release --example fig6_time_to_accuracy -- \
//!     [--rounds 12] [--devices 4] [--bandwidth-mbps 0.01] [--quick] \
//!     [--set participation_mode=availability] [--set pipeline_depth=2]
//! ```

use anyhow::Result;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool};

const INPUT: [usize; 3] = [4, 4, 1]; // row 16; dim = 10 * (16 + 1) = 170
const CLASSES: usize = 10;

fn run_one(base: &ExperimentConfig, algo: &str) -> Result<ExperimentLog> {
    let mut cfg = base.clone();
    cfg.algorithm = algo.into();
    cfg.name = format!("fig6_{algo}");
    let meta = reference_meta(&INPUT, CLASSES, 4, 8, 2);
    let pool = reference_pool(meta, cfg.num_workers)?;
    let mut coord = Coordinator::with_pool(cfg, pool)?;
    coord.run()
}

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let quick = cli.flag("quick");

    let mut base = ExperimentConfig::default();
    base.model = "reference-linear".into();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 6 } else { 12 });
    base.devices = cli.opt_parse("devices")?.unwrap_or(4);
    base.local_epochs = 1;
    base.max_batches_per_epoch = 2;
    base.lr = 0.02;
    base.train_samples = 128;
    base.test_samples = 64;
    base.seed = 7;
    base.eval_every = 1;
    // The simulated fleet: heterogeneous compute, 10 kbit/s uplinks — the
    // regime where the wire is the round's critical path.
    base.simtime = true;
    base.sim_bandwidth_mbps = cli.opt_parse("bandwidth-mbps")?.unwrap_or(0.01);
    for (k, v) in &cli.sets {
        base.set(k, v)?;
    }
    base.validate()?;

    let algos = ["fedadam", "fedadam-ssm", "fedadam-ssm-q"];
    let mut logs = Vec::new();
    for algo in algos {
        logs.push(run_one(&base, algo)?);
    }

    // Common target: the best accuracy every algorithm reached.
    let target = logs
        .iter()
        .map(ExperimentLog::best_accuracy)
        .fold(f64::INFINITY, f64::min);

    // Same cell contract as `ExperimentLog::to_csv`: NaN (non-eval round,
    // or sim_secs with `--set simtime=false`) emits an EMPTY cell — a
    // literal `NaN` token breaks strict CSV consumers.
    fn cell(x: f64, digits: usize) -> String {
        if x.is_nan() {
            String::new()
        } else {
            format!("{x:.digits$}")
        }
    }
    let mut csv = String::from("algorithm,round,sim_secs,cum_uplink_mbit,test_accuracy\n");
    println!(
        "{:<16} {:>9} {:>12} {:>14} {:>16}",
        "algorithm", "best acc", "sim total s", "uplink Mbit", "secs to target"
    );
    for (algo, log) in algos.iter().zip(&logs) {
        for r in &log.rounds {
            csv.push_str(&format!(
                "{algo},{},{},{:.4},{}\n",
                r.round,
                cell(r.sim_secs, 4),
                r.uplink_bits as f64 / 1e6,
                cell(r.test_accuracy, 6)
            ));
        }
        let last = log.rounds.last().expect("rounds ran");
        let ttt = log
            .time_to_accuracy(target)
            .map(|s| format!("{s:.2}"))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<16} {:>9.3} {:>12.2} {:>14.3} {:>16}",
            algo,
            log.best_accuracy(),
            last.sim_secs,
            last.uplink_bits as f64 / 1e6,
            ttt
        );
    }

    std::fs::create_dir_all("results/fig6")?;
    std::fs::write("results/fig6/time_to_accuracy.csv", &csv)?;
    println!(
        "\nwrote results/fig6/time_to_accuracy.csv \
         (x = sim_secs, y = test_accuracy; target {target:.3})"
    );

    // The headline claim, checked right here: sparse uplinks reach the
    // common target in less simulated time than the dense baseline.
    let t = |i: usize| logs[i].time_to_accuracy(target);
    if let (Some(dense), Some(ssm), Some(ssm_q)) = (t(0), t(1), t(2)) {
        println!(
            "speedup to target: ssm {:.1}x, ssm-q {:.1}x over dense fedadam",
            dense / ssm,
            dense / ssm_q
        );
    }
    Ok(())
}
