"""The L1 perf model's invariants (used by DESIGN.md §Perf)."""

from compile.kernels import analysis as A


def test_all_kernels_fit_vmem_at_default_block():
    for p in A.PROFILES:
        assert p.fits_vmem(64 * 1024), p.name


def test_all_kernels_memory_bound():
    # Element-wise kernels must sit below the roofline ridge.
    for p in A.PROFILES:
        assert p.bound() == "memory", p.name


def test_fused_adam_beats_unfused():
    adam = next(p for p in A.PROFILES if p.name == "adam_update")
    assert A.naive_adam_passes() / adam.bytes_per_elem() >= 1.4


def test_roofline_monotone_in_d():
    p = A.PROFILES[0]
    assert p.roofline_time(2_000_000) > p.roofline_time(1_000_000)


def test_report_renders():
    r = A.report()
    assert "adam_update" in r and "ridge" in r
    # Every profile appears.
    for p in A.PROFILES:
        assert p.name in r


def test_block_too_large_overflows():
    p = A.PROFILES[0]  # 7 resident blocks
    assert not p.fits_vmem(2**20)  # 7 * 4 MiB * 2 > 16 MiB
