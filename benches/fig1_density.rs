//! Fig.-1 harness in bench form: times the delta-extraction pipeline
//! (local round → ΔW/ΔM/ΔV → histogram) and re-verifies the magnitude
//! ordering that justifies the SSM (ΔW ≫ ΔM ≫ ΔV).
//!
//! The full figure (density series) is produced by
//! `cargo run --release --example fig1_density`.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench fig1_density`.

use fedadam_ssm::algorithms::LocalMode;
use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::coordinator::device::{Device, LocalRunConfig};
use fedadam_ssm::data::{partition, synthetic, Partition, Shard};
use fedadam_ssm::runtime::{Engine, Manifest};
use fedadam_ssm::tensor;

fn median_log10(x: &[f32]) -> f64 {
    let mut logs: Vec<f64> = x
        .iter()
        .filter(|&&v| v != 0.0)
        .map(|&v| (v.abs() as f64).log10())
        .collect();
    logs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    logs[logs.len() / 2]
}

fn main() {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping fig1 bench: {e}");
            return;
        }
    };
    let mut bench = from_env();
    bench.max_iters = 10;

    let engine = Engine::load(&manifest, "cnn_small").unwrap();
    let h = engine.handle();
    let meta = h.meta().clone();
    let spec = synthetic::SyntheticSpec::for_input_shape(&meta.input_shape, 1024, 1);
    let task = synthetic::generate(&spec, 7);
    let shards = partition(&task.train, 1, Partition::Iid, 7);
    let mut device = Device::new(
        0,
        Shard {
            data: shards.into_iter().next().unwrap(),
        },
        h.clone(),
    );
    let run = LocalRunConfig {
        local_epochs: 1,
        max_batches_per_epoch: 4,
        lr: 0.001,
        use_epoch_program: true,
    };
    let w0 = h.init(7).unwrap();
    let zeros = vec![0.0f32; meta.dim];

    let mut deltas = (vec![0.0f32; meta.dim], vec![0.0f32; meta.dim], vec![0.0f32; meta.dim]);
    bench.run("local round -> (dW,dM,dV) extraction", || {
        let r = device
            .train_round(LocalMode::Adam, w0.clone(), zeros.clone(), zeros.clone(), &run)
            .unwrap();
        deltas = (
            tensor::sub(&r.w, &w0),
            tensor::sub(&r.m, &zeros),
            tensor::sub(&r.v, &zeros),
        );
        black_box(&deltas);
    });
    bench.run("log-histogram of 3 x d deltas", || {
        black_box((
            median_log10(&deltas.0),
            median_log10(&deltas.1),
            median_log10(&deltas.2),
        ));
    });

    let (mw, mm, mv) = (
        median_log10(&deltas.0),
        median_log10(&deltas.1),
        median_log10(&deltas.2),
    );
    println!("medians: dW {mw:.2}  dM {mm:.2}  dV {mv:.2}");
    assert!(mw > mm && mm > mv, "Fig. 1 ordering must hold");

    bench.report("Fig. 1 pipeline");
    println!("\n{}", bench.to_csv());
}
