//! s-level uniform quantization — the Efficient-Adam compressor [28].
//!
//! Deterministic rounding over `[-max|x|, max|x|]` with `s` representable
//! levels; wire format is `ceil(log2 s)` bits per lane + one f32 scale.
//! Matches `compile/kernels/quantize.py::uniform_quantize`.

use crate::sparse::codec::{index_bits, BitPacker, BitUnpacker, DecodeError};

/// Packed s-level payload.
#[derive(Clone, Debug)]
pub struct UniformPacket {
    pub dim: usize,
    pub scale: f32,
    pub levels: u32,
    pub codes: Vec<u8>,
}

impl UniformPacket {
    /// Wire size: `d * ceil(log2 s)` bits + 32-bit scale.
    pub fn wire_bits(&self) -> u64 {
        self.dim as u64 * index_bits(self.levels as usize + 1) + 32
    }
}

/// Quantize to `s_levels` representable values (`s_levels >= 2`).
pub fn uniform_compress(x: &[f32], s_levels: u32) -> UniformPacket {
    assert!(s_levels >= 2, "need at least 2 levels");
    let levels = s_levels - 1; // number of bins
    let scale = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let bits = index_bits(s_levels as usize);
    let mut packer = BitPacker::with_capacity(x.len() * bits as usize);
    let safe = scale.max(1e-30);
    for &v in x {
        let t = (v / safe).clamp(-1.0, 1.0);
        let q = ((t + 1.0) * 0.5 * levels as f32).round() as u64;
        packer.push(q, bits);
    }
    UniformPacket {
        dim: x.len(),
        scale,
        levels,
        codes: packer.finish(),
    }
}

/// Dequantize.
///
/// Trusted in-process path (the packet came from [`uniform_compress`] in
/// this address space); transport-facing callers must use
/// [`try_uniform_decompress`].
pub fn uniform_decompress(p: &UniformPacket) -> Vec<f32> {
    dequantize_codes(&p.codes, p.dim, p.scale, p.levels)
}

/// Fallible [`uniform_decompress`] for untrusted bytes: never panics, and
/// only accepts the canonical output of [`uniform_compress`] — exact code
/// length, every code on the `s`-level grid, zero padding bits, and a
/// finite non-negative scale.
pub fn try_uniform_decompress(p: &UniformPacket) -> Result<Vec<f32>, DecodeError> {
    try_dequantize_codes(&p.codes, p.dim, p.scale, p.levels)
}

/// Unpack `n` codes and map them back onto the s-level grid — the shared
/// back half of the dense ([`uniform_decompress`]) and sparse
/// (`super::sparse_uniform`) decompressors, so the grid math lives once.
pub(crate) fn dequantize_codes(codes: &[u8], n: usize, scale: f32, levels: u32) -> Vec<f32> {
    if scale == 0.0 {
        // All inputs were exactly 0.0 — reconstruct them exactly.
        return vec![0.0; n];
    }
    let bits = index_bits(levels as usize + 1);
    let mut u = BitUnpacker::new(codes);
    (0..n)
        .map(|_| {
            let q = u.pull(bits) as f32;
            (q / levels as f32 * 2.0 - 1.0) * scale
        })
        .collect()
}

/// Fallible twin of [`dequantize_codes`] — the shared validation core of
/// the dense and sparse untrusted decompressors.  Checks the structural
/// invariants the trusted path assumes: `codes` holds exactly
/// `ceil(n·ceil(log₂ s) / 8)` bytes, every code is `<= levels`, padding
/// bits are zero, and the scale is a finite non-negative f32.
pub(crate) fn try_dequantize_codes(
    codes: &[u8],
    n: usize,
    scale: f32,
    levels: u32,
) -> Result<Vec<f32>, DecodeError> {
    if levels == 0 {
        return Err(DecodeError::BadValue("quantizer with zero levels"));
    }
    if !scale.is_finite() || scale < 0.0 {
        return Err(DecodeError::BadValue("non-finite or negative quantizer scale"));
    }
    let bits = index_bits(levels as usize + 1);
    let total_bits = n * bits as usize;
    let expected = total_bits.div_ceil(8);
    if codes.len() != expected {
        return Err(DecodeError::PayloadSize {
            expected,
            got: codes.len(),
        });
    }
    let mut u = BitUnpacker::new(codes);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let q = u.try_pull(bits)?;
        if q > levels as u64 {
            return Err(DecodeError::BadValue("quantizer code above top level"));
        }
        out.push(if scale == 0.0 {
            0.0
        } else {
            (q as f32 / levels as f32 * 2.0 - 1.0) * scale
        });
    }
    let pad = (expected * 8 - total_bits) as u64;
    if pad > 0 && u.try_pull(pad)? != 0 {
        return Err(DecodeError::BadValue("nonzero code padding bits"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn try_decompress_accepts_canonical_and_rejects_malformed() {
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
        for &s in &[2u32, 3, 16] {
            let p = uniform_compress(&x, s);
            assert_eq!(try_uniform_decompress(&p).unwrap(), uniform_decompress(&p));

            let mut short = p.clone();
            short.codes.truncate(short.codes.len() - 1);
            assert!(matches!(
                try_uniform_decompress(&short),
                Err(DecodeError::PayloadSize { .. })
            ));

            let mut bad_scale = p.clone();
            bad_scale.scale = f32::NAN;
            assert!(try_uniform_decompress(&bad_scale).is_err());
        }
        // Non-power-of-two s leaves unused code points: reject them.
        let p = uniform_compress(&x, 3); // 2 bits/lane, code 3 invalid
        let mut evil = p.clone();
        evil.codes[0] |= 0b11; // first lane -> code 3 > levels (2)
        assert!(matches!(
            try_uniform_decompress(&evil),
            Err(DecodeError::BadValue("quantizer code above top level"))
        ));
    }

    #[test]
    fn roundtrip_error_bounded_by_bin_width() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        for &s in &[2u32, 4, 16, 256] {
            let p = uniform_compress(&x, s);
            let y = uniform_decompress(&p);
            let bin = 2.0 * p.scale / (s - 1) as f32;
            for (xi, yi) in x.iter().zip(&y) {
                assert!(
                    (xi - yi).abs() <= bin / 2.0 + 1e-5,
                    "s={s} x={xi} y={yi} bin={bin}"
                );
            }
        }
    }

    #[test]
    fn zero_vector() {
        let p = uniform_compress(&[0.0; 16], 16);
        assert_eq!(p.scale, 0.0);
        assert_eq!(uniform_decompress(&p), vec![0.0; 16]);
    }

    #[test]
    fn wire_bits_counts_levels() {
        let x = vec![1.0f32; 64];
        let p = uniform_compress(&x, 16); // 4 bits per lane
        assert_eq!(p.wire_bits(), 64 * 4 + 32);
        let p2 = uniform_compress(&x, 2); // 1 bit per lane
        assert_eq!(p2.wire_bits(), 64 + 32);
    }

    #[test]
    fn extremes_map_to_extremes() {
        let x = vec![-3.0f32, 3.0, 0.0];
        let p = uniform_compress(&x, 3); // levels at -3, 0, +3
        let y = uniform_decompress(&p);
        assert_eq!(y, vec![-3.0, 3.0, 0.0]);
    }
}
