//! Offline stand-in for the `anyhow` crate: the API subset this repository
//! uses (`Error`, `Result`, `Context`, `anyhow!`, `bail!`, `ensure!`),
//! implemented over a plain message + context chain.
//!
//! The container building this repo has no crates.io access, so the real
//! crate is vendored as this minimal subset.  Swapping in upstream `anyhow`
//! is a one-line `Cargo.toml` change; nothing in the repo depends on stub
//! internals.

use std::fmt;

/// A context-chained error value (message + optional cause).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    fn fmt_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, as upstream anyhow does.
            self.fmt_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.fmt_chain(f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into the message chain.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        err.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 42))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1, "too small: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(11).is_err());
    }
}
