//! The transport protocol's message vocabulary.
//!
//! Four messages run a federated round over a socket:
//!
//! - [`Msg::Hello`] / [`Msg::HelloAck`] — registration handshake.  The
//!   agent declares the protocol version, its **config fingerprint** and
//!   its agent index; the server refuses a fingerprint that differs from
//!   its own (a remote run is only bit-identical to the in-process run if
//!   every process resolved the *same* determinism-bearing knobs — the
//!   fingerprint is exactly that set, see
//!   [`crate::config::ExperimentConfig::fingerprint`]).  The ack pins the
//!   agent count and model dimension the agent must agree on.
//! - [`Msg::RoundStart`] — one round's downlink: the global model (and
//!   the aggregated moments when the algorithm's policy is
//!   `Aggregated`), plus the full cohort assignment list.  Every agent
//!   receives the whole cohort and trains the slice it owns
//!   (`device % agents == agent_index`).
//! - [`Msg::Uplink`] — one device's compressed update: the wire-codec
//!   header `(kind, k, levels, bits)` plus the body bytes that
//!   [`crate::algorithms::wire::WireBody::try_decode`] validates.  The
//!   body length is *separately* checked against `ceil(bits / 8)` by the
//!   server — the framed-byte accounting invariant.
//! - [`Msg::Shutdown`] — the run is over; agents exit cleanly.
//!
//! Encoding is the journal's [`ByteWriter`]/[`ByteReader`] little-endian
//! codec with a leading tag byte; floats travel as raw bits so the
//! handshake and payloads are bit-exact.  [`Msg::decode`] is untrusted:
//! truncated, oversized or trailing-garbage payloads error (never panic),
//! and length prefixes are allocation-guarded by the reader.

use anyhow::{bail, ensure, Result};

use crate::util::bytes::{ByteReader, ByteWriter};

/// Bumped on any wire-incompatible change; the handshake refuses a
/// mismatch before anything else is parsed.
pub const PROTOCOL_VERSION: u32 = 1;

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_ROUND_START: u8 = 3;
const TAG_UPLINK: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// One cohort slot: which device trains it and the FedAvg weight the
/// sampler assigned (bit-exact f64 — the server verifies the uplink
/// echoes it unchanged).
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub slot: u32,
    pub device: u32,
    pub weight: f64,
}

/// One device's compressed uplink message.
#[derive(Clone, Debug, PartialEq)]
pub struct Uplink {
    pub round: u64,
    pub slot: u32,
    pub device: u32,
    /// Mean local training loss (bit-exact f64; folded server-side in
    /// ascending slot order).
    pub mean_loss: f64,
    /// FedAvg weight — must echo the assignment bit-for-bit.
    pub weight: f64,
    /// Wire-codec header: body variant tag ([`crate::algorithms::wire`]).
    pub kind: u8,
    /// Mask support size (0 for dense/whole-`d` bodies).
    pub k: u64,
    /// Quantizer bin count `s - 1` (0 for unquantized bodies).
    pub levels: u32,
    /// Priced ledger bits; `body.len()` must equal `ceil(bits / 8)`.
    pub bits: u64,
    /// The contiguous bitstream [`crate::algorithms::wire::WireBody::encode`] produced.
    pub body: Vec<u8>,
}

/// Everything that crosses the transport, agent ⇄ server.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Agent → server registration.
    Hello {
        version: u32,
        fingerprint: u64,
        agent: u32,
    },
    /// Server → agent registration accept.
    HelloAck { agents: u32, dim: u64 },
    /// Server → every agent: one round's downlink.
    RoundStart {
        round: u64,
        w: Vec<f32>,
        /// Aggregated global moments — present iff the algorithm's
        /// momentum policy for this round is `Aggregated`.
        m: Option<Vec<f32>>,
        v: Option<Vec<f32>>,
        assignments: Vec<Assignment>,
    },
    /// Agent → server: one finished device slot.
    Uplink(Uplink),
    /// Server → agents: the run is complete.
    Shutdown,
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Msg::Hello {
                version,
                fingerprint,
                agent,
            } => {
                w.put_u8(TAG_HELLO);
                w.put_u32(*version);
                w.put_u64(*fingerprint);
                w.put_u32(*agent);
            }
            Msg::HelloAck { agents, dim } => {
                w.put_u8(TAG_HELLO_ACK);
                w.put_u32(*agents);
                w.put_u64(*dim);
            }
            Msg::RoundStart {
                round,
                w: model,
                m,
                v,
                assignments,
            } => {
                w.put_u8(TAG_ROUND_START);
                w.put_u64(*round);
                w.put_f32s(model);
                put_opt_f32s(&mut w, m);
                put_opt_f32s(&mut w, v);
                w.put_usize(assignments.len());
                for a in assignments {
                    w.put_u32(a.slot);
                    w.put_u32(a.device);
                    w.put_f64(a.weight);
                }
            }
            Msg::Uplink(u) => {
                w.put_u8(TAG_UPLINK);
                w.put_u64(u.round);
                w.put_u32(u.slot);
                w.put_u32(u.device);
                w.put_f64(u.mean_loss);
                w.put_f64(u.weight);
                w.put_u8(u.kind);
                w.put_u64(u.k);
                w.put_u32(u.levels);
                w.put_u64(u.bits);
                w.put_bytes(&u.body);
            }
            Msg::Shutdown => w.put_u8(TAG_SHUTDOWN),
        }
        w.into_inner()
    }

    /// Decode an untrusted frame payload.  Errors (never panics) on a
    /// bad tag, truncation, or trailing bytes.
    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        let mut r = ByteReader::new(bytes);
        let msg = match r.take_u8()? {
            TAG_HELLO => Msg::Hello {
                version: r.take_u32()?,
                fingerprint: r.take_u64()?,
                agent: r.take_u32()?,
            },
            TAG_HELLO_ACK => Msg::HelloAck {
                agents: r.take_u32()?,
                dim: r.take_u64()?,
            },
            TAG_ROUND_START => {
                let round = r.take_u64()?;
                let w = r.take_f32s()?;
                let m = take_opt_f32s(&mut r)?;
                let v = take_opt_f32s(&mut r)?;
                let n = r.take_usize()?;
                ensure!(
                    n <= r.remaining(),
                    "assignment count {n} exceeds the remaining payload"
                );
                let mut assignments = Vec::with_capacity(n);
                for _ in 0..n {
                    assignments.push(Assignment {
                        slot: r.take_u32()?,
                        device: r.take_u32()?,
                        weight: r.take_f64()?,
                    });
                }
                Msg::RoundStart {
                    round,
                    w,
                    m,
                    v,
                    assignments,
                }
            }
            TAG_UPLINK => Msg::Uplink(Uplink {
                round: r.take_u64()?,
                slot: r.take_u32()?,
                device: r.take_u32()?,
                mean_loss: r.take_f64()?,
                weight: r.take_f64()?,
                kind: r.take_u8()?,
                k: r.take_u64()?,
                levels: r.take_u32()?,
                bits: r.take_u64()?,
                body: r.take_bytes()?,
            }),
            TAG_SHUTDOWN => Msg::Shutdown,
            tag => bail!("unknown transport message tag {tag}"),
        };
        r.finish()?;
        Ok(msg)
    }
}

fn put_opt_f32s(w: &mut ByteWriter, v: &Option<Vec<f32>>) {
    match v {
        Some(v) => {
            w.put_bool(true);
            w.put_f32s(v);
        }
        None => w.put_bool(false),
    }
}

fn take_opt_f32s(r: &mut ByteReader) -> Result<Option<Vec<f32>>> {
    Ok(if r.take_bool()? {
        Some(r.take_f32s()?)
    } else {
        None
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_msgs() -> Vec<Msg> {
        vec![
            Msg::Hello {
                version: PROTOCOL_VERSION,
                fingerprint: 0xDEAD_BEEF_CAFE_F00D,
                agent: 3,
            },
            Msg::HelloAck { agents: 4, dim: 577 },
            Msg::RoundStart {
                round: 9,
                w: vec![1.5, -0.0, f32::NEG_INFINITY],
                m: Some(vec![0.25]),
                v: None,
                assignments: vec![
                    Assignment {
                        slot: 0,
                        device: 2,
                        weight: 125.0,
                    },
                    Assignment {
                        slot: 1,
                        device: 3,
                        weight: 130.5,
                    },
                ],
            },
            Msg::Uplink(Uplink {
                round: 9,
                slot: 1,
                device: 3,
                mean_loss: 2.302,
                weight: 130.5,
                kind: 3,
                k: 5,
                levels: 0,
                bits: 41,
                body: vec![0xFF, 0x01, 0x00, 0x7A, 0x10, 0x02],
            }),
            Msg::Shutdown,
        ]
    }

    #[test]
    fn every_message_roundtrips() {
        for msg in all_msgs() {
            let bytes = msg.encode();
            assert_eq!(Msg::decode(&bytes).unwrap(), msg, "{msg:?}");
        }
    }

    #[test]
    fn truncations_and_trailing_bytes_error() {
        for msg in all_msgs() {
            let bytes = msg.encode();
            for cut in 0..bytes.len() {
                assert!(
                    Msg::decode(&bytes[..cut]).is_err(),
                    "{msg:?} truncated to {cut} decoded"
                );
            }
            let mut long = bytes.clone();
            long.push(0);
            assert!(Msg::decode(&long).is_err(), "{msg:?} + trailing byte decoded");
        }
        assert!(Msg::decode(&[99]).is_err(), "unknown tag decoded");
    }

    #[test]
    fn hostile_lengths_cannot_drive_allocations() {
        // A RoundStart whose model-length prefix claims 2^61 floats must
        // error on the reader's allocation guard, not OOM.
        let mut w = ByteWriter::new();
        w.put_u8(3); // TAG_ROUND_START
        w.put_u64(0);
        w.put_u64(u64::MAX / 4); // hostile f32 count
        let err = Msg::decode(&w.into_inner());
        assert!(err.is_err());
    }
}
