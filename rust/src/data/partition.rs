//! Federated data partitioning (paper §VII-A *Data distribution*).
//!
//! IID: a uniform random split.  Non-IID: per-class Dirichlet(θ) allocation
//! across devices following Yurochkin et al. / Wang et al. — the papers the
//! authors cite — with θ = 0.1 as the paper's default (lower θ = more skew).

use super::Dataset;
use crate::rng::Rng;

/// How to split the training corpus across devices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    Iid,
    /// Dirichlet with concentration θ.
    Dirichlet(f64),
}

impl Partition {
    pub fn parse(iid: bool, theta: f64) -> Self {
        if iid {
            Partition::Iid
        } else {
            Partition::Dirichlet(theta)
        }
    }
}

/// The whole fleet's shard assignment in compressed (CSR-like) form —
/// *which* sample indices belong to *which* device, without materializing
/// a single pixel.  Built once at registration (O(corpus) index words);
/// a device's actual [`Dataset`] is synthesized on demand with
/// [`ShardPlan::materialize`] only when a round samples it, so holding a
/// registered fleet of 10⁶ devices never costs a second copy of the
/// corpus.
///
/// `materialize(data, d)` is pinned (by `plan_materializes_the_exact_partition`)
/// to equal `partition(data, devices, how, seed)[d]` bit-for-bit — the
/// plan is a memory layout, never a semantics change.
pub struct ShardPlan {
    /// `offsets[d] .. offsets[d+1]` delimits device `d`'s slice of `index`.
    offsets: Vec<u64>,
    /// Sample indices grouped by device (each group in assignment order).
    index: Vec<u32>,
}

impl ShardPlan {
    /// Run the partition assignment (same RNG stream as [`partition`])
    /// and store only the index structure.
    pub fn build(data: &Dataset, devices: usize, how: Partition, seed: u64) -> ShardPlan {
        let assignment = assign(data, devices, how, seed);
        assert!(data.len() <= u32::MAX as usize, "sample ids must fit in u32");
        let mut offsets = Vec::with_capacity(devices + 1);
        let mut index = Vec::with_capacity(data.len());
        offsets.push(0u64);
        for shard in &assignment {
            index.extend(shard.iter().map(|&s| s as u32));
            offsets.push(index.len() as u64);
        }
        ShardPlan { offsets, index }
    }

    /// Registered fleet size.
    pub fn devices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Device `d`'s shard size in samples — O(1), no materialization.
    pub fn shard_len(&self, d: usize) -> usize {
        (self.offsets[d + 1] - self.offsets[d]) as usize
    }

    /// Device `d`'s sample indices (assignment order).
    pub fn shard_indices(&self, d: usize) -> &[u32] {
        &self.index[self.offsets[d] as usize..self.offsets[d + 1] as usize]
    }

    /// Synthesize device `d`'s dataset from the shared corpus — exactly
    /// the shard [`partition`] would have built eagerly.
    pub fn materialize(&self, data: &Dataset, d: usize) -> Dataset {
        let idx: Vec<usize> = self.shard_indices(d).iter().map(|&s| s as usize).collect();
        data.subset(&idx)
    }
}

/// Split `data` into `devices` shards; every sample is assigned exactly once
/// and every device receives at least one sample.
pub fn partition(data: &Dataset, devices: usize, how: Partition, seed: u64) -> Vec<Dataset> {
    assign(data, devices, how, seed)
        .iter()
        .map(|idx| data.subset(idx))
        .collect()
}

/// The shared assignment core of [`partition`] and [`ShardPlan`]: one RNG
/// stream (`seed ^ 0x9a11_0c0d`), one deal, one non-empty-shard repair —
/// so the eager and lazy paths cannot drift.
fn assign(data: &Dataset, devices: usize, how: Partition, seed: u64) -> Vec<Vec<usize>> {
    assert!(devices > 0);
    let mut rng = Rng::new(seed ^ 0x9a11_0c0d);
    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); devices];

    match how {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..data.len()).collect();
            rng.shuffle(&mut idx);
            for (i, sample) in idx.into_iter().enumerate() {
                assignment[i % devices].push(sample);
            }
        }
        Partition::Dirichlet(theta) => {
            // Per class: draw device proportions ~ Dir(theta), then deal the
            // class's samples out by those proportions.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); data.num_classes];
            for (i, &l) in data.labels.iter().enumerate() {
                by_class[l as usize].push(i);
            }
            for samples in by_class.iter_mut() {
                rng.shuffle(samples);
                let props = rng.dirichlet(theta, devices);
                // Largest-remainder apportionment of samples to devices.
                let n = samples.len();
                let mut counts: Vec<usize> = props.iter().map(|p| (p * n as f64) as usize).collect();
                let mut assigned: usize = counts.iter().sum();
                // Distribute the remainder to the devices with largest share.
                let mut order: Vec<usize> = (0..devices).collect();
                order.sort_by(|&a, &b| props[b].partial_cmp(&props[a]).unwrap());
                let mut oi = 0;
                while assigned < n {
                    counts[order[oi % devices]] += 1;
                    assigned += 1;
                    oi += 1;
                }
                let mut cursor = 0;
                for (dev, &c) in counts.iter().enumerate() {
                    assignment[dev].extend_from_slice(&samples[cursor..cursor + c]);
                    cursor += c;
                }
            }
        }
    }

    // Guarantee non-empty shards: steal one sample from the largest shard.
    for dev in 0..devices {
        if assignment[dev].is_empty() {
            let donor = (0..devices)
                .max_by_key(|&d| assignment[d].len())
                .unwrap();
            if let Some(s) = assignment[donor].pop() {
                assignment[dev].push(s);
            }
        }
    }

    assignment
}

/// Earth-mover-ish skew metric: mean total-variation distance between each
/// shard's class distribution and the global one (0 = IID, →1 = disjoint).
pub fn label_skew(shards: &[Dataset]) -> f64 {
    if shards.is_empty() {
        return 0.0;
    }
    let classes = shards[0].num_classes;
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let mut global = vec![0.0f64; classes];
    for s in shards {
        for (c, &n) in s.class_histogram().iter().enumerate() {
            global[c] += n as f64;
        }
    }
    for g in &mut global {
        *g /= total as f64;
    }
    let mut tv = 0.0;
    for s in shards {
        let h = s.class_histogram();
        let n = s.len().max(1) as f64;
        let mut dist = 0.0;
        for c in 0..classes {
            dist += (h[c] as f64 / n - global[c]).abs();
        }
        tv += dist / 2.0;
    }
    tv / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn task() -> Dataset {
        generate(&SyntheticSpec::fashion_mnist_like(2000, 10), 1).train
    }

    #[test]
    fn partition_is_exact_cover() {
        let data = task();
        for how in [Partition::Iid, Partition::Dirichlet(0.1)] {
            let shards = partition(&data, 7, how, 42);
            let total: usize = shards.iter().map(|s| s.len()).sum();
            assert_eq!(total, data.len(), "{how:?}");
            assert!(shards.iter().all(|s| !s.is_empty()), "{how:?}");
        }
    }

    #[test]
    fn iid_shards_balanced() {
        let data = task();
        let shards = partition(&data, 10, Partition::Iid, 1);
        for s in &shards {
            assert!((s.len() as i64 - 200).abs() <= 1);
        }
        assert!(label_skew(&shards) < 0.1);
    }

    #[test]
    fn dirichlet_low_theta_is_skewed() {
        let data = task();
        let iid = label_skew(&partition(&data, 10, Partition::Iid, 2));
        let noniid = label_skew(&partition(&data, 10, Partition::Dirichlet(0.1), 2));
        assert!(
            noniid > iid + 0.2,
            "Dirichlet(0.1) should be much more skewed: iid={iid:.3} noniid={noniid:.3}"
        );
    }

    #[test]
    fn deterministic_by_seed() {
        let data = task();
        let a = partition(&data, 5, Partition::Dirichlet(0.5), 9);
        let b = partition(&data, 5, Partition::Dirichlet(0.5), 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
        }
    }

    #[test]
    fn plan_materializes_the_exact_partition() {
        let data = task();
        for how in [Partition::Iid, Partition::Dirichlet(0.1)] {
            let eager = partition(&data, 7, how, 42);
            let plan = ShardPlan::build(&data, 7, how, 42);
            assert_eq!(plan.devices(), 7, "{how:?}");
            let total: usize = (0..7).map(|d| plan.shard_len(d)).sum();
            assert_eq!(total, data.len(), "{how:?}");
            for (d, shard) in eager.iter().enumerate() {
                assert_eq!(plan.shard_len(d), shard.len(), "{how:?} device {d}");
                let lazy = plan.materialize(&data, d);
                assert_eq!(lazy.labels, shard.labels, "{how:?} device {d}");
                let lb: Vec<u32> = lazy.images.iter().map(|v| v.to_bits()).collect();
                let eb: Vec<u32> = shard.images.iter().map(|v| v.to_bits()).collect();
                assert_eq!(lb, eb, "{how:?} device {d}");
            }
        }
    }

    #[test]
    fn more_devices_than_samples() {
        let data = generate(&SyntheticSpec::fashion_mnist_like(3, 1), 5).train;
        let shards = partition(&data, 3, Partition::Dirichlet(0.1), 1);
        assert!(shards.iter().all(|s| !s.is_empty()));
    }
}
