//! Hand-rolled CLI parsing (offline build: no clap).
//!
//! Grammar: `fedadam-ssm <command> [--key value] [--key=value] [--flag]
//! [--set cfg_key=value]...`.  `--set` is repeatable and maps straight onto
//! [`crate::config::ExperimentConfig::set`] — every runtime knob,
//! including the performance trio `num_workers` / `agg_shards` /
//! `pipeline_depth` and the quantized-SSM pair `algorithm=fedadam-ssm-q` /
//! `quant_levels=s`, rides through here with no dedicated flags.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub sets: Vec<(String, String)>,
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an iterator of args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                cli.command = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let value = match inline_val {
                    Some(v) => Some(v),
                    None => {
                        // Next token is the value unless it looks like a flag.
                        if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                            Some(it.next().unwrap())
                        } else {
                            None
                        }
                    }
                };
                if key == "set" {
                    let v = value.ok_or_else(|| {
                        anyhow::anyhow!("--set requires key=value")
                    })?;
                    let (k, val) = v
                        .split_once('=')
                        .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got {v:?}"))?;
                    cli.sets.push((k.to_string(), val.to_string()));
                } else {
                    cli.options.insert(key, value.unwrap_or_else(|| "true".into()));
                }
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.opt(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(_) => bail!("invalid value {v:?} for --{key}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Cli {
        Cli::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_options_and_sets() {
        let c = parse(&[
            "run",
            "--config",
            "x.toml",
            "--out=results",
            "--set",
            "lr=0.01",
            "--set",
            "algorithm=fedadam-top",
            "--verbose",
        ]);
        assert_eq!(c.command, "run");
        assert_eq!(c.opt("config"), Some("x.toml"));
        assert_eq!(c.opt("out"), Some("results"));
        assert_eq!(
            c.sets,
            vec![
                ("lr".into(), "0.01".into()),
                ("algorithm".into(), "fedadam-top".into())
            ]
        );
        assert!(c.flag("verbose"));
        assert!(!c.flag("quiet"));
    }

    #[test]
    fn quantized_ssm_knobs_ride_through_set() {
        // The quantized-SSM pair has no dedicated flags: algorithm id and
        // s both travel via --set and must land on a valid config.
        let c = parse(&[
            "run",
            "--set",
            "algorithm=fedadam-ssm-q",
            "--set",
            "quant_levels=4",
        ]);
        let mut cfg = crate::config::ExperimentConfig::default();
        for (k, v) in &c.sets {
            cfg.set(k, v).unwrap();
        }
        assert_eq!(cfg.algorithm, "fedadam-ssm-q");
        assert_eq!(cfg.quant_levels, 4);
        cfg.validate().unwrap();
        cfg.quant_levels = 1;
        assert!(cfg.validate().unwrap_err().to_string().contains("fedadam-ssm-q"));
    }

    #[test]
    fn no_command() {
        let c = parse(&["--help"]);
        assert_eq!(c.command, "");
        assert!(c.flag("help"));
    }

    #[test]
    fn bad_set_rejected() {
        assert!(Cli::parse(vec!["run".to_string(), "--set".into(), "oops".into()]).is_err());
    }

    #[test]
    fn opt_parse_types() {
        let c = parse(&["run", "--rounds", "12"]);
        assert_eq!(c.opt_parse::<usize>("rounds").unwrap(), Some(12));
        assert_eq!(c.opt_parse::<usize>("absent").unwrap(), None);
        let bad = parse(&["run", "--rounds", "abc"]);
        assert!(bad.opt_parse::<usize>("rounds").is_err());
    }
}
