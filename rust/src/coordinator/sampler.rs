//! Pluggable partial-participation device sampling.
//!
//! Every round the coordinator asks its [`ParticipationSampler`] for a
//! [`Cohort`]: *which* devices train, and *what FedAvg weight* each one's
//! upload carries through the aggregation path.  Three deterministic,
//! seed-driven implementations sit behind the `participation_mode` knob:
//!
//! - [`UniformSampler`] (`uniform`, default) — uniform without
//!   replacement, **bit-identical to the original loop**: the same RNG
//!   stream (`seed ^ 0x5a3c_91f7`), the same shuffle/truncate/sort, and
//!   cohort weights equal to the devices' data sizes.  Internally the
//!   full `Vec` shuffle is replaced by an epoch-stamped sparse window
//!   (`ShuffleWindow`) that replays the identical Fisher–Yates draw
//!   sequence while only ever *writing* O(fleet − cohort) positions and
//!   allocating O(cohort) per round.  Note the pinned legacy stream
//!   consumes `n − 1` RNG draws per partial round, so uniform is
//!   inherently Θ(fleet) RNG *steps* per round — the O(cohort)-per-round
//!   scaling story belongs to `importance` (and `availability`'s ranking);
//!   uniform's win here is allocation- and write-traffic-flatness.
//! - [`ImportanceSampler`] (`importance`) — `m` i.i.d. draws with
//!   probability `p_i ∝ |D_i|` (local data size), drawn in O(1) each from
//!   a Walker/Vose [`AliasTable`] built once at construction (the old
//!   per-draw `categorical` linear scan made every round O(m·fleet)).
//!   Each unique selected device carries weight `mult_i · |D_i| /
//!   (m·p_i)`, the classical unbiased importance re-weighting: the
//!   cohort's weighted FedAvg aggregate has the full-participation
//!   aggregate as its expectation, and the cohort weights always sum to
//!   the full corpus weight, so the downstream `weight / Σweights`
//!   normalization *is* the `1/(m·p_i)` estimator.
//! - [`AvailabilitySampler`] (`availability`) — each device follows a
//!   deterministic per-round on/off duty-cycle trace (a pure function of
//!   `(seed, device, round)`).  The sampler over-selects up to
//!   `ceil(target · over_select)` available candidates, then enforces the
//!   round deadline by keeping the `target` fastest (by simulated compute
//!   latency, ties by id) and dropping the over-selected stragglers.  A
//!   floor of one device is always enforced — an all-off round falls back
//!   to a deterministic single device.
//!
//! All three are pure functions of `(config, data sizes, latencies,
//! round)` — no host entropy, no wall clock — so cohorts are identical at
//! any `num_workers` / `agg_shards` / `pipeline_depth`.
//!
//! ```
//! use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
//! use fedadam_ssm::coordinator::sampler::{self, ParticipationSampler as _};
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.participation = 0.5;
//! cfg.participation_mode = ParticipationMode::Importance;
//! let data = [60.0, 30.0, 10.0, 20.0];
//! let latency = [0.0; 4];
//! let mut a = sampler::build(&cfg, &data, &latency);
//! let mut b = sampler::build(&cfg, &data, &latency);
//! // Seed-deterministic: an identically-built sampler replays the cohort.
//! let cohort = a.sample(0);
//! assert_eq!(cohort.devices, b.sample(0).devices);
//! assert!(!cohort.devices.is_empty());
//! ```

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{ExperimentConfig, ParticipationMode};
use crate::rng::Rng;
use crate::util::bytes::{ByteReader, ByteWriter};

/// The legacy participation stream tag (pre-sampler coordinator seeded its
/// shuffle RNG with `seed ^ 0x5a3c_91f7`) — [`UniformSampler`] must keep
/// it to stay bit-identical.
const UNIFORM_STREAM: u64 = 0x5a3c_91f7;
/// Importance-draw stream tag (domain-separated from every other seed use).
const IMPORTANCE_STREAM: u64 = 0x7e2d_9b14_55c3_a86f;
/// Availability duty-cycle trace tag.
const TRACE_STREAM: u64 = 0x3f91_44d0_8ae7_125b;
/// Availability per-round candidate-shuffle tag.
const SELECT_STREAM: u64 = 0xc65a_07e9_31fd_b842;

/// One round's participants: device ids (ascending, unique) and the
/// FedAvg weight each upload carries (same order).
#[derive(Clone, Debug, PartialEq)]
pub struct Cohort {
    /// Participating device ids, strictly ascending.
    pub devices: Vec<usize>,
    /// Effective FedAvg weight per participant (aligned with `devices`).
    pub weights: Vec<f64>,
}

impl Cohort {
    /// Number of participating devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// `true` when no device participates (samplers never produce this —
    /// a floor of one device is enforced everywhere).
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Sum of the cohort's FedAvg weights.
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }
}

/// Per-round cohort selection strategy — one instance per experiment.
pub trait ParticipationSampler: Send {
    /// Stable id (matches `ParticipationMode::as_str`).
    fn name(&self) -> &'static str;

    /// The cohort for communication round `round`.  Must be deterministic
    /// given the constructor inputs and `round`, return strictly
    /// ascending unique device ids, and never be empty.
    fn sample(&mut self, round: usize) -> Cohort;

    /// Serialize the sampler's advancing cursor (RNG stream position) into
    /// a journal snapshot.  Stateless samplers (pure functions of `round`)
    /// write nothing.
    fn save_state(&self, out: &mut ByteWriter) {
        let _ = out;
    }

    /// Restore the cursor written by [`Self::save_state`].
    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let _ = input;
        Ok(())
    }
}

/// Target cohort size: `round(n · participation)` clamped to `[1, n]` —
/// the exact formula of the original loop.
pub fn target_cohort_size(devices: usize, participation: f64) -> usize {
    ((devices as f64 * participation).round() as usize).clamp(1, devices)
}

/// Build the sampler the config asks for.  `data_weights[i]` is device
/// `i`'s FedAvg data weight (`|D_i|`); `compute_secs[i]` its simulated
/// per-round compute latency (the availability deadline ranking).
pub fn build(
    cfg: &ExperimentConfig,
    data_weights: &[f64],
    compute_secs: &[f64],
) -> Box<dyn ParticipationSampler> {
    assert_eq!(
        data_weights.len(),
        compute_secs.len(),
        "one latency per device"
    );
    match cfg.participation_mode {
        ParticipationMode::Uniform => Box::new(UniformSampler::new(
            cfg.seed,
            cfg.participation,
            data_weights.to_vec(),
        )),
        ParticipationMode::Importance => Box::new(ImportanceSampler::new(
            cfg.seed,
            cfg.participation,
            data_weights.to_vec(),
        )),
        ParticipationMode::Availability => Box::new(AvailabilitySampler::new(
            cfg.seed,
            cfg.participation,
            cfg.duty_cycle,
            cfg.over_select,
            data_weights.to_vec(),
            compute_secs.to_vec(),
        )),
    }
}

/// Epoch-stamped sparse view of the virtual shuffle array `[0, 1, …, n)`.
///
/// The legacy cohort draw allocated and shuffled a dense `Vec` of the
/// whole fleet every round.  This window replays the *identical* backward
/// Fisher–Yates draw sequence against a virtual array whose untouched
/// position `i` implicitly holds value `i`: a write stamps the position
/// with the current epoch, a read returns the stamped value only when the
/// stamp matches, and bumping the epoch "clears" the whole array in O(1).
/// Two flat `Vec<u32>`s are paid once at construction (O(fleet) at
/// registration); per round there is no allocation, no O(fleet) zeroing,
/// and no dense swap traffic.
struct ShuffleWindow {
    /// Epoch stamp per position; a stale stamp means "identity value".
    epochs: Vec<u32>,
    /// Stamped value per position (valid only when the stamp is current).
    values: Vec<u32>,
    epoch: u32,
}

impl ShuffleWindow {
    fn new(n: usize) -> ShuffleWindow {
        assert!(n <= u32::MAX as usize, "fleet ids must fit in u32");
        ShuffleWindow {
            epochs: vec![0; n],
            values: vec![0; n],
            epoch: 0,
        }
    }

    /// Start a fresh virtual array (all positions back to identity).
    fn begin(&mut self) {
        if self.epoch == u32::MAX {
            // One full rewrite every 2³²−1 rounds keeps stamps unambiguous.
            self.epochs.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    fn get(&self, i: usize) -> usize {
        if self.epochs[i] == self.epoch {
            self.values[i] as usize
        } else {
            i
        }
    }

    fn set(&mut self, i: usize, v: usize) {
        self.epochs[i] = self.epoch;
        self.values[i] = v as u32;
    }
}

/// Uniform without replacement — the original loop's exact RNG stream and
/// cohorts, replayed sparsely (see `ShuffleWindow`).
pub struct UniformSampler {
    rng: Rng,
    participation: f64,
    data_weights: Vec<f64>,
    window: ShuffleWindow,
}

impl UniformSampler {
    pub fn new(seed: u64, participation: f64, data_weights: Vec<f64>) -> UniformSampler {
        let window = ShuffleWindow::new(data_weights.len());
        UniformSampler {
            // The legacy stream: MUST stay `seed ^ 0x5a3c_91f7` (and be
            // consumed only on m < n rounds) for bit-identity with the
            // pre-sampler coordinator.
            rng: Rng::new(seed ^ UNIFORM_STREAM),
            participation,
            data_weights,
            window,
        }
    }
}

impl ParticipationSampler for UniformSampler {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn sample(&mut self, _round: usize) -> Cohort {
        let n = self.data_weights.len();
        let m = target_cohort_size(n, self.participation);
        let devices: Vec<usize> = if m == n {
            // Full participation consumes no randomness (legacy contract).
            (0..n).collect()
        } else {
            // Replay of `shuffle(0..n); truncate(m); sort()` without the
            // dense Vec.  `Rng::shuffle` is backward Fisher–Yates
            // (`for i in (1..n).rev() { swap(i, below(i+1)) }`).  While
            // the cursor is still above the window, position `i` is read
            // exactly once — at its own step — and then discarded by the
            // truncation, so only the value *leaving* `i` needs a write.
            self.window.begin();
            for i in (m..n).rev() {
                let vi = self.window.get(i);
                let j = self.rng.below(i + 1);
                if j != i {
                    self.window.set(j, vi);
                }
            }
            // Once inside the window the remaining swaps merely permute
            // the surviving multiset, which the final sort erases —
            // consume the draws (the stream cursor must advance by
            // exactly `n − 1` per partial round) and skip the writes.
            for i in (1..m).rev() {
                let _ = self.rng.below(i + 1);
            }
            let mut idx: Vec<usize> = (0..m).map(|p| self.window.get(p)).collect();
            idx.sort_unstable();
            idx
        };
        let weights = devices.iter().map(|&i| self.data_weights[i]).collect();
        Cohort { devices, weights }
    }

    fn save_state(&self, out: &mut ByteWriter) {
        out.put_u64s(&self.rng.state());
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let s = input.take_u64s()?;
        anyhow::ensure!(s.len() == 4, "sampler cursor must be 4 words");
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]]);
        Ok(())
    }
}

/// Walker/Vose alias table: O(fleet) build once, O(1) per draw.
///
/// A draw costs exactly two RNG values — one `below(n)` to pick a column
/// and one `uniform()` against the column's acceptance threshold — so the
/// stream cursor advances by a fixed `2m` per round regardless of fleet
/// size, and the journal's 4-word cursor snapshot keeps working.
/// Construction is the standard two-worklist method, fully deterministic
/// (worklists fill in index order, drain LIFO): a table built twice from
/// the same weights draws the same device stream.
///
/// ```
/// use fedadam_ssm::coordinator::sampler::AliasTable;
/// use fedadam_ssm::rng::Rng;
///
/// let table = AliasTable::new(&[60.0, 30.0, 10.0]);
/// let mut a = Rng::new(7);
/// let mut b = Rng::new(7);
/// // Deterministic: identical RNG streams draw identical devices.
/// let draws: Vec<usize> = (0..5).map(|_| table.draw(&mut a)).collect();
/// let replay: Vec<usize> = (0..5).map(|_| table.draw(&mut b)).collect();
/// assert_eq!(draws, replay);
/// assert!(draws.iter().all(|&d| d < 3));
/// ```
pub struct AliasTable {
    /// Acceptance threshold per column (`uniform() < prob[i]` keeps `i`).
    prob: Vec<f64>,
    /// Overflow target per column (self-alias when the column is full).
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from unnormalized positive weights.
    pub fn new(weights: &[f64]) -> AliasTable {
        let n = weights.len();
        assert!(n > 0 && n <= u32::MAX as usize);
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && weights.iter().all(|&w| w > 0.0),
            "alias table needs strictly positive weights"
        );
        // Scale so the average column holds exactly 1.0 of probability
        // mass, then move each under-full column's deficit onto one
        // over-full donor.
        let mut scaled: Vec<f64> = weights.iter().map(|&w| w * n as f64 / total).collect();
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        let mut prob = vec![1.0f64; n];
        let mut alias: Vec<u32> = (0..n as u32).collect();
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            // The donor loses what the small column was missing.
            scaled[l as usize] = (scaled[l as usize] + scaled[s as usize]) - 1.0;
            if scaled[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Leftovers (either list) are full columns up to FP rounding.
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
            alias[i as usize] = i;
        }
        AliasTable { prob, alias }
    }

    /// Number of columns (= devices).
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// `true` for a zero-column table (never constructed — `new` asserts).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// One O(1) draw: column `i` with probability `prob[i]`, else its
    /// alias.  Consumes exactly two RNG values.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

/// Data-size-proportional sampling with unbiased re-weighting.
pub struct ImportanceSampler {
    rng: Rng,
    participation: f64,
    data_weights: Vec<f64>,
    /// `Σ |D_i|` over the whole fleet.
    total: f64,
    /// O(1)-draw index over `data_weights`, built once at construction.
    table: AliasTable,
}

impl ImportanceSampler {
    pub fn new(seed: u64, participation: f64, data_weights: Vec<f64>) -> ImportanceSampler {
        let total: f64 = data_weights.iter().sum();
        assert!(
            total > 0.0 && data_weights.iter().all(|&w| w > 0.0),
            "importance sampling needs strictly positive data weights"
        );
        let table = AliasTable::new(&data_weights);
        ImportanceSampler {
            rng: Rng::new(seed ^ IMPORTANCE_STREAM),
            participation,
            data_weights,
            total,
            table,
        }
    }

    /// Selection probability of device `i` in one draw.
    pub fn prob(&self, i: usize) -> f64 {
        self.data_weights[i] / self.total
    }
}

impl ParticipationSampler for ImportanceSampler {
    fn name(&self) -> &'static str {
        "importance"
    }

    fn sample(&mut self, _round: usize) -> Cohort {
        let n = self.data_weights.len();
        let m = target_cohort_size(n, self.participation);
        // m i.i.d. draws with replacement, p_i ∝ |D_i|, each O(1) via the
        // alias table; a device drawn `mult` times trains once and its
        // upload carries `mult` shares.  The whole round is O(m log m) —
        // the old dense multiplicity vector and per-draw linear scan over
        // the fleet are gone.
        let mut mult: BTreeMap<usize, usize> = BTreeMap::new();
        for _ in 0..m {
            *mult.entry(self.table.draw(&mut self.rng)).or_insert(0) += 1;
        }
        let mut devices = Vec::with_capacity(mult.len());
        let mut weights = Vec::with_capacity(mult.len());
        for (i, c) in mult {
            devices.push(i);
            // Unbiased estimator share: mult · w_i / (m·p_i).  With
            // p_i ∝ w_i each share is total/m, so the cohort weights
            // sum to the FULL corpus weight and the aggregate's
            // `weight/Σweights` normalization equals the 1/(m·p_i)
            // re-weighted FedAvg estimator exactly.
            let p = self.prob(i);
            weights.push(c as f64 * self.data_weights[i] / (m as f64 * p));
        }
        Cohort { devices, weights }
    }

    fn save_state(&self, out: &mut ByteWriter) {
        out.put_u64s(&self.rng.state());
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let s = input.take_u64s()?;
        anyhow::ensure!(s.len() == 4, "sampler cursor must be 4 words");
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]]);
        Ok(())
    }
}

/// Duty-cycle availability traces with over-selection and a deadline.
pub struct AvailabilitySampler {
    seed: u64,
    participation: f64,
    duty_cycle: f64,
    over_select: f64,
    data_weights: Vec<f64>,
    /// `speed_rank[d]` = position of device `d` in ascending
    /// `(compute_secs, id)` order, precomputed once so the per-round
    /// deadline cut is a plain integer-key sort of the O(cohort)
    /// candidate list instead of a float-comparator sort (the latencies
    /// themselves are not needed after ranking).
    speed_rank: Vec<u32>,
}

impl AvailabilitySampler {
    pub fn new(
        seed: u64,
        participation: f64,
        duty_cycle: f64,
        over_select: f64,
        data_weights: Vec<f64>,
        compute_secs: Vec<f64>,
    ) -> AvailabilitySampler {
        assert_eq!(data_weights.len(), compute_secs.len());
        // Latencies come from `LatencyModel` and are finite, so the
        // `(compute_secs, id)` comparator is a strict total order and the
        // precomputed global ranking induces exactly the ordering the old
        // per-round comparator sort produced on every candidate subset.
        let n = compute_secs.len();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by(|&a, &b| {
            compute_secs[a]
                .partial_cmp(&compute_secs[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut speed_rank = vec![0u32; n];
        for (r, &d) in order.iter().enumerate() {
            speed_rank[d] = r as u32;
        }
        AvailabilitySampler {
            seed,
            participation,
            duty_cycle,
            over_select,
            data_weights,
            speed_rank,
        }
    }

    /// Device `device`'s on/off duty-cycle trace at round `round` — a pure
    /// function of `(seed, device, round)`, so any schedule replays it.
    pub fn available(&self, device: usize, round: usize) -> bool {
        let mut rng = Rng::new(
            self.seed
                ^ TRACE_STREAM
                ^ (device as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (round as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        rng.uniform() < self.duty_cycle
    }
}

impl ParticipationSampler for AvailabilitySampler {
    fn name(&self) -> &'static str {
        "availability"
    }

    fn sample(&mut self, round: usize) -> Cohort {
        let n = self.data_weights.len();
        let m = target_cohort_size(n, self.participation);
        let mut avail: Vec<usize> = (0..n).filter(|&i| self.available(i, round)).collect();
        if avail.is_empty() {
            // Floor of 1: an all-off round still trains one device
            // (deterministic round-robin fallback).
            let fallback = round % n;
            return Cohort {
                devices: vec![fallback],
                weights: vec![self.data_weights[fallback]],
            };
        }
        let target = m.min(avail.len());
        // Over-select: contact extra candidates so deadline drops don't
        // shrink the cohort below target.
        let contacted = ((m as f64 * self.over_select).ceil() as usize)
            .clamp(target, avail.len());
        let mut rng = Rng::new(
            self.seed ^ SELECT_STREAM ^ (round as u64).wrapping_mul(0xA24B_AED4_963E_E407),
        );
        rng.shuffle(&mut avail);
        let mut candidates: Vec<usize> = avail.into_iter().take(contacted).collect();
        // Deadline: the round closes once `target` devices have finished —
        // keep the fastest by simulated compute latency (ties by id),
        // dropping the over-selected stragglers.  The precomputed rank
        // reproduces the old `(compute_secs, id)` comparator exactly.
        candidates.sort_unstable_by_key(|&d| self.speed_rank[d]);
        candidates.truncate(target);
        candidates.sort_unstable();
        let weights = candidates.iter().map(|&i| self.data_weights[i]).collect();
        Cohort {
            devices: candidates,
            weights,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: ParticipationMode, participation: f64, seed: u64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.participation_mode = mode;
        cfg.participation = participation;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn uniform_replays_the_legacy_rng_stream() {
        let n = 7;
        let weights: Vec<f64> = (0..n).map(|i| 10.0 + i as f64).collect();
        let lat = vec![0.0; n];
        let c = cfg(ParticipationMode::Uniform, 0.5, 42);
        let mut s = build(&c, &weights, &lat);
        // Legacy replica: the pre-sampler coordinator's exact logic.
        let mut legacy = Rng::new(42 ^ 0x5a3c_91f7);
        for round in 0..10 {
            let m = ((n as f64 * 0.5).round() as usize).clamp(1, n);
            let mut idx: Vec<usize> = (0..n).collect();
            legacy.shuffle(&mut idx);
            idx.truncate(m);
            idx.sort_unstable();
            let cohort = s.sample(round);
            assert_eq!(cohort.devices, idx, "round {round}");
            let want: Vec<f64> = idx.iter().map(|&i| weights[i]).collect();
            assert_eq!(cohort.weights, want, "round {round}");
        }
    }

    #[test]
    fn uniform_full_participation_consumes_no_randomness() {
        let weights = vec![5.0; 4];
        let lat = vec![0.0; 4];
        let c = cfg(ParticipationMode::Uniform, 1.0, 9);
        let mut s = build(&c, &weights, &lat);
        for round in 0..5 {
            let cohort = s.sample(round);
            assert_eq!(cohort.devices, vec![0, 1, 2, 3], "round {round}");
            assert_eq!(cohort.total_weight(), 20.0);
        }
    }

    #[test]
    fn importance_weights_sum_to_the_full_corpus() {
        let weights = vec![60.0, 30.0, 10.0, 50.0, 2.0];
        let lat = vec![0.0; 5];
        let c = cfg(ParticipationMode::Importance, 0.6, 3);
        let mut s = build(&c, &weights, &lat);
        let total: f64 = weights.iter().sum();
        for round in 0..50 {
            let cohort = s.sample(round);
            assert!(!cohort.is_empty());
            assert!(cohort.devices.windows(2).all(|w| w[0] < w[1]), "sorted unique");
            assert!(
                (cohort.total_weight() - total).abs() < 1e-9 * total,
                "round {round}: cohort weight {} != corpus {total}",
                cohort.total_weight()
            );
        }
    }

    #[test]
    fn availability_respects_traces_and_deadline() {
        let n = 9;
        let weights: Vec<f64> = vec![3.0; n];
        let lat: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect(); // device 8 fastest
        let mut s = AvailabilitySampler::new(21, 0.5, 0.7, 2.0, weights.clone(), lat);
        for round in 0..60 {
            let cohort = s.sample(round);
            assert!(!cohort.is_empty(), "round {round}");
            assert!(cohort.len() <= ((n as f64 * 0.5).round() as usize), "round {round}");
            assert!(cohort.devices.windows(2).all(|w| w[0] < w[1]));
            for (&d, &w) in cohort.devices.iter().zip(&cohort.weights) {
                assert_eq!(w, weights[d]);
            }
            // Every selected device was on duty (no fallback fires at
            // duty 0.7 with 9 devices under this seed — and if it did,
            // the single fallback device is also a legal cohort).
            if cohort.len() > 1 {
                for &d in &cohort.devices {
                    assert!(s.available(d, round), "round {round}: device {d} off-duty");
                }
            }
        }
    }

    #[test]
    fn availability_deadline_keeps_the_fastest_candidates() {
        // Duty cycle 1.0 ⇒ everyone available; over_select covers the whole
        // fleet ⇒ candidates = all devices ⇒ the deadline must keep exactly
        // the `target` fastest.
        let n = 6;
        let weights = vec![1.0; n];
        let lat = vec![5.0, 1.0, 4.0, 0.5, 3.0, 2.0];
        let mut s = AvailabilitySampler::new(7, 0.5, 1.0, 10.0, weights, lat);
        let cohort = s.sample(0);
        // target = round(6·0.5) = 3 fastest: devices 3 (0.5), 1 (1.0), 5 (2.0).
        assert_eq!(cohort.devices, vec![1, 3, 5]);
    }

    #[test]
    fn builder_dispatches_by_mode() {
        let weights = vec![1.0, 2.0];
        let lat = vec![0.1, 0.2];
        for (mode, name) in [
            (ParticipationMode::Uniform, "uniform"),
            (ParticipationMode::Importance, "importance"),
            (ParticipationMode::Availability, "availability"),
        ] {
            let c = cfg(mode, 1.0, 5);
            let s = build(&c, &weights, &lat);
            assert_eq!(s.name(), name);
            assert_eq!(s.name(), mode.as_str());
        }
    }

    #[test]
    fn cursor_snapshot_resumes_the_sampling_stream() {
        for mode in [ParticipationMode::Uniform, ParticipationMode::Importance] {
            let weights = vec![9.0, 4.0, 7.0, 1.0, 3.0];
            let lat = vec![0.0; 5];
            let c = cfg(mode, 0.5, 77);
            let mut a = build(&c, &weights, &lat);
            for round in 0..3 {
                a.sample(round);
            }
            // Snapshot mid-stream, rebuild fresh, restore the cursor.
            let mut out = ByteWriter::new();
            a.save_state(&mut out);
            let mut b = build(&c, &weights, &lat);
            let bytes = out.into_inner();
            let mut r = ByteReader::new(&bytes);
            b.load_state(&mut r).unwrap();
            r.finish().unwrap();
            for round in 3..8 {
                assert_eq!(a.sample(round), b.sample(round), "{mode:?} round {round}");
            }
        }
    }

    #[test]
    fn uniform_window_replay_matches_dense_shuffle_at_scale() {
        // Larger fleet, including the m == 1 window edge, against the
        // dense legacy replica — one shared stream, many rounds.
        let n = 50;
        let weights = vec![1.0; n];
        let lat = vec![0.0; n];
        for participation in [0.02, 0.1, 0.9] {
            let c = cfg(ParticipationMode::Uniform, participation, 1234);
            let mut s = build(&c, &weights, &lat);
            let mut legacy = Rng::new(1234 ^ 0x5a3c_91f7);
            for round in 0..20 {
                let m = target_cohort_size(n, participation);
                let mut idx: Vec<usize> = (0..n).collect();
                legacy.shuffle(&mut idx);
                idx.truncate(m);
                idx.sort_unstable();
                assert_eq!(s.sample(round).devices, idx, "p={participation} round {round}");
            }
        }
    }

    #[test]
    fn alias_table_holds_exactly_the_input_distribution() {
        // Per-column mass check: prob[i] plus every (1 − prob[j]) donated
        // to i must equal n · w_i / total, i.e. the table is not merely
        // approximately right, it redistributes the exact scaled weights.
        let weights = [60.0, 30.0, 10.0, 50.0, 2.0, 2.0, 46.0];
        let n = weights.len();
        let total: f64 = weights.iter().sum();
        let t = AliasTable::new(&weights);
        assert_eq!(t.len(), n);
        assert!(!t.is_empty());
        for i in 0..n {
            let mut mass = t.prob[i];
            for j in 0..n {
                if t.alias[j] as usize == i && j != i {
                    mass += 1.0 - t.prob[j];
                }
            }
            let want = weights[i] * n as f64 / total;
            assert!((mass - want).abs() < 1e-9, "column {i}: {mass} vs {want}");
        }
        // Every draw lands in range and the two-values-per-draw cursor
        // contract holds (below + uniform).
        let mut rng = Rng::new(99);
        for _ in 0..1000 {
            assert!(t.draw(&mut rng) < n);
        }
    }

    #[test]
    fn availability_rank_breaks_latency_ties_by_id() {
        // Duplicate latencies: the (compute_secs, id) order must keep the
        // lower id first, exactly like the old per-round comparator.
        let n = 5;
        let weights = vec![1.0; n];
        let lat = vec![2.0, 1.0, 2.0, 1.0, 0.5];
        let mut s = AvailabilitySampler::new(3, 0.6, 1.0, 10.0, weights, lat);
        // target = round(5·0.6) = 3 fastest: 4 (0.5), then the 1.0 tie
        // broken by id → 1 before 3.
        assert_eq!(s.sample(0).devices, vec![1, 3, 4]);
    }

    #[test]
    fn target_cohort_size_matches_the_legacy_formula() {
        assert_eq!(target_cohort_size(8, 1.0), 8);
        assert_eq!(target_cohort_size(8, 0.5), 4);
        assert_eq!(target_cohort_size(8, 0.01), 1);
        assert_eq!(target_cohort_size(3, 0.5), 2); // 1.5 rounds away from zero
        assert_eq!(target_cohort_size(1, 0.1), 1);
    }
}
