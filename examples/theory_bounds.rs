//! Theorem-1 / Proposition-1 numerical harness.
//!
//! 1. Evaluates the closed-form coefficients Γ, Λ, Θ, Φ across local epochs
//!    and verifies Proposition 1's ordering Γ > Θ > Λ under condition (26).
//! 2. Runs FedAdam-SSM against *centralized Adam* (full-gradient, pooled
//!    data — the paper's w̌ sequence) on `mlp_tiny` and reports the measured
//!    divergence `‖w_n − w̌‖` next to the bound's structure: the measured
//!    divergence must be dominated by the SSM variant with the worse mask
//!    (SSM_V), mirroring why eq. 28 picks ΔW.
//!
//! ```text
//! cargo run --release --example theory_bounds
//! ```

use anyhow::Result;
use fedadam_ssm::algorithms::centralized::{AdamParams, CentralizedAdam};
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::tensor;
use fedadam_ssm::theory::{coeffs, prop1_condition, BoundParams};

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");

    // --- Part 1: closed-form coefficients -------------------------------
    println!("=== Proposition 1: Γ > Θ > Λ under condition (26) ===");
    let p = BoundParams {
        d: 2410.0, // mlp_tiny
        g: 1.0,
        rho: 2.0,
        eta: 1e-3,
        beta2: 0.95, // small enough for condition (26) at this d
        ..Default::default()
    };
    println!("condition (26) satisfied: {}", prop1_condition(&p));
    println!("{:>3} {:>14} {:>14} {:>14} {:>14}", "l", "Gamma", "Theta", "Lambda", "Phi");
    for l in [1u32, 2, 3, 5, 8] {
        let c = coeffs(&p, l);
        println!(
            "{l:>3} {:>14.4e} {:>14.4e} {:>14.4e} {:>14.4e}",
            c.gamma, c.theta, c.lambda, c.phi_term
        );
        anyhow::ensure!(
            c.gamma > c.theta && c.theta > c.lambda,
            "Prop 1 ordering violated at l={l}"
        );
    }
    println!("ordering holds at every l — masking by |ΔW| minimizes the bound\n");

    // --- Part 2: measured divergence vs centralized Adam ----------------
    println!("=== Theorem 1: measured ‖W_fed − W_centralized‖ ===");
    let algos = ["fedadam-ssm", "fedadam-ssm-m", "fedadam-ssm-v", "fedadam"];
    let rounds = 8usize;
    let mut results = Vec::new();
    for algo in algos {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "mlp_tiny".into();
        cfg.algorithm = algo.into();
        cfg.rounds = rounds;
        cfg.devices = 4;
        cfg.local_epochs = 2;
        cfg.train_samples = 512;
        cfg.test_samples = 64;
        cfg.sparsity = 0.05;
        cfg.seed = 11;
        let mut coord = Coordinator::new(cfg, artifacts)?;

        // Centralized Adam twin: same init, full-batch gradient on the
        // pooled corpus via the `grads` program.
        let h = coord.handle();
        let w0 = h.init(11)?;
        let mut central = CentralizedAdam::new(
            w0,
            AdamParams {
                eta: 0.001,
                ..Default::default()
            },
        );
        // Pooled "full" gradient approximated by a large fixed batch.
        let meta = h.meta().clone();
        let spec = fedadam_ssm::data::synthetic::SyntheticSpec::for_input_shape(
            &meta.input_shape,
            meta.batch * 8,
            1,
        );
        let pool = fedadam_ssm::data::synthetic::generate(&spec, 11).train;
        let steps_per_round = 2 * 4; // local_epochs * batches
        let mut div = 0.0;
        for _ in 0..rounds {
            coord.step_round()?;
            for s in 0..steps_per_round {
                // cycle batches of the pooled set
                let mut x = Vec::with_capacity(meta.batch * meta.row());
                let mut y = Vec::with_capacity(meta.batch);
                for i in 0..meta.batch {
                    let idx = (s * meta.batch + i) % pool.len();
                    x.extend_from_slice(pool.image(idx));
                    y.push(pool.labels[idx]);
                }
                let (g, _) = h.grads(&central.w, x, y)?;
                central.step(&g);
            }
            div = tensor::l2_dist(&coord.global().w, &central.w);
        }
        println!("{algo:<16} final divergence {div:>10.4}");
        results.push((algo, div));
    }
    let get = |n: &str| results.iter().find(|(a, _)| *a == n).unwrap().1;
    // The paper's ordering: dense FedAdam closest to centralized; SSM(W)
    // beats SSM(V) (Remark 2 + eq. 28 optimality).
    anyhow::ensure!(
        get("fedadam-ssm") <= get("fedadam-ssm-v") * 1.05,
        "SSM(W) should not diverge more than SSM(V)"
    );
    println!("\ndivergence(SSM over ΔW) <= divergence(SSM over ΔV): eq. 28 optimal mask confirmed");
    Ok(())
}
