//! Numerical evaluation of the paper's theory (Theorems 1-3, Prop. 1).
//!
//! These are the closed-form constants of Theorem 1's divergence bound
//! between FedAdam-SSM and centralized Adam, used by
//! `examples/theory_bounds.rs` to check the bound against measured
//! divergence, and by unit tests to verify Proposition 1's ordering
//! `Γ > Θ > Λ` (the justification for masking by `|ΔW|`).

/// Problem/algorithm constants appearing in the bounds.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// Lipschitz constant ρ of the gradient (Assumption 1).
    pub rho: f64,
    /// Per-coordinate gradient bound G (Assumption 2).
    pub g: f64,
    /// Local gradient variance σ_l (Assumption 3).
    pub sigma_l: f64,
    /// Global variance σ_g (Assumption 3).
    pub sigma_g: f64,
    /// Model dimension d.
    pub d: f64,
    /// Mini-batch size |D̃_n|.
    pub batch: f64,
    /// Learning rate η.
    pub eta: f64,
    /// Adam constants.
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for BoundParams {
    fn default() -> Self {
        BoundParams {
            rho: 1.0,
            g: 1.0,
            sigma_l: 0.1,
            sigma_g: 0.1,
            d: 1000.0,
            batch: 32.0,
            eta: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
        }
    }
}

/// The Theorem-1 coefficients at local epoch `l`.
#[derive(Clone, Copy, Debug)]
pub struct DivergenceCoeffs {
    pub gamma: f64,
    pub lambda: f64,
    pub theta: f64,
    pub phi_term: f64,
}

/// φ = β₁/√β₂ (eq. 21).
pub fn phi(p: &BoundParams) -> f64 {
    p.beta1 / p.beta2.sqrt()
}

/// ψ (eq. 22).
pub fn psi(p: &BoundParams) -> f64 {
    1.0 + p.beta1 / p.beta2.sqrt()
        + p.eta * p.rho * (1.0 - p.beta1) / p.eps.sqrt()
            * (1.0 + (1.0 - p.beta2) * p.d * p.g * p.g / p.eps)
}

/// χ (eq. 23).
pub fn chi(p: &BoundParams) -> f64 {
    let g2 = p.g * p.g;
    p.d * p.g * p.eta
        * (2.0 * p.beta1 * (1.0 - p.beta2.sqrt()) / (p.eps * (p.eps * p.beta2).sqrt())
            * (g2 + p.eps)
            + (1.0 - p.beta1) * p.beta2 / (p.eps * p.eps.sqrt()) * g2)
        + (1.0 - p.beta1) * p.eta * (p.sigma_l / p.batch.sqrt() + p.sigma_g) / p.eps.sqrt()
            * (1.0 + (1.0 - p.beta2) * p.d * g2 / p.eps)
}

/// The recursion roots `r± = (ψ ± √(ψ²+4φ)) / 2`.
pub fn roots(p: &BoundParams) -> (f64, f64, f64) {
    let ps = psi(p);
    let ph = phi(p);
    let disc = (ps * ps + 4.0 * ph).sqrt();
    ((ps + disc) / 2.0, (ps - disc) / 2.0, disc)
}

/// Evaluate Γ, Λ, Θ, Φ (eq. 17-20) at local epoch `l`.
pub fn coeffs(p: &BoundParams, l: u32) -> DivergenceCoeffs {
    let ph = phi(p);
    let ps = psi(p);
    let (rp, rm, disc) = roots(p);
    let rp_l = rp.powi(l as i32);
    let rm_l = rm.powi(l as i32);
    let g2 = p.g * p.g;
    let ee = p.eps * p.eps.sqrt(); // ε√ε
    let k_adam = p.d * g2 * p.eta * p.rho / ee * p.beta1 * (1.0 - p.beta2);

    let gamma = (rm_l * (ph + (disc - ps) / 2.0 - k_adam) + rp_l * ((disc + ps) / 2.0 - ph + k_adam))
        / disc;

    let lambda = p.eta * p.beta1 / (p.eps.sqrt() * disc) * (rp_l - rm_l);

    let theta =
        p.d.sqrt() * p.g * p.eta * p.beta2 / (2.0 * ee * disc) * (rp_l - rm_l);

    let noise = p.sigma_l / p.batch.sqrt() + p.sigma_g;
    let a = noise / disc
        * (p.eta / p.eps.sqrt() * (1.0 - p.beta1) + p.d * g2 * p.eta / ee * (1.0 - p.beta2))
        * (rp_l - rm_l);
    let b = chi(p) / (1.0 - ps - ph)
        * (((1.0 - rp) * rm_l - (1.0 - rm) * rp_l) / disc + 1.0);
    DivergenceCoeffs {
        gamma,
        lambda,
        theta,
        phi_term: a + b,
    }
}

/// Proposition 1's condition on β₂: `β₂ < 1 − 1/(1 + 2Gρ√d)`.
pub fn prop1_condition(p: &BoundParams) -> bool {
    p.beta2 < 1.0 - 1.0 / (1.0 + 2.0 * p.g * p.rho * p.d.sqrt())
}

/// The Theorem-1 upper bound on `‖w_n^{l,t} − w̌^{l,t}‖` given the current
/// sparsification errors of the three global vectors.
pub fn divergence_bound(
    p: &BoundParams,
    l: u32,
    err_w: f64,
    err_m: f64,
    err_v: f64,
) -> f64 {
    let c = coeffs(p, l);
    c.gamma * err_w + c.lambda * err_m + c.theta * err_v + c.phi_term
}

/// RHS of Theorem 2 (non-convex convergence bound) divided into its parts;
/// returns (optimality-gap term, sparsification term, constant term).
pub fn convergence_bound_nonconvex(
    p: &BoundParams,
    alpha: f64,
    l: u32,
    t_rounds: u32,
    f0_minus_ft: f64,
    data_term: f64,
) -> (f64, f64, f64) {
    let lf = l as f64;
    let g2 = p.g * p.g;
    let t1 = 2.0 / (p.eta * t_rounds as f64) * f0_minus_ft;
    let t2 = 2.0 * ((p.eta * p.rho + 2.0) * (1.0 - alpha) + p.eta * p.rho - 1.0)
        * p.eta * g2 * p.d * lf * lf
        / p.eps;
    let beta2_sum = p.beta2 * (1.0 - p.beta2.powi(l as i32)) / (1.0 - p.beta2);
    let beta1_sum = 4.0 * p.beta1 * (1.0 - p.beta1.powi(l as i32))
        / (p.eps * (1.0 - p.beta1) * (1.0 - p.beta1));
    let t3 = 6.0 * g2 * p.d
        * ((lf - beta2_sum) * g2 * g2 * p.d * lf / (4.0 * p.eps.powi(3))
            + lf * lf / p.eps
            + beta1_sum
            + 1.0
            + p.rho * p.rho * lf * lf / (3.0 * p.eps))
        + 6.0 * data_term;
    (t1, t2, t3)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> BoundParams {
        // d large enough that 1 − 1/(1+2Gρ√d) > β₂ = 0.999 (Remark 3).
        BoundParams {
            rho: 2.0,
            g: 1.0,
            d: 1_000_000.0,
            eta: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-4,
            ..Default::default()
        }
    }

    #[test]
    fn prop1_condition_holds_for_paper_defaults() {
        // d large => 1 - 1/(1+2Gρ√d) ≈ 1 > 0.999 (Remark 3).
        assert!(prop1_condition(&params()));
        // Tiny d with big beta2 violates it.
        let bad = BoundParams {
            d: 1.0,
            g: 0.1,
            rho: 0.1,
            beta2: 0.999,
            ..Default::default()
        };
        assert!(!prop1_condition(&bad));
    }

    #[test]
    fn prop1_ordering_gamma_theta_lambda() {
        // Under the condition, Γ > Θ > Λ across local epochs and params.
        for &(d, eta, eps, l, beta2) in &[
            (1_000_000.0, 1e-3, 1e-2, 1u32, 0.999),
            (1_000_000.0, 1e-3, 1e-4, 3, 0.999),
            (1_000_000.0, 1e-4, 1e-4, 5, 0.999),
            (54_314.0, 1e-3, 1e-6, 2, 0.99), // cnn_small's d needs smaller β₂
        ] {
            let p = BoundParams {
                d,
                eta,
                eps,
                beta2,
                ..params()
            };
            assert!(prop1_condition(&p), "condition d={d}");
            let c = coeffs(&p, l);
            assert!(
                c.gamma > c.theta && c.theta > c.lambda,
                "d={d} eta={eta} eps={eps} l={l}: Γ={} Θ={} Λ={}",
                c.gamma,
                c.theta,
                c.lambda
            );
        }
    }

    #[test]
    fn coeffs_positive_and_grow_with_l() {
        let p = params();
        let c1 = coeffs(&p, 1);
        let c5 = coeffs(&p, 5);
        assert!(c1.gamma > 0.0 && c1.lambda > 0.0 && c1.theta > 0.0);
        assert!(c5.gamma > c1.gamma);
        assert!(c5.lambda > c1.lambda);
        assert!(c5.theta > c1.theta);
    }

    #[test]
    fn divergence_bound_monotone_in_errors() {
        let p = params();
        let b0 = divergence_bound(&p, 2, 0.0, 0.0, 0.0);
        let b1 = divergence_bound(&p, 2, 1.0, 0.0, 0.0);
        let b2 = divergence_bound(&p, 2, 1.0, 1.0, 1.0);
        assert!(b0 < b1 && b1 < b2);
    }

    #[test]
    fn zero_error_bound_reduces_to_phi() {
        // Eq. 24: with zero sparsification error only Φ remains.
        let p = params();
        let c = coeffs(&p, 3);
        let b = divergence_bound(&p, 3, 0.0, 0.0, 0.0);
        assert!((b - c.phi_term).abs() < 1e-12);
    }

    #[test]
    fn convergence_bound_decreases_with_alpha() {
        // Remark 4: higher sparsification ratio α => smaller bound.
        let p = params();
        let (a1, s1, c1) = convergence_bound_nonconvex(&p, 0.05, 3, 100, 1.0, 0.01);
        let (a2, s2, c2) = convergence_bound_nonconvex(&p, 0.5, 3, 100, 1.0, 0.01);
        assert_eq!(a1, a2);
        assert_eq!(c1, c2);
        assert!(s2 < s1, "sparser (lower alpha) must cost more: {s1} vs {s2}");
    }
}
