//! Accuracy/bit frontier: quantized shared-sparse-mask uplink vs the
//! sparse and dense baselines (the ROADMAP "Quantized SSM composition"
//! item; the paper's Fig. 2 axis).
//!
//! Sweeps `s ∈ {2, 4, 16}` × sparsity `α` for `fedadam-ssm-q` on the
//! pure-Rust [`ReferenceExecutor`] (runs offline, no PJRT artifacts),
//! alongside the f32-valued `fedadam-ssm` and dense `fedadam` anchors,
//! and emits the per-round accuracy-vs-cumulative-uplink-bits curve as
//! CSV (`results/frontier.csv` + stdout) — the frontier the two isolated
//! families could never trace.
//!
//! Before any timing, every swept point is re-run at a different worker
//! count and asserted **byte-identical** (log + final weights): the
//! quantized wire format must hold the same determinism contract as the
//! rest of the zoo.  Then the round loop is timed for the quantized vs
//! f32 SSM so the bit-packing overhead is visible.
//!
//! Run: `cargo bench --bench frontier`.

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool};

const INPUT: [usize; 3] = [4, 4, 1]; // row 16; dim = 10 * (16 + 1) = 170
const CLASSES: usize = 10; // matches SyntheticSpec::for_input_shape

fn frontier_cfg(algo: &str, alpha: f64, s: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "frontier".into();
    cfg.model = "reference-linear".into();
    cfg.algorithm = algo.into();
    cfg.rounds = 6;
    cfg.devices = 3;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 2;
    cfg.lr = 0.02;
    cfg.sparsity = alpha;
    cfg.train_samples = 96;
    cfg.test_samples = 64;
    cfg.seed = 7;
    cfg.eval_every = 1;
    cfg.quant_levels = s;
    cfg.num_workers = workers;
    cfg
}

fn run_once(algo: &str, alpha: f64, s: usize, workers: usize) -> (ExperimentLog, Vec<f32>) {
    let cfg = frontier_cfg(algo, alpha, s, workers);
    let meta = reference_meta(&INPUT, CLASSES, 4, 8, 2);
    let pool = reference_pool(meta, cfg.num_workers).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg, pool).expect("coordinator");
    let log = coord.run().expect("run");
    let w = coord.global().w.clone();
    (log, w)
}

/// `(algorithm, alpha, s)` — `s = 0` marks the un-quantized f32 schemes.
fn sweep_points() -> Vec<(&'static str, f64, usize)> {
    let mut points = vec![("fedadam", 1.0, 0)]; // dense anchor (α unused)
    for &alpha in &[0.02f64, 0.05, 0.2] {
        points.push(("fedadam-ssm", alpha, 0)); // sparse f32 anchor
        for &s in &[2usize, 4, 16] {
            points.push(("fedadam-ssm-q", alpha, s));
        }
    }
    points
}

fn main() {
    // ---- Determinism gate: bit-identity across worker counts, BEFORE ----
    // ---- any timing (a quantizer that decodes differently under a     ----
    // ---- different schedule would poison every number below).  The    ----
    // ---- 1-worker run of each point is kept and reused for the sweep. ----
    let points = sweep_points();
    let mut logs: Vec<ExperimentLog> = Vec::with_capacity(points.len());
    for &(algo, alpha, s) in &points {
        let s_cfg = if s == 0 { 16 } else { s };
        let (log1, w1) = run_once(algo, alpha, s_cfg, 1);
        for workers in [2usize, 3] {
            let (log, w) = run_once(algo, alpha, s_cfg, workers);
            assert_eq!(w1, w, "{algo} α={alpha} s={s_cfg} {workers}w: weights diverged");
            assert_eq!(log1.rounds.len(), log.rounds.len());
            for (a, b) in log1.rounds.iter().zip(&log.rounds) {
                let tag = format!("{algo} α={alpha} s={s_cfg} {workers}w round {}", a.round);
                assert_eq!(a.uplink_bits, b.uplink_bits, "{tag}");
                assert_eq!(a.downlink_bits, b.downlink_bits, "{tag}");
                assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits(), "{tag}");
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag}");
            }
        }
        logs.push(log1);
    }
    println!("determinism gate: all sweep points byte-identical at 1/2/3 workers\n");

    // ---- Frontier sweep (from the gate's cached runs): accuracy vs bits --
    let last_bits =
        |log: &ExperimentLog| log.rounds.last().map(|r| r.uplink_bits).unwrap_or(0);
    // f32-SSM anchor total per alpha, for the compression-ratio column.
    let ssm_total = |alpha: f64| -> Option<u64> {
        points
            .iter()
            .zip(&logs)
            .find(|(p, _)| p.0 == "fedadam-ssm" && p.1 == alpha)
            .map(|(_, log)| last_bits(log))
    };
    let mut csv = String::from("algorithm,s,alpha,round,cum_uplink_bits,test_accuracy\n");
    println!(
        "{:<16} {:>4} {:>6} {:>10} {:>16} {:>10}",
        "algorithm", "s", "alpha", "best acc", "uplink (kbit)", "bits/SSM"
    );
    for (&(algo, alpha, s), log) in points.iter().zip(&logs) {
        for r in &log.rounds {
            csv.push_str(&format!(
                "{algo},{s},{alpha},{},{},{:.6}\n",
                r.round, r.uplink_bits, r.test_accuracy
            ));
        }
        let total = last_bits(log);
        let ratio = ssm_total(alpha)
            .map(|t| format!("{:.3}", total as f64 / t as f64))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<16} {:>4} {:>6} {:>10.3} {:>16.1} {:>10}",
            algo,
            if s == 0 { "f32".into() } else { s.to_string() },
            alpha,
            log.best_accuracy(),
            total as f64 / 1e3,
            ratio,
        );
    }
    if std::fs::create_dir_all("results").is_ok()
        && std::fs::write("results/frontier.csv", &csv).is_ok()
    {
        println!("\nwrote results/frontier.csv");
    }
    println!("\n{csv}");

    // ---- Timing: quantized vs f32 SSM round loop ------------------------
    let mut bench = from_env();
    bench.max_iters = 6; // one full run is already ~100ms-scale
    for &(algo, s) in &[("fedadam-ssm", 16usize), ("fedadam-ssm-q", 16), ("fedadam-ssm-q", 2)] {
        bench.run(format!("run: {algo} s={s} α=0.05 (6 rounds, 1w)"), || {
            black_box(run_once(algo, 0.05, s, 1));
        });
    }
    bench.report("accuracy/bit frontier");
    println!("\n{}", bench.to_csv());
}
