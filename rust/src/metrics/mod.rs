//! Experiment metrics: per-round records, CSV/JSON emission, and the
//! communication ledger the Table-I harness reads.

pub mod comm;

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// One communication round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean local training loss across devices this round.
    pub train_loss: f64,
    /// Test loss on the global model (NaN when not evaluated this round).
    pub test_loss: f64,
    /// Test accuracy on the global model (NaN when not evaluated).
    pub test_accuracy: f64,
    /// Cumulative uplink bits across all devices since round 0.
    pub uplink_bits: u64,
    /// Cumulative downlink bits.
    pub downlink_bits: u64,
    /// Wall-clock seconds spent in this round.
    pub wall_secs: f64,
    /// Cumulative *simulated* seconds since round 0 under the configured
    /// latency model (`NaN` when the run carries no simulated clock —
    /// `simtime = false`).  See [`crate::simtime`].
    pub sim_secs: f64,
    /// L2 norm of the aggregated ΔW (convergence diagnostics).
    pub update_norm: f64,
    /// Registered fleet size (`cfg.devices`) — constant across a run, but
    /// recorded per row so a log is self-describing about the fleet it
    /// came from.
    pub fleet_devices: u64,
    /// Realized cohort size this round (after participation sampling,
    /// availability traces and the deadline cut).
    pub cohort_devices: u64,
    /// *Measured* wall-clock uplink round-trip latency of the round's
    /// slowest device slot — RoundStart broadcast to validated Uplink
    /// arrival at the transport server, in real host seconds.  Only a
    /// socket run measures anything: in-process runs carry `NaN`
    /// (emitted as an empty CSV cell / JSON `null`).  This is observed
    /// host time — the measured counterpart of the *modeled* `sim_secs`
    /// clock — so, like `wall_secs`, it sits outside the bit-identity
    /// and journal-replay contracts.
    pub meas_uplink_max_secs: f64,
    /// Mean measured uplink round-trip latency across the round's device
    /// slots (same measurement and caveats as `meas_uplink_max_secs`).
    pub meas_uplink_mean_secs: f64,
}

/// A full experiment's log plus identifying metadata.
#[derive(Clone, Debug, Default)]
pub struct ExperimentLog {
    pub name: String,
    pub algorithm: String,
    pub model: String,
    pub iid: bool,
    pub rounds: Vec<RoundRecord>,
}

impl ExperimentLog {
    /// Cumulative uplink in Mbit at the end of `round` (Table I's unit).
    pub fn uplink_mbit(&self, round: usize) -> f64 {
        self.rounds
            .get(round)
            .map(|r| r.uplink_bits as f64 / 1e6)
            .unwrap_or(f64::NAN)
    }

    /// Best test accuracy seen.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_accuracy)
            .filter(|a| a.is_finite())
            .fold(f64::NAN, f64::max)
    }

    /// Minimum cumulative uplink Mbit at which `target` accuracy was hit
    /// (Table I "Comm."); `None` = the paper's `∞`.
    pub fn comm_to_accuracy(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.test_accuracy.is_finite() && r.test_accuracy >= target)
            .map(|r| r.uplink_bits as f64 / 1e6)
    }

    /// Simulated seconds at which `target` accuracy was first reached —
    /// the time-to-accuracy axis sparse uplinks are supposed to win.
    /// `None` when the target was never hit *or* the run carried no
    /// simulated clock (`simtime = false` leaves `sim_secs` at `NaN`).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.rounds
            .iter()
            .find(|r| r.test_accuracy.is_finite() && r.test_accuracy >= target)
            .and_then(|r| r.sim_secs.is_finite().then_some(r.sim_secs))
    }

    /// CSV with a header row.
    ///
    /// Rounds that were not evaluated carry `NaN` in
    /// `test_loss`/`test_accuracy`, and runs without a simulated clock
    /// carry `NaN` in `sim_secs`; those cells are emitted **empty**
    /// (strict CSV consumers reject a literal `NaN` token).  A genuinely
    /// evaluated round that diverged to `±inf` still prints `inf` — an
    /// empty cell means "not evaluated" / "not simulated", never
    /// "diverged".
    pub fn to_csv(&self) -> String {
        fn cell(x: f64) -> String {
            if x.is_nan() {
                String::new()
            } else {
                format!("{x:.6}")
            }
        }
        let mut out = String::from(
            "round,train_loss,test_loss,test_accuracy,uplink_bits,downlink_bits,wall_secs,sim_secs,update_norm,fleet_devices,cohort_devices,meas_uplink_max_secs,meas_uplink_mean_secs\n",
        );
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{:.6},{},{},{},{},{:.4},{},{:.6e},{},{},{},{}",
                r.round,
                r.train_loss,
                cell(r.test_loss),
                cell(r.test_accuracy),
                r.uplink_bits,
                r.downlink_bits,
                r.wall_secs,
                cell(r.sim_secs),
                r.update_norm,
                r.fleet_devices,
                r.cohort_devices,
                cell(r.meas_uplink_max_secs),
                cell(r.meas_uplink_mean_secs)
            );
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_csv())
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    /// Structured JSON (metadata + rounds) for downstream tooling.
    pub fn to_json(&self) -> String {
        use crate::util::json::Value;
        use std::collections::BTreeMap;
        let mut top = BTreeMap::new();
        top.insert("name".to_string(), Value::Str(self.name.clone()));
        top.insert("algorithm".to_string(), Value::Str(self.algorithm.clone()));
        top.insert("model".to_string(), Value::Str(self.model.clone()));
        top.insert("iid".to_string(), Value::Bool(self.iid));
        let rounds: Vec<Value> = self
            .rounds
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("round".into(), Value::Num(r.round as f64));
                m.insert("train_loss".into(), Value::Num(r.train_loss));
                m.insert("test_loss".into(), finite(r.test_loss));
                m.insert("test_accuracy".into(), finite(r.test_accuracy));
                m.insert("uplink_bits".into(), Value::Num(r.uplink_bits as f64));
                m.insert("downlink_bits".into(), Value::Num(r.downlink_bits as f64));
                m.insert("wall_secs".into(), Value::Num(r.wall_secs));
                m.insert("sim_secs".into(), finite(r.sim_secs));
                m.insert("update_norm".into(), Value::Num(r.update_norm));
                m.insert("fleet_devices".into(), Value::Num(r.fleet_devices as f64));
                m.insert("cohort_devices".into(), Value::Num(r.cohort_devices as f64));
                m.insert(
                    "meas_uplink_max_secs".into(),
                    finite(r.meas_uplink_max_secs),
                );
                m.insert(
                    "meas_uplink_mean_secs".into(),
                    finite(r.meas_uplink_mean_secs),
                );
                Value::Obj(m)
            })
            .collect();
        top.insert("rounds".to_string(), Value::Arr(rounds));
        return Value::Obj(top).render();

        fn finite(x: f64) -> Value {
            if x.is_finite() {
                Value::Num(x)
            } else {
                Value::Null
            }
        }
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json())
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let last = self.rounds.last();
        format!(
            "{} [{}] {} rounds: best acc {:.3}, final loss {:.4}, uplink {:.2} Mbit",
            self.name,
            self.algorithm,
            self.rounds.len(),
            self.best_accuracy(),
            last.map(|r| r.train_loss).unwrap_or(f64::NAN),
            last.map(|r| r.uplink_bits as f64 / 1e6).unwrap_or(0.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log() -> ExperimentLog {
        ExperimentLog {
            name: "t".into(),
            algorithm: "fedadam-ssm".into(),
            model: "cnn_small".into(),
            iid: true,
            rounds: (0..5)
                .map(|i| RoundRecord {
                    round: i,
                    train_loss: 2.0 - i as f64 * 0.2,
                    test_loss: 2.0 - i as f64 * 0.2,
                    test_accuracy: 0.2 + i as f64 * 0.1,
                    uplink_bits: (i as u64 + 1) * 1_000_000,
                    downlink_bits: (i as u64 + 1) * 500_000,
                    wall_secs: 0.5,
                    sim_secs: (i as f64 + 1.0) * 2.0,
                    update_norm: 1.0,
                    fleet_devices: 100,
                    cohort_devices: 10 + i as u64,
                    meas_uplink_max_secs: f64::NAN,
                    meas_uplink_mean_secs: f64::NAN,
                })
                .collect(),
        }
    }

    #[test]
    fn comm_to_accuracy_finds_first_crossing() {
        let l = log();
        assert_eq!(l.comm_to_accuracy(0.45), Some(4.0)); // round 3: acc 0.5, 4 Mbit
        assert_eq!(l.comm_to_accuracy(0.9), None);
        assert!((l.best_accuracy() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn csv_shape() {
        let csv = log().to_csv();
        assert_eq!(csv.lines().count(), 6);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn csv_non_eval_rounds_round_trip_without_nan() {
        // Non-eval rounds carry NaN internally; the CSV must emit empty
        // cells (never the literal `NaN`) and every other field must
        // parse back to the exact written value.  All column indices are
        // resolved from the header row, never hard-coded, so adding a
        // column can't silently shift an assertion onto the wrong cell.
        let mut l = log();
        l.rounds[1].test_loss = f64::NAN;
        l.rounds[1].test_accuracy = f64::NAN;
        l.rounds[3].test_loss = f64::NAN;
        l.rounds[3].test_accuracy = f64::NAN;
        l.rounds[2].sim_secs = f64::NAN; // no simulated clock that round
        l.rounds[4].meas_uplink_max_secs = 0.25; // "a transport run" that round
        l.rounds[4].meas_uplink_mean_secs = 0.125;
        let csv = l.to_csv();
        assert!(!csv.contains("NaN"), "literal NaN leaked into CSV:\n{csv}");

        let lines: Vec<&str> = csv.lines().collect();
        let header: Vec<&str> = lines[0].split(',').collect();
        let col = |name: &str| {
            header
                .iter()
                .position(|h| *h == name)
                .unwrap_or_else(|| panic!("column {name} missing from header: {header:?}"))
        };
        for (i, line) in lines[1..].iter().enumerate() {
            let cells: Vec<&str> = line.split(',').collect();
            assert_eq!(cells.len(), header.len(), "row {i} lost a column: {line}");
            // round + train_loss always parse.
            assert_eq!(cells[col("round")].parse::<usize>().unwrap(), i);
            let train: f64 = cells[col("train_loss")].parse().unwrap();
            assert!((train - l.rounds[i].train_loss).abs() < 1e-9);
            if l.rounds[i].test_loss.is_finite() {
                let tl: f64 = cells[col("test_loss")].parse().unwrap();
                let ta: f64 = cells[col("test_accuracy")].parse().unwrap();
                assert!((tl - l.rounds[i].test_loss).abs() < 1e-9);
                assert!((ta - l.rounds[i].test_accuracy).abs() < 1e-9);
            } else {
                assert!(cells[col("test_loss")].is_empty(), "row {i}: want empty test_loss");
                assert!(
                    cells[col("test_accuracy")].is_empty(),
                    "row {i}: want empty test_accuracy"
                );
            }
            // Ledger columns survive exactly.
            assert_eq!(
                cells[col("uplink_bits")].parse::<u64>().unwrap(),
                l.rounds[i].uplink_bits
            );
            assert_eq!(
                cells[col("downlink_bits")].parse::<u64>().unwrap(),
                l.rounds[i].downlink_bits
            );
            // Simulated-clock cell: empty exactly when not simulated.
            if l.rounds[i].sim_secs.is_finite() {
                let sim: f64 = cells[col("sim_secs")].parse().unwrap();
                assert!((sim - l.rounds[i].sim_secs).abs() < 1e-9, "row {i}");
            } else {
                assert!(cells[col("sim_secs")].is_empty(), "row {i}: want empty sim_secs");
            }
            // Fleet/cohort sizes are plain integers, always present.
            assert_eq!(
                cells[col("fleet_devices")].parse::<u64>().unwrap(),
                l.rounds[i].fleet_devices
            );
            assert_eq!(
                cells[col("cohort_devices")].parse::<u64>().unwrap(),
                l.rounds[i].cohort_devices
            );
            // Measured-latency cells: empty exactly when not measured
            // (in-process rounds), numeric round-trip when measured.
            for (name, want) in [
                ("meas_uplink_max_secs", l.rounds[i].meas_uplink_max_secs),
                ("meas_uplink_mean_secs", l.rounds[i].meas_uplink_mean_secs),
            ] {
                if want.is_finite() {
                    let got: f64 = cells[col(name)].parse().unwrap();
                    assert!((got - want).abs() < 1e-9, "row {i} {name}");
                } else {
                    assert!(cells[col(name)].is_empty(), "row {i}: want empty {name}");
                }
            }
        }
    }

    #[test]
    fn time_to_accuracy_reads_the_simulated_clock() {
        let l = log(); // acc 0.2, 0.3, ... 0.6 at sim 2, 4, ... 10
        assert_eq!(l.time_to_accuracy(0.45), Some(8.0)); // round 3
        assert_eq!(l.time_to_accuracy(0.2), Some(2.0));
        assert_eq!(l.time_to_accuracy(0.9), None, "never reached");
        // A run without the simulated clock has no time axis at all.
        let mut dry = log();
        for r in &mut dry.rounds {
            r.sim_secs = f64::NAN;
        }
        assert_eq!(dry.time_to_accuracy(0.2), None);
    }

    #[test]
    fn json_roundtrip_parses() {
        let j = log().to_json();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("algorithm").unwrap().as_str(), Some("fedadam-ssm"));
        assert_eq!(v.get("rounds").unwrap().as_arr().unwrap().len(), 5);
        // NaN must serialize as null, not break the document.
        let mut l = log();
        l.rounds[0].test_accuracy = f64::NAN;
        assert!(crate::util::json::parse(&l.to_json()).is_ok());
    }

    #[test]
    fn uplink_mbit() {
        let l = log();
        assert!((l.uplink_mbit(0) - 1.0).abs() < 1e-12);
        assert!(l.uplink_mbit(99).is_nan());
    }
}
