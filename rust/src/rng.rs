//! Deterministic pseudo-random number generation.
//!
//! The sandbox builds fully offline against the vendored crate set, which
//! does not include `rand`/`rand_distr`, so this module provides the small
//! set of generators the framework needs: SplitMix64 seeding,
//! xoshiro256++ as the workhorse generator, Box–Muller normals,
//! Marsaglia–Tsang gammas and a Dirichlet built on top of them.
//!
//! Everything is reproducible from a `u64` seed; every consumer derives its
//! own stream via [`Rng::fork`] so experiment components never share state.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a `u64`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// The raw xoshiro256++ state — a snapshot of this stream's cursor.
    /// Persisting it (e.g. in a coordinator journal snapshot) and later
    /// rebuilding via [`Rng::from_state`] resumes the stream exactly
    /// where it left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact saved cursor (inverse of
    /// [`Rng::state`] — NOT a seeding function; use [`Rng::new`] for that).
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent stream (e.g. per device, per round).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Johnk boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a + 1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.uniform().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): normalized vector of gammas.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = v.iter().sum();
        for x in &mut v {
            *x /= sum;
        }
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(3);
        for &shape in &[0.1, 0.5, 1.0, 3.0, 10.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(0.5),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for &alpha in &[0.1, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 10);
            assert_eq!(v.len(), 10);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_peaky() {
        let mut r = Rng::new(5);
        let v = r.dirichlet(0.05, 10);
        let max = v.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.5, "low-concentration Dirichlet should be peaky: {v:?}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..32 {
            assert_eq!(r.categorical(&w), 2);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(8);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
