//! Exact top-k selection by magnitude (paper Definition 1).
//!
//! The SSM is `1_{Top_k}(ΔW)` (eq. 28), so top-k selection sits on the
//! device hot path once per round per device.  A full sort is `O(d log d)`;
//! this module uses **quickselect** over the magnitudes (`O(d)` expected)
//! followed by a small sort of the selected indices.  Ties at the threshold
//! are broken by lower-index-first so the mask always has *exactly* `k`
//! ones — `Definition 1`'s permutation tie-break — which keeps the wire
//! cost model exact (the python kernel keeps ties instead; the cross-layer
//! tests use tie-free inputs).

/// Indices of the `k` largest `|x|`, returned sorted ascending.
///
/// `k` is clamped to `[0, d]`.  Exactly `min(k, d)` indices are returned.
pub fn top_k_indices(x: &[f32], k: usize) -> Vec<u32> {
    let d = x.len();
    let k = k.min(d);
    if k == 0 {
        return Vec::new();
    }
    if k == d {
        return (0..d as u32).collect();
    }
    // Quickselect on (magnitude, index) keys; order: larger magnitude first,
    // then smaller index first.
    let mut idx: Vec<u32> = (0..d as u32).collect();
    let mut lo = 0usize;
    let mut hi = d;
    let mut state = 0x9E37_79B9_7F4A_7C15u64 ^ (d as u64);
    while hi - lo > 1 {
        // Pseudo-random pivot avoids adversarial quadratic behaviour.
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let pivot_at = lo + (state as usize) % (hi - lo);
        idx.swap(lo, pivot_at);
        let pivot = idx[lo];
        let pm = mag(x, pivot);
        let mut i = lo + 1;
        let mut j = hi - 1;
        loop {
            while i <= j && before(x, idx[i], pm, pivot) {
                i += 1;
            }
            while i <= j && !before(x, idx[j], pm, pivot) {
                j -= 1;
            }
            if i >= j {
                break;
            }
            idx.swap(i, j);
        }
        idx.swap(lo, i - 1);
        let rank = i - 1; // pivot's final position
        match rank.cmp(&k) {
            std::cmp::Ordering::Equal => break,
            std::cmp::Ordering::Less => lo = rank + 1,
            std::cmp::Ordering::Greater => hi = rank,
        }
        if lo >= k {
            break;
        }
    }
    let mut out: Vec<u32> = idx[..k].to_vec();
    out.sort_unstable();
    out
}

#[inline]
fn mag(x: &[f32], i: u32) -> f32 {
    x[i as usize].abs()
}

/// Strict ordering: does element `a` come before the pivot?
#[inline]
fn before(x: &[f32], a: u32, pivot_mag: f32, pivot_idx: u32) -> bool {
    let am = mag(x, a);
    am > pivot_mag || (am == pivot_mag && a < pivot_idx)
}

/// The k-th largest magnitude (the Pallas kernel's `tau`).
///
/// Contract: the keep rule is `|x| >= tau`, so an empty selection must
/// keep *nothing* — `k == 0` and empty input both return `f32::INFINITY`
/// (no finite magnitude passes).  This is also the `fold(min)` identity,
/// so the two cases need no special-casing downstream.  `k > len` clamps
/// to `len` (the threshold is the smallest magnitude present).
///
/// The Pallas kernel (`compile/kernels/topk.py`) cannot represent `k == 0`
/// at all — it clips `k` into `[1, d]` — so the ∞ convention here is the
/// rust-side extension of the same `|x| >= tau` rule, not a divergence.
pub fn top_k_threshold(x: &[f32], k: usize) -> f32 {
    if k == 0 || x.is_empty() {
        return f32::INFINITY;
    }
    let idx = top_k_indices(x, k);
    idx.iter().map(|&i| x[i as usize].abs()).fold(f32::INFINITY, f32::min)
}

/// Dense 0/1 mask of the top-k (exactly k ones).
pub fn top_k_mask(x: &[f32], k: usize) -> Vec<bool> {
    let mut mask = vec![false; x.len()];
    for i in top_k_indices(x, k) {
        mask[i as usize] = true;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn brute_force(x: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..x.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            x[b as usize]
                .abs()
                .partial_cmp(&x[a as usize].abs())
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut out: Vec<u32> = idx[..k.min(x.len())].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_brute_force_randomized() {
        let mut rng = Rng::new(99);
        for trial in 0..50 {
            let d = 1 + rng.below(300);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let k = rng.below(d + 1);
            assert_eq!(top_k_indices(&x, k), brute_force(&x, k), "trial {trial} d={d} k={k}");
        }
    }

    #[test]
    fn handles_ties_by_index() {
        let x = vec![1.0, -1.0, 1.0, 1.0];
        assert_eq!(top_k_indices(&x, 2), vec![0, 1]);
        assert_eq!(top_k_indices(&x, 3), vec![0, 1, 2]);
    }

    #[test]
    fn edge_cases() {
        assert!(top_k_indices(&[], 3).is_empty());
        assert!(top_k_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k_indices(&[1.0, 2.0], 5), vec![0, 1]);
    }

    #[test]
    fn threshold_is_kth_magnitude() {
        let x = vec![0.1, -5.0, 3.0, -2.0, 4.0];
        assert_eq!(top_k_threshold(&x, 1), 5.0);
        assert_eq!(top_k_threshold(&x, 3), 3.0);
        assert_eq!(top_k_threshold(&x, 5), 0.1);
    }

    #[test]
    fn threshold_empty_selection_keeps_nothing() {
        // Contract: keep rule is |x| >= tau, so k == 0 and empty input both
        // yield +inf — no finite element passes.
        let x = vec![0.1, -5.0, 3.0];
        assert_eq!(top_k_threshold(&x, 0), f32::INFINITY);
        assert_eq!(top_k_threshold(&[], 3), f32::INFINITY);
        assert_eq!(top_k_threshold(&[], 0), f32::INFINITY);
        assert_eq!(x.iter().filter(|v| v.abs() >= f32::INFINITY).count(), 0);
        // k > len clamps: threshold is the smallest magnitude present.
        assert_eq!(top_k_threshold(&x, 99), 0.1);
    }

    #[test]
    fn mask_has_exactly_k_ones() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        for &k in &[0usize, 1, 50, 999, 1000] {
            let ones = top_k_mask(&x, k).iter().filter(|&&b| b).count();
            assert_eq!(ones, k);
        }
    }

    #[test]
    fn all_equal_input() {
        let x = vec![2.0f32; 64];
        let idx = top_k_indices(&x, 10);
        assert_eq!(idx, (0..10).collect::<Vec<u32>>());
    }
}
