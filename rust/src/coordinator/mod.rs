//! The round coordinator: Algorithm 2's outer loop.
//!
//! Owns the engine pool, data, devices, algorithm and ledger; each round it
//! (1) hands devices the global state per the algorithm's momentum policy,
//! (2) runs `L` local epochs per device through the AOT programs —
//!     **concurrently**, on scoped threads, load-balanced across the
//!     engine pool's workers,
//! (3) compresses and "uploads" each delta (bit-accurately priced),
//! (4) FedAvg-aggregates, post-processes, applies, and
//! (5) evaluates + logs.
//!
//! Determinism: local training for every participant starts from the same
//! downloaded global state, so per-device results do not depend on
//! scheduling.  Training results are collected and processed in ascending
//! device order, and compression (which may hold per-device algorithm
//! state such as error-feedback memories) plus ledger accounting stay
//! sequential in that same order — every f32 sum, the comm ledger and the
//! experiment log are byte-identical at any `num_workers`.

pub mod device;
pub mod server;

use std::time::Instant;

use anyhow::{Context, Result};

use crate::algorithms::{self, Algorithm, LocalDelta, MomentumPolicy, Upload};
use crate::config::{ExperimentConfig, SparsifyBackend};
use crate::data::{partition, synthetic, Dataset, Partition, Shard};
use crate::metrics::comm::CommLedger;
use crate::metrics::{ExperimentLog, RoundRecord};
use crate::runtime::{EngineHandle, EnginePool, Manifest};
use crate::tensor;

pub use device::{Device, LocalRunConfig};
pub use server::{aggregate, aggregate_sharded, GlobalState};

/// A fully-wired experiment ready to run.
pub struct Coordinator {
    pub cfg: ExperimentConfig,
    pool: EnginePool,
    devices: Vec<Device>,
    test_set: Dataset,
    algorithm: Box<dyn Algorithm>,
    global: GlobalState,
    /// Per-device `(m, v)` for `MomentumPolicy::DeviceLocal` algorithms.
    device_moments: Vec<(Vec<f32>, Vec<f32>)>,
    ledger: CommLedger,
    log: ExperimentLog,
    round: usize,
    /// Round-robin participation RNG (partial participation).
    sampler: crate::rng::Rng,
}

/// What one participant's scoped-thread training run produces.
struct TrainOutput {
    mean_loss: f64,
    delta: LocalDelta,
    /// `(m, v)` to write back when the policy is `DeviceLocal`.
    moments: Option<(Vec<f32>, Vec<f32>)>,
}

impl Coordinator {
    /// Build everything: engine pool, data, shards, algorithm, initial model.
    pub fn new(cfg: ExperimentConfig, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        // Validate before the (expensive) pool build; `with_pool` validates
        // again because it is itself a public entry point.
        cfg.validate()?;
        let manifest = Manifest::load(artifacts_dir)?;
        // Concurrency is bounded by participant count, so never spin up
        // (and compile executables for) more workers than devices.
        let workers = crate::runtime::pool::resolve_workers(cfg.num_workers).min(cfg.devices);
        let pool = EnginePool::load(&manifest, &cfg.model, workers)
            .with_context(|| format!("loading model {:?}", cfg.model))?;
        Self::with_pool(cfg, pool)
    }

    /// Build an experiment on an already-constructed engine pool.
    ///
    /// This is the backend-injection seam: tests and benches hand in an
    /// [`EnginePool`] built from any [`crate::runtime::Executor`] factory
    /// (e.g. the pure-Rust [`crate::runtime::ReferenceExecutor`], which
    /// needs no PJRT artifacts), and the full round loop — training,
    /// compression, aggregation, eval, ledger — runs against it.
    pub fn with_pool(cfg: ExperimentConfig, pool: EnginePool) -> Result<Self> {
        cfg.validate()?;
        let meta = pool.meta().clone();

        // Synthetic stand-in corpus shaped for this model.
        let spec = synthetic::SyntheticSpec::for_input_shape(
            &meta.input_shape,
            cfg.train_samples,
            cfg.test_samples,
        );
        let task = synthetic::generate(&spec, cfg.seed);
        let how = Partition::parse(cfg.iid, cfg.dirichlet_theta);
        let shards = partition(&task.train, cfg.devices, how, cfg.seed);

        let handle = pool.handle();
        let devices: Vec<Device> = shards
            .into_iter()
            .enumerate()
            .map(|(i, data)| Device::new(i, Shard { data }, handle.clone()))
            .collect();

        let algorithm = algorithms::build(&cfg, meta.dim)?;
        let w0 = handle.init(cfg.seed as i32)?;
        let global = GlobalState::new(w0);
        let device_moments = (0..cfg.devices)
            .map(|_| (vec![0.0f32; meta.dim], vec![0.0f32; meta.dim]))
            .collect();

        let cfg_seed = cfg.seed;
        let log = ExperimentLog {
            name: cfg.name.clone(),
            algorithm: cfg.algorithm.clone(),
            model: cfg.model.clone(),
            iid: cfg.iid,
            rounds: Vec::new(),
        };
        Ok(Coordinator {
            cfg,
            pool,
            devices,
            test_set: task.test,
            algorithm,
            global,
            device_moments,
            ledger: CommLedger::default(),
            log,
            round: 0,
            sampler: crate::rng::Rng::new(cfg_seed ^ 0x5a3c_91f7),
        })
    }

    /// Devices participating this round (uniform without replacement when
    /// `participation < 1`; at least one device always runs).
    fn sample_participants(&mut self) -> Vec<usize> {
        let n = self.devices.len();
        let m = ((n as f64 * self.cfg.participation).round() as usize).clamp(1, n);
        if m == n {
            return (0..n).collect();
        }
        let mut idx: Vec<usize> = (0..n).collect();
        self.sampler.shuffle(&mut idx);
        idx.truncate(m);
        idx.sort_unstable();
        idx
    }

    /// Immutable view of the global state.
    pub fn global(&self) -> &GlobalState {
        &self.global
    }

    pub fn handle(&self) -> EngineHandle {
        self.pool.handle()
    }

    /// Worker threads in the engine pool.
    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    /// Run one communication round; returns its record.
    pub fn step_round(&mut self) -> Result<RoundRecord> {
        let t = self.round;
        let start = Instant::now();
        let run_cfg = LocalRunConfig {
            local_epochs: self.cfg.local_epochs,
            max_batches_per_epoch: self.cfg.max_batches_per_epoch,
            lr: self.cfg.lr as f32,
            use_epoch_program: self.cfg.use_epoch_program,
        };
        let mode = self.algorithm.local_mode(t);
        let policy = self.algorithm.momentum_policy(t);
        let keep_moments = policy == MomentumPolicy::DeviceLocal;
        let dim = self.global.dim();

        let participants = self.sample_participants();

        // 1-4. Train → delta → compress → upload, in bounded chunks of
        //    participants so peak memory stays O(chunk · d) rather than
        //    O(N · d) (dense deltas are 3·d f32 each; at 100+ devices and
        //    ResNet-scale d an unbounded barrier would hold gigabytes).
        //
        //    Within a chunk, local training runs on one scoped thread per
        //    participant; threads block inside the engine pool's queue, so
        //    concurrency is governed by `num_workers`, and each result is a
        //    pure function of its inputs — scheduling cannot change any bit
        //    of the output.  Chunks, result collection, compression (which
        //    may mutate per-device algorithm state such as EF memories) and
        //    ledger accounting all proceed in ascending device order, so
        //    the wire log is byte-identical at any worker count.
        let chunk_size = (self.pool.num_workers() * 2).max(8);
        let mut uploads: Vec<Upload> = Vec::with_capacity(participants.len());
        let mut loss_sum = 0.0f64;
        for chunk in participants.chunks(chunk_size) {
            // Download: snapshot starting moments before any training runs
            // (matches the sequential schedule — a device only ever
            // observed its own pre-round state anyway).
            let downloads: Vec<(Vec<f32>, Vec<f32>)> = chunk
                .iter()
                .map(|&di| match policy {
                    MomentumPolicy::Aggregated => (self.global.m.clone(), self.global.v.clone()),
                    MomentumPolicy::DeviceLocal => self.device_moments[di].clone(),
                })
                .collect();
            let global_w = &self.global.w;
            // Re-derived per chunk (not hoisted for the whole round): the
            // compress stage below needs `&mut self`, which cannot coexist
            // with `&mut Device` borrows held for later chunks.  The rescan
            // is O(devices · log participants) per chunk — noise next to
            // training.  Relies on `sample_participants` returning sorted
            // indices (it does; binary_search would misassign otherwise).
            let chunk_devices: Vec<(usize, &mut Device)> = self
                .devices
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| chunk.binary_search(i).is_ok())
                .collect();
            let outputs: Vec<Result<TrainOutput>> = std::thread::scope(|scope| {
                let handles: Vec<_> = chunk_devices
                    .into_iter()
                    .zip(downloads)
                    .map(|((_di, dev), (m0, v0))| {
                        scope.spawn(move || -> Result<TrainOutput> {
                            let result = dev.train_round(
                                mode,
                                global_w.clone(),
                                m0.clone(),
                                v0.clone(),
                                &run_cfg,
                            )?;
                            let delta = LocalDelta {
                                dw: tensor::sub(&result.w, global_w),
                                dm: tensor::sub(&result.m, &m0),
                                dv: tensor::sub(&result.v, &v0),
                                weight: dev.weight(),
                            };
                            Ok(TrainOutput {
                                mean_loss: result.mean_loss,
                                delta,
                                moments: keep_moments.then(|| (result.m, result.v)),
                            })
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
                    .collect()
            });
            for (&di, output) in chunk.iter().zip(outputs) {
                let output = output.with_context(|| format!("device {di} local round"))?;
                loss_sum += output.mean_loss;
                if let Some(moments) = output.moments {
                    self.device_moments[di] = moments;
                }
                let upload = self.compress_upload(t, di, output.delta)?;
                self.ledger.up(upload.bits);
                uploads.push(upload);
            }
        }

        // 5. Server aggregate + broadcast — sharded across the lane space
        //    (bit-identical to the 1-shard reduce at any shard count).
        let shards = if self.cfg.agg_shards == 0 {
            self.pool.num_workers()
        } else {
            self.cfg.agg_shards
        };
        let mut agg = aggregate_sharded(&uploads, dim, shards);
        self.algorithm.postprocess(&mut agg);
        self.ledger
            .down(self.algorithm.downlink_bits(&agg), participants.len());
        let update_norm = tensor::l2_norm(&agg.dw);
        self.global.apply(&agg);

        // 6. Evaluate.
        let (test_loss, test_acc) = if t % self.cfg.eval_every == 0 || t + 1 == self.cfg.rounds {
            self.evaluate()?
        } else {
            (f64::NAN, f64::NAN)
        };

        let record = RoundRecord {
            round: t,
            train_loss: loss_sum / participants.len() as f64,
            test_loss,
            test_accuracy: test_acc,
            uplink_bits: self.ledger.uplink_bits,
            downlink_bits: self.ledger.downlink_bits,
            wall_secs: start.elapsed().as_secs_f64(),
            update_norm,
        };
        self.log.rounds.push(record.clone());
        self.round += 1;
        Ok(record)
    }

    /// Compress via the configured backend (native quickselect, or the
    /// AOT Pallas sparsifier for the plain SSM algorithm).
    fn compress_upload(&mut self, t: usize, di: usize, delta: LocalDelta) -> Result<Upload> {
        if self.cfg.sparsify_backend == SparsifyBackend::Xla
            && self.cfg.algorithm == "fedadam-ssm"
        {
            // Cross-layer path: run eq. 10-12 + 28 inside XLA, then encode.
            use crate::algorithms::Recon;
            use crate::sparse::{codec::cost, top_k_indices, SparseVec};
            let dim = delta.dw.len();
            let k = self.cfg.k_for(dim);
            // The shared mask's support comes from the threshold indices,
            // NOT from the kernel output's non-zeros: a kept lane whose
            // value is exactly 0.0 is still transmitted (and priced), and
            // `SparseVec::from_dense` would silently drop it, making
            // `nnz < k` while `bits` charges for k.  Gathering the masked
            // kernel outputs at the top-k indices keeps the encoded wire
            // format bit-for-bit consistent with `cost::fedadam_ssm(d, k)`.
            // (The kernel keeps ties at the threshold, so its support is a
            // superset of these exactly-k indices; values at them agree.)
            let idx = top_k_indices(&delta.dw, k);
            let (sw, sm, sv) = self
                .pool
                .handle()
                .sparsify(delta.dw, delta.dm, delta.dv, k as i32)?;
            return Ok(Upload {
                dw: Recon::Sparse(SparseVec::gather(&sw, &idx)),
                dm: Some(Recon::Sparse(SparseVec::gather(&sm, &idx))),
                dv: Some(Recon::Sparse(SparseVec::gather(&sv, &idx))),
                weight: delta.weight,
                bits: cost::fedadam_ssm(dim, k),
            });
        }
        Ok(self.algorithm.compress(t, di, delta))
    }

    /// Evaluate the global model on the held-out test set, fanning eval
    /// batches out across the engine pool.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        evaluate_model(
            &self.pool.handle(),
            &self.global.w,
            &self.test_set,
            self.pool.num_workers(),
        )
    }

    /// Run all configured rounds, returning the full log.
    pub fn run(&mut self) -> Result<ExperimentLog> {
        while self.round < self.cfg.rounds {
            let r = self.step_round()?;
            log::info!(
                "[{}] round {:>3}: loss {:.4} acc {} uplink {:.2} Mbit ({:.1}s)",
                self.cfg.algorithm,
                r.round,
                r.train_loss,
                if r.test_accuracy.is_nan() {
                    "-".to_string()
                } else {
                    format!("{:.3}", r.test_accuracy)
                },
                r.uplink_bits as f64 / 1e6,
                r.wall_secs,
            );
        }
        Ok(self.log.clone())
    }

    /// The log accumulated so far.
    pub fn log(&self) -> &ExperimentLog {
        &self.log
    }
}

/// Build and run eval batch `b` (samples `[b·e, (b+1)·e) ∩ [0, len)`,
/// zero-weight-padded to the program's fixed batch shape).
fn eval_one_batch(
    engine: &EngineHandle,
    w: &[f32],
    data: &Dataset,
    b: usize,
) -> Result<(f64, f64, f64)> {
    let meta = engine.meta();
    let e = meta.eval_batch;
    let row = meta.row();
    let start = b * e;
    let n = (data.len() - start).min(e);
    let mut x = Vec::with_capacity(e * row);
    let mut y = Vec::with_capacity(e);
    let mut wt = Vec::with_capacity(e);
    for i in 0..e {
        if i < n {
            x.extend_from_slice(data.image(start + i));
            y.push(data.labels[start + i]);
            wt.push(1.0);
        } else {
            x.extend(std::iter::repeat(0.0).take(row));
            y.push(0);
            wt.push(0.0);
        }
    }
    engine.eval_batch(w, x, y, wt)
}

/// Evaluate `w` over `data` in fixed-size weighted eval batches, fanning
/// the batches out across the engine pool.
///
/// The test set is pre-sliced into `ceil(len / eval_batch)` batches;
/// batches are dispatched concurrently in chunks of `workers` scoped
/// threads (each blocks inside the pool's queue, so device-level
/// concurrency is governed by the pool), and the per-batch
/// `(loss_sum, correct, weight)` triples are reduced **in ascending batch
/// order**.  Each batch is a pure function of its inputs and the f64
/// reduction order is fixed, so the result is bit-identical to the
/// sequential path (`workers = 1`) at any worker count.
pub fn evaluate_model(
    engine: &EngineHandle,
    w: &[f32],
    data: &Dataset,
    workers: usize,
) -> Result<(f64, f64)> {
    let e = engine.meta().eval_batch;
    let nb = data.len().div_ceil(e.max(1));
    let workers = workers.max(1);

    let mut parts: Vec<(f64, f64, f64)> = Vec::with_capacity(nb);
    if workers == 1 {
        for b in 0..nb {
            parts.push(eval_one_batch(engine, w, data, b)?);
        }
    } else {
        for chunk_start in (0..nb).step_by(workers) {
            let chunk_end = (chunk_start + workers).min(nb);
            let outs: Vec<Result<(f64, f64, f64)>> = std::thread::scope(|scope| {
                let handles: Vec<_> = (chunk_start..chunk_end)
                    .map(|b| {
                        let h = engine.clone();
                        scope.spawn(move || eval_one_batch(&h, w, data, b))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
                    .collect()
            });
            for out in outs {
                parts.push(out?);
            }
        }
    }

    let mut loss_sum = 0.0;
    let mut correct = 0.0;
    let mut weight = 0.0;
    for (ls, c, wsum) in parts {
        loss_sum += ls;
        correct += c;
        weight += wsum;
    }
    if weight == 0.0 {
        return Ok((f64::NAN, f64::NAN));
    }
    Ok((loss_sum / weight, correct / weight))
}
