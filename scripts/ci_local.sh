#!/usr/bin/env bash
# Run the full CI matrix locally in one command — the same gates
# .github/workflows/ci.yml runs on every push:
#
#   1. tier-1: release build + full test suite
#   2. determinism grid: workers x shards x pipeline_depth x
#      participation_mode, via the FEDADAM_* env overrides the test base
#      configs read (the determinism-bearing suites only, to keep the
#      sweep fast; CI re-runs the full suite per grid point)
#   3. quantized-SSM conformance lanes: FEDADAM_ALGORITHM in
#      {fedadam-ssm-q, fedadam-ssm-qef} x FEDADAM_PIPELINE_DEPTH in {0, 2}
#      pins the conformance suite to one quantized id per lane
#   4. resume lanes: the kill/resume + journal-purity suite pinned at
#      FEDADAM_PIPELINE_DEPTH in {0, 2}
#   5. transport lane: the socket bit-identity + hostile-bytes suites,
#      the agent kill-respawn durability suite (a killed agent process
#      restarted against its agent_state_dir stays bit-identical), then
#      the multi-process demo (1 coordinator + 2 agent processes; its
#      exit status is the byte-identity assert)
#   6. clippy -D warnings + rustfmt --check (skipped with a note when the
#      components aren't installed)
#   7. rustdoc with -D warnings (broken intra-doc links fail) + doc-tests
#   8. benches stay buildable (cargo bench --no-run)
#   9. perf pins: e2e_round, transport_loopback, topk, quant and
#      agg_scaling --json vs the checked-in BENCH_*.json (prints WARN on
#      >10% wall-clock regression; never fails — absolute numbers are
#      host-dependent).  transport_loopback additionally hard-asserts
#      in-bench that a real device agent's RSS growth stays flat between
#      fleet 1e3 and 1e5 (the agent-round-fleet-* cases; -snap pins
#      snapshot overhead); topk/quant re-assert in-bench that the radix
#      select matches the sort oracle and the fused encode stays
#      byte-identical to the staged pipeline
#  10. fleet lane: fleet_scaling in quick mode (fleets 1e3/1e5) — the
#      per-round flatness assert and the dense-vs-spilled residual
#      conformance leg are hard gates; the BENCH_fleet_scaling.json
#      diff is warn-only (1e6 is local-only, without FEDADAM_BENCH_QUICK)
#
# Usage: scripts/ci_local.sh [--quick]
#   --quick  skip the determinism + conformance + resume grids
#            (tier-1 + transport + lint + docs + benches + perf pins only)

set -euo pipefail
cd "$(dirname "$0")/.."

QUICK=0
[[ "${1:-}" == "--quick" ]] && QUICK=1

step() { printf '\n\033[1m== %s ==\033[0m\n' "$*"; }

step "tier-1: cargo build --release"
cargo build --release

step "tier-1: cargo test -q"
cargo test -q

if [[ "$QUICK" == 0 ]]; then
  for workers in 1 4; do
    for shards in 1 4; do
      for pipeline in 0 2; do
        for mode in uniform importance; do
          step "determinism: workers=$workers shards=$shards pipeline_depth=$pipeline mode=$mode"
          FEDADAM_NUM_WORKERS=$workers \
          FEDADAM_AGG_SHARDS=$shards \
          FEDADAM_PIPELINE_DEPTH=$pipeline \
          FEDADAM_PARTICIPATION_MODE=$mode \
            cargo test -q --test algorithm_conformance --test coordinator_e2e --test proptests
        done
      done
    done
  done

  for algo in fedadam-ssm-q fedadam-ssm-qef; do
    for pipeline in 0 2; do
      step "conformance: algorithm=$algo pipeline_depth=$pipeline"
      FEDADAM_ALGORITHM=$algo \
      FEDADAM_PIPELINE_DEPTH=$pipeline \
        cargo test -q --test algorithm_conformance
    done
  done

  for pipeline in 0 2; do
    step "resume: pipeline_depth=$pipeline kill/resume + journal purity"
    FEDADAM_PIPELINE_DEPTH=$pipeline \
      cargo test -q --test resume_conformance
  done
fi

step "transport: socket suite + hostile-bytes properties"
cargo test -q --test transport
cargo test -q --test proptests -- \
  prop_frame_mutation prop_msg_mutation prop_wire_body_mutation

step "transport: agent kill-respawn durability (fresh-process resume)"
# Named explicitly (they also ran in the full suite above) so a
# durability regression is unmissable in the step log.
cargo test -q --test transport -- \
  killed_agent_respawns crash_between_persist_and_send

step "transport: multi-process demo (exit status = byte-identity)"
cargo run --release --example multiprocess_demo

step "lint: clippy + rustfmt"
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "clippy not installed; skipping (CI runs it)"
fi
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --check
else
  echo "rustfmt not installed; skipping (CI runs it)"
fi

step "docs: cargo doc --no-deps + doc-tests"
cargo doc --no-deps
cargo test --doc -q

step "benches: cargo bench --no-run"
cargo bench --no-run

step "perf pin: e2e_round --json vs BENCH_e2e_round.json (warn-only)"
FEDADAM_BENCH_QUICK=1 \
  cargo bench --bench e2e_round -- --json \
    --json-out target/BENCH_e2e_round.json \
    --baseline BENCH_e2e_round.json

step "perf pin: transport_loopback --json vs BENCH_transport_loopback.json (warn-only)"
FEDADAM_BENCH_QUICK=1 \
  cargo bench --bench transport_loopback -- --json \
    --json-out target/BENCH_transport_loopback.json \
    --baseline BENCH_transport_loopback.json

step "perf pin: topk --json vs BENCH_topk.json (warn-only)"
FEDADAM_BENCH_QUICK=1 \
  cargo bench --bench topk -- --json \
    --json-out target/BENCH_topk.json \
    --baseline BENCH_topk.json

step "perf pin: quant --json vs BENCH_quant.json (warn-only)"
FEDADAM_BENCH_QUICK=1 \
  cargo bench --bench quant -- --json \
    --json-out target/BENCH_quant.json \
    --baseline BENCH_quant.json

step "perf pin: agg_scaling --json vs BENCH_agg_scaling.json (warn-only)"
FEDADAM_BENCH_QUICK=1 \
  cargo bench --bench agg_scaling -- --json \
    --json-out target/BENCH_agg_scaling.json \
    --baseline BENCH_agg_scaling.json

step "fleet lane: fleet_scaling flatness + spill conformance (quick: 1e3/1e5)"
# Hard gates (in-bench asserts): per-round wall-clock flat in fleet size,
# dense-vs-spilled residuals bit-identical across the zoo.  The baseline
# diff is warn-only.  The 1e6 sweep runs without FEDADAM_BENCH_QUICK.
FEDADAM_BENCH_QUICK=1 \
  cargo bench --bench fleet_scaling -- --json \
    --json-out target/BENCH_fleet_scaling.json \
    --baseline BENCH_fleet_scaling.json

step "ci_local: all gates green"
