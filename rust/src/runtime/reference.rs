//! A pure-Rust reference executor: the deterministic stand-in backend.
//!
//! The container build links the vendored `xla` stub, so the AOT artifacts
//! cannot execute and every artifact-gated test skips.  This module closes
//! that gap: [`ReferenceExecutor`] implements the full [`Prog`] contract
//! (init / train / epoch / eval / sgd / grads / sparsify) for a linear
//! softmax classifier in plain `f32` Rust, so the **entire** coordinator
//! loop — local training, compression, streaming aggregation, overlapped
//! eval, ledger — runs and is testable offline.  The algorithm-zoo
//! conformance suite (including its `pipeline_depth` bit-identity sweep),
//! the aggregation/eval benches and the barrier-vs-pipelined
//! `e2e_round` bench are built on it.
//!
//! Semantics mirror the AOT programs:
//! - every call is a **pure function of its arguments** (no hidden state),
//!   so results are bitwise independent of which pool worker serves it;
//! - Adam uses the paper's constants (β₁ = 0.9, β₂ = 0.999, ε = 1e-6);
//! - `eval` returns weighted `(loss_sum, correct, weight_sum)` — a lane
//!   with weight `0.0` contributes exactly nothing, whatever its payload;
//! - `sparsify` applies the shared top-k mask of `|ΔW|` with the kernel's
//!   tie rule (keep every lane with `|ΔW| >= τ`, a superset of k on ties).
//!
//! Model: `logits = W·x + b` with `W: [classes, row]` row-major followed
//! by `b: [classes]`, so `dim = classes·(row + 1)`.
//!
//! **Float epoch**: since PR 10 the default [`KernelMode::Blocked`]
//! computes logits through blocked kernels whose dot products use eight
//! fixed-order partial accumulators ([`dot8`] — vectorizable because the
//! loop-carried serial dependency is gone).  That reassociates float sums
//! versus the seed-era per-sample scalar loops, so trajectories are close
//! but **not bit-identical** to the old epoch — the PR-8 alias-table
//! precedent: the epoch change is declared here, the conformance grids
//! (run-vs-run comparisons) re-pin automatically, and the retired
//! [`KernelMode::PerSample`] path is retained as the differential oracle
//! (`tests/reference_kernels.rs`).  Each mode by itself remains a pure
//! function of its arguments, bitwise reproducible at any worker count.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::engine::{Arg, Prog};
use super::manifest::ModelMeta;
use super::pool::{EnginePool, Executor};

/// Paper Adam constants (match `artifacts/manifest.json`).
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-6;

/// Samples per block in the blocked logit kernel: small enough that a
/// block of logits stays in L1, large enough to amortize each weight-row
/// load across the block.
const SAMPLE_BLOCK: usize = 8;

/// Which float epoch the executor's training kernels compute in.
///
/// Both modes are pure functions of their arguments and bitwise
/// reproducible at any worker count; they differ only in the
/// *association order* of the logit dot-product sums (see the module
/// docs).  `Blocked` is the default; `PerSample` is kept as the
/// seed-era oracle for the differential suite.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// Blocked matrix-matrix kernels with fixed-order 8-lane partial
    /// accumulators ([`dot8`]).  Current epoch.
    #[default]
    Blocked,
    /// The original per-sample scalar triple loops.  Retired epoch,
    /// retained as the differential-test oracle.
    PerSample,
}

/// Fixed-order 8-accumulator dot product.
///
/// Splitting the sum across eight independent partial accumulators
/// removes the loop-carried dependency of the serial `z += a[j]*b[j]`
/// form, so the compiler can vectorize it without `-ffast-math`-style
/// licence.  The combine order — `((s0+s4)+(s2+s6)) + ((s1+s5)+(s3+s7))`
/// then the scalar tail — is fixed, making the function a pure,
/// platform-deterministic map from its inputs to one `f32`.
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for ch in 0..chunks {
        let o = ch * 8;
        for (l, s) in acc.iter_mut().enumerate() {
            *s += a[o + l] * b[o + l];
        }
    }
    let mut tail = 0.0f32;
    for j in chunks * 8..n {
        tail += a[j] * b[j];
    }
    let s0 = (acc[0] + acc[4]) + (acc[2] + acc[6]);
    let s1 = (acc[1] + acc[5]) + (acc[3] + acc[7]);
    (s0 + s1) + tail
}

/// Build the [`ModelMeta`] for a reference linear model.
///
/// `dim = num_classes * (row + 1)` where `row = Π input_shape`.
pub fn reference_meta(
    input_shape: &[usize],
    num_classes: usize,
    batch: usize,
    eval_batch: usize,
    epoch_batches: usize,
) -> ModelMeta {
    let row: usize = input_shape.iter().product();
    ModelMeta {
        name: "reference-linear".into(),
        dim: num_classes * (row + 1),
        input_shape: input_shape.to_vec(),
        num_classes,
        batch,
        eval_batch,
        epoch_batches,
        artifacts: BTreeMap::new(),
    }
}

/// An [`EnginePool`] whose every worker runs a [`ReferenceExecutor`]
/// in the default [`KernelMode::Blocked`].
pub fn reference_pool(meta: ModelMeta, num_workers: usize) -> Result<EnginePool> {
    reference_pool_with_mode(meta, num_workers, KernelMode::default())
}

/// [`reference_pool`] with an explicit [`KernelMode`] — used by the
/// differential suite to run the retired per-sample epoch side by side
/// with the blocked one.
pub fn reference_pool_with_mode(
    meta: ModelMeta,
    num_workers: usize,
    mode: KernelMode,
) -> Result<EnginePool> {
    let factory_meta = meta.clone();
    EnginePool::with_factory(meta, num_workers, move |_worker| {
        ReferenceExecutor::with_mode(factory_meta.clone(), mode)
    })
}

/// The deterministic linear-softmax backend (one per pool worker).
pub struct ReferenceExecutor {
    row: usize,
    classes: usize,
    dim: usize,
    /// Fixed scan length of the `epoch` program (`meta.epoch_batches`).
    epoch_batches: usize,
    /// Float epoch the training kernels compute in.
    mode: KernelMode,
}

impl ReferenceExecutor {
    pub fn new(meta: ModelMeta) -> Result<ReferenceExecutor> {
        Self::with_mode(meta, KernelMode::default())
    }

    pub fn with_mode(meta: ModelMeta, mode: KernelMode) -> Result<ReferenceExecutor> {
        let row = meta.row();
        let classes = meta.num_classes;
        if meta.dim != classes * (row + 1) {
            return Err(anyhow!(
                "reference model needs dim = classes*(row+1) = {}, got {}",
                classes * (row + 1),
                meta.dim
            ));
        }
        Ok(ReferenceExecutor {
            row,
            classes,
            dim: meta.dim,
            epoch_batches: meta.epoch_batches.max(1),
            mode,
        })
    }

    /// Deterministic small-normal init from the seed.
    fn init(&self, seed: i32) -> Vec<f32> {
        let mut rng = crate::rng::Rng::new((seed as i64 as u64) ^ 0x9e37_79b9_7f4a_7c15);
        (0..self.dim).map(|_| (rng.normal() * 0.05) as f32).collect()
    }

    /// `out = W·x + b` for one sample — serial scalar accumulation
    /// (the retired per-sample epoch).
    fn logits(&self, w: &[f32], x: &[f32], out: &mut [f32]) {
        let (row, c) = (self.row, self.classes);
        for (cls, o) in out.iter_mut().enumerate() {
            let wrow = &w[cls * row..(cls + 1) * row];
            let mut z = w[c * row + cls];
            for j in 0..row {
                z += wrow[j] * x[j];
            }
            *o = z;
        }
    }

    /// Logits for a block of `bl` samples, sample-major into
    /// `out[s*classes + cls]`.  The class-major loop order reuses each
    /// weight row across the whole block (one load per `SAMPLE_BLOCK`
    /// samples instead of one per sample); each entry is `bias +
    /// dot8(wrow, xs)` — the blocked float epoch.
    fn logits_block(&self, w: &[f32], xs: &[f32], bl: usize, out: &mut [f32]) {
        let (row, c) = (self.row, self.classes);
        debug_assert_eq!(xs.len(), bl * row);
        debug_assert!(out.len() >= bl * c);
        for cls in 0..c {
            let wrow = &w[cls * row..(cls + 1) * row];
            let bias = w[c * row + cls];
            for s in 0..bl {
                let xi = &xs[s * row..(s + 1) * row];
                out[s * c + cls] = bias + dot8(wrow, xi);
            }
        }
    }

    /// Softmax cross-entropy + prediction for one sample.  `z` holds the
    /// logits on entry and the softmax probabilities on exit.
    fn softmax_loss(z: &mut [f32], label: usize) -> (f32, usize) {
        let max = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in z.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in z.iter_mut() {
            *v /= sum;
        }
        // Argmax with lowest-index tie break (deterministic).
        let mut pred = 0usize;
        for c in 1..z.len() {
            if z[c] > z[pred] {
                pred = c;
            }
        }
        let p_y = z[label].max(f32::MIN_POSITIVE);
        (-(p_y.ln()), pred)
    }

    /// Mean-batch softmax gradient into `g`; returns the mean loss.
    /// Dispatches on the executor's [`KernelMode`].
    fn grad_batch(&self, w: &[f32], x: &[f32], y: &[i32], g: &mut [f32]) -> f32 {
        match self.mode {
            KernelMode::Blocked => self.grad_batch_blocked(w, x, y, g),
            KernelMode::PerSample => self.grad_batch_per_sample(w, x, y, g),
        }
    }

    /// Retired per-sample scalar epoch (differential oracle).
    fn grad_batch_per_sample(&self, w: &[f32], x: &[f32], y: &[i32], g: &mut [f32]) -> f32 {
        let (row, c) = (self.row, self.classes);
        let b = y.len();
        let inv_b = 1.0 / b as f32;
        let mut z = vec![0.0f32; c];
        let mut loss_sum = 0.0f32;
        for i in 0..b {
            let xi = &x[i * row..(i + 1) * row];
            let label = (y[i].rem_euclid(c as i32)) as usize;
            self.logits(w, xi, &mut z);
            let (loss, _pred) = Self::softmax_loss(&mut z, label);
            loss_sum += loss;
            for cls in 0..c {
                let mut gz = z[cls];
                if cls == label {
                    gz -= 1.0;
                }
                let gz = gz * inv_b;
                g[c * row + cls] += gz;
                let grow = &mut g[cls * row..(cls + 1) * row];
                for j in 0..row {
                    grow[j] += gz * xi[j];
                }
            }
        }
        loss_sum * inv_b
    }

    /// Blocked epoch: logits come from [`Self::logits_block`]; the
    /// softmax and the gradient scatter then run per sample in the SAME
    /// ascending order (and same axpy association) as the per-sample
    /// path, so only the logit dot products reassociate between modes.
    fn grad_batch_blocked(&self, w: &[f32], x: &[f32], y: &[i32], g: &mut [f32]) -> f32 {
        let (row, c) = (self.row, self.classes);
        let b = y.len();
        let inv_b = 1.0 / b as f32;
        let mut zb = vec![0.0f32; SAMPLE_BLOCK * c];
        let mut loss_sum = 0.0f32;
        let mut base = 0usize;
        while base < b {
            let bl = (b - base).min(SAMPLE_BLOCK);
            self.logits_block(w, &x[base * row..(base + bl) * row], bl, &mut zb);
            for s in 0..bl {
                let i = base + s;
                let xi = &x[i * row..(i + 1) * row];
                let label = (y[i].rem_euclid(c as i32)) as usize;
                let z = &mut zb[s * c..(s + 1) * c];
                let (loss, _pred) = Self::softmax_loss(z, label);
                loss_sum += loss;
                for cls in 0..c {
                    let mut gz = z[cls];
                    if cls == label {
                        gz -= 1.0;
                    }
                    let gz = gz * inv_b;
                    g[c * row + cls] += gz;
                    let grow = &mut g[cls * row..(cls + 1) * row];
                    for j in 0..row {
                        grow[j] += gz * xi[j];
                    }
                }
            }
            base += bl;
        }
        loss_sum * inv_b
    }

    /// One Adam step in place (no bias correction — matches the stateless
    /// AOT `train` program, which has no step counter input).
    fn adam_step(w: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], eta: f32) {
        for i in 0..w.len() {
            m[i] = BETA1 * m[i] + (1.0 - BETA1) * g[i];
            v[i] = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
            w[i] -= eta * m[i] / (v[i].sqrt() + EPS);
        }
    }

    /// Weighted eval: `(Σ wᵢ·lossᵢ, Σ wᵢ·[predᵢ = yᵢ], Σ wᵢ)`.
    /// Dispatches on the executor's [`KernelMode`].
    fn eval(&self, w: &[f32], x: &[f32], y: &[i32], wt: &[f32]) -> (f32, f32, f32) {
        match self.mode {
            KernelMode::Blocked => self.eval_blocked(w, x, y, wt),
            KernelMode::PerSample => self.eval_per_sample(w, x, y, wt),
        }
    }

    /// Retired per-sample scalar epoch (differential oracle).
    fn eval_per_sample(&self, w: &[f32], x: &[f32], y: &[i32], wt: &[f32]) -> (f32, f32, f32) {
        let (row, c) = (self.row, self.classes);
        let mut z = vec![0.0f32; c];
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        let mut weight = 0.0f32;
        for i in 0..y.len() {
            let xi = &x[i * row..(i + 1) * row];
            let label = (y[i].rem_euclid(c as i32)) as usize;
            self.logits(w, xi, &mut z);
            let (loss, pred) = Self::softmax_loss(&mut z, label);
            loss_sum += wt[i] * loss;
            if pred == label {
                correct += wt[i];
            }
            weight += wt[i];
        }
        (loss_sum, correct, weight)
    }

    /// Blocked eval: block logits via [`Self::logits_block`], then the
    /// softmax / reductions per sample in the same ascending order as
    /// the per-sample path.
    fn eval_blocked(&self, w: &[f32], x: &[f32], y: &[i32], wt: &[f32]) -> (f32, f32, f32) {
        let (row, c) = (self.row, self.classes);
        let b = y.len();
        let mut zb = vec![0.0f32; SAMPLE_BLOCK * c];
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        let mut weight = 0.0f32;
        let mut base = 0usize;
        while base < b {
            let bl = (b - base).min(SAMPLE_BLOCK);
            self.logits_block(w, &x[base * row..(base + bl) * row], bl, &mut zb);
            for s in 0..bl {
                let i = base + s;
                let label = (y[i].rem_euclid(c as i32)) as usize;
                let z = &mut zb[s * c..(s + 1) * c];
                let (loss, pred) = Self::softmax_loss(z, label);
                loss_sum += wt[i] * loss;
                if pred == label {
                    correct += wt[i];
                }
                weight += wt[i];
            }
            base += bl;
        }
        (loss_sum, correct, weight)
    }

    /// Shared top-k mask of `|dw|` with the kernel's `|x| >= τ` keep rule.
    fn sparsify(&self, dw: &[f32], dm: &[f32], dv: &[f32], k: i32) -> Vec<Vec<f32>> {
        let k = (k.max(1) as usize).min(self.dim);
        let tau = crate::sparse::top_k_threshold(dw, k);
        let mask = |src: &[f32]| -> Vec<f32> {
            src.iter()
                .zip(dw)
                .map(|(&v, &w)| if w.abs() >= tau { v } else { 0.0 })
                .collect()
        };
        vec![mask(dw), mask(dm), mask(dv)]
    }
}

/// Sequential argument decoder for [`Executor::execute`] calls.
struct ArgStream(std::vec::IntoIter<Arg>);

impl ArgStream {
    fn new(args: Vec<Arg>) -> ArgStream {
        ArgStream(args.into_iter())
    }

    fn next(&mut self) -> Result<Arg> {
        self.0.next().ok_or_else(|| anyhow!("missing argument"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        match self.next()? {
            Arg::F32(v, _) => Ok(v),
            other => Err(anyhow!("expected f32 tensor, got {other:?}")),
        }
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        match self.next()? {
            Arg::I32(v, _) => Ok(v),
            other => Err(anyhow!("expected i32 tensor, got {other:?}")),
        }
    }

    fn sf32(&mut self) -> Result<f32> {
        match self.next()? {
            Arg::ScalarF32(x) => Ok(x),
            other => Err(anyhow!("expected f32 scalar, got {other:?}")),
        }
    }

    fn si32(&mut self) -> Result<i32> {
        match self.next()? {
            Arg::ScalarI32(x) => Ok(x),
            other => Err(anyhow!("expected i32 scalar, got {other:?}")),
        }
    }
}

impl Executor for ReferenceExecutor {
    fn execute(&mut self, prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        let mut a = ArgStream::new(args);
        match prog {
            Prog::Init => {
                let seed = a.si32()?;
                Ok(vec![self.init(seed)])
            }
            Prog::Train => {
                let (mut w, mut m, mut v) = (a.f32s()?, a.f32s()?, a.f32s()?);
                let (x, y, eta) = (a.f32s()?, a.i32s()?, a.sf32()?);
                let mut g = vec![0.0f32; self.dim];
                let loss = self.grad_batch(&w, &x, &y, &mut g);
                Self::adam_step(&mut w, &mut m, &mut v, &g, eta);
                Ok(vec![w, m, v, vec![loss]])
            }
            Prog::Epoch => {
                let (mut w, mut m, mut v) = (a.f32s()?, a.f32s()?, a.f32s()?);
                let (x, y, eta) = (a.f32s()?, a.i32s()?, a.sf32()?);
                // The epoch program is compiled for a fixed scan shape
                // [epoch_batches, batch, ...]; recover it from the meta.
                let nb = self.epoch_batches;
                if y.len() % nb != 0 {
                    return Err(anyhow!("epoch: {} labels not divisible by {nb}", y.len()));
                }
                let b = y.len() / nb;
                let per_sample = self.row;
                if x.len() != nb * b * per_sample {
                    return Err(anyhow!("epoch: ragged batch shapes"));
                }
                let mut loss_sum = 0.0f32;
                for s in 0..nb {
                    let xs = &x[s * b * per_sample..(s + 1) * b * per_sample];
                    let ys = &y[s * b..(s + 1) * b];
                    let mut g = vec![0.0f32; self.dim];
                    let loss = self.grad_batch(&w, xs, ys, &mut g);
                    Self::adam_step(&mut w, &mut m, &mut v, &g, eta);
                    loss_sum += loss;
                }
                Ok(vec![w, m, v, vec![loss_sum / nb as f32]])
            }
            Prog::Eval => {
                let w = a.f32s()?;
                let (x, y, wt) = (a.f32s()?, a.i32s()?, a.f32s()?);
                let (loss, correct, weight) = self.eval(&w, &x, &y, &wt);
                Ok(vec![vec![loss], vec![correct], vec![weight]])
            }
            Prog::Sgd => {
                let mut w = a.f32s()?;
                let (x, y, eta) = (a.f32s()?, a.i32s()?, a.sf32()?);
                let mut g = vec![0.0f32; self.dim];
                let loss = self.grad_batch(&w, &x, &y, &mut g);
                for i in 0..w.len() {
                    w[i] -= eta * g[i];
                }
                Ok(vec![w, vec![loss]])
            }
            Prog::Grads => {
                let w = a.f32s()?;
                let (x, y) = (a.f32s()?, a.i32s()?);
                let mut g = vec![0.0f32; self.dim];
                let loss = self.grad_batch(&w, &x, &y, &mut g);
                Ok(vec![g, vec![loss]])
            }
            Prog::Sparsify => {
                let (dw, dm, dv) = (a.f32s()?, a.f32s()?, a.f32s()?);
                let k = a.si32()?;
                Ok(self.sparsify(&dw, &dm, &dv, k))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        reference_meta(&[2, 2, 1], 3, 2, 4, 2) // row 4, dim 15
    }

    fn exec() -> ReferenceExecutor {
        ReferenceExecutor::new(meta()).unwrap()
    }

    #[test]
    fn init_is_deterministic() {
        let mut e1 = exec();
        let mut e2 = exec();
        let a = e1.execute(Prog::Init, vec![Arg::ScalarI32(7)]).unwrap();
        let b = e2.execute(Prog::Init, vec![Arg::ScalarI32(7)]).unwrap();
        assert_eq!(a, b);
        let c = e1.execute(Prog::Init, vec![Arg::ScalarI32(8)]).unwrap();
        assert_ne!(a, c);
        assert_eq!(a[0].len(), 15);
    }

    #[test]
    fn train_reduces_loss_on_separable_batch() {
        let mut e = exec();
        let w0 = e.execute(Prog::Init, vec![Arg::ScalarI32(1)]).unwrap().remove(0);
        // Two strongly-separated samples.
        let x = vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let y = vec![0, 1];
        let mut w = w0;
        let mut m = vec![0.0; 15];
        let mut v = vec![0.0; 15];
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 0..50 {
            let out = e
                .execute(
                    Prog::Train,
                    vec![
                        Arg::vec(w.clone()),
                        Arg::vec(m.clone()),
                        Arg::vec(v.clone()),
                        Arg::F32(x.clone(), vec![2, 2, 2, 1]),
                        Arg::I32(y.clone(), vec![2]),
                        Arg::ScalarF32(0.05),
                    ],
                )
                .unwrap();
            let loss = out[3][0];
            if step == 0 {
                first = loss;
            }
            last = loss;
            w = out[0].clone();
            m = out[1].clone();
            v = out[2].clone();
        }
        assert!(last < first, "loss should fall: {first} -> {last}");
        assert!(last.is_finite());
    }

    #[test]
    fn eval_zero_weight_lane_contributes_nothing() {
        let mut e = exec();
        let w = e.execute(Prog::Init, vec![Arg::ScalarI32(3)]).unwrap().remove(0);
        let eval = |e: &mut ReferenceExecutor, x: Vec<f32>, y: Vec<i32>, wt: Vec<f32>| {
            e.execute(
                Prog::Eval,
                vec![
                    Arg::vec(w.clone()),
                    Arg::F32(x, vec![4, 2, 2, 1]),
                    Arg::I32(y, vec![4]),
                    Arg::F32(wt, vec![4]),
                ],
            )
            .unwrap()
        };
        let base_x = vec![0.5f32; 16];
        let mut garbage_x = base_x.clone();
        for v in garbage_x[8..].iter_mut() {
            *v = 42.0; // arbitrary junk in the zero-weight lanes
        }
        let wt = vec![1.0, 1.0, 0.0, 0.0];
        let a = eval(&mut e, base_x, vec![0, 1, 0, 0], wt.clone());
        let b = eval(&mut e, garbage_x, vec![0, 1, 2, 1], wt);
        assert_eq!(a, b, "zero-weight lanes must not affect any output");
        assert_eq!(a[2], vec![2.0]);
    }

    #[test]
    fn sparsify_keeps_shared_mask_with_ties() {
        let mut e = exec();
        let dw = vec![5.0, 0.0, -3.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let dm: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let dv = vec![1.0; 15];
        let out = e
            .execute(
                Prog::Sparsify,
                vec![
                    Arg::vec(dw.clone()),
                    Arg::vec(dm),
                    Arg::vec(dv),
                    Arg::ScalarI32(2),
                ],
            )
            .unwrap();
        // τ = 3.0 ⇒ lanes {0, 2} kept in all three vectors.
        assert_eq!(out[0], {
            let mut v = vec![0.0f32; 15];
            v[0] = 5.0;
            v[2] = -3.0;
            v
        });
        assert_eq!(out[1][0], 0.0); // dm[0] gathered
        assert_eq!(out[1][2], 2.0);
        assert!(out[1][3] == 0.0 && out[2][3] == 0.0, "masked lanes zeroed");
    }

    #[test]
    fn pool_of_reference_executors_round_trips() {
        let pool = reference_pool(meta(), 3).unwrap();
        assert_eq!(pool.num_workers(), 3);
        let h = pool.handle();
        let w = h.init(9).unwrap();
        assert_eq!(w.len(), 15);
        // Same request through different workers is bitwise stable.
        let again = h.init(9).unwrap();
        assert_eq!(w, again);
    }

    #[test]
    fn dot8_is_deterministic_and_close_to_serial() {
        let mut rng = crate::rng::Rng::new(42);
        // Length 103 = 12 full 8-lane chunks + a 7-element scalar tail.
        let a: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..103).map(|_| rng.normal() as f32).collect();
        let d1 = dot8(&a, &b);
        let d2 = dot8(&a, &b);
        assert_eq!(d1.to_bits(), d2.to_bits(), "dot8 must be pure");
        let serial: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(
            (d1 - serial).abs() <= 1e-4 * (1.0 + serial.abs()),
            "dot8 {d1} strayed from serial {serial}"
        );
    }

    #[test]
    fn blocked_and_per_sample_epochs_agree_closely() {
        // row 17 exercises dot8's chunk + tail split; batch 12 spans a
        // full SAMPLE_BLOCK plus a partial trailing block.
        let meta = reference_meta(&[17], 5, 12, 12, 1);
        let blocked = ReferenceExecutor::with_mode(meta.clone(), KernelMode::Blocked).unwrap();
        let per = ReferenceExecutor::with_mode(meta, KernelMode::PerSample).unwrap();
        let dim = blocked.dim;
        let mut rng = crate::rng::Rng::new(7);
        let w: Vec<f32> = (0..dim).map(|_| (rng.normal() * 0.1) as f32).collect();
        let x: Vec<f32> = (0..12 * 17).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..12i32).map(|i| i % 5).collect();

        let mut gb = vec![0.0f32; dim];
        let mut gp = vec![0.0f32; dim];
        let lb = blocked.grad_batch(&w, &x, &y, &mut gb);
        let lp = per.grad_batch(&w, &x, &y, &mut gp);
        assert!((lb - lp).abs() <= 1e-4 * (1.0 + lp.abs()), "loss: {lb} vs {lp}");
        for (a, b) in gb.iter().zip(&gp) {
            assert!((a - b).abs() <= 1e-4, "grad lane diverged: {a} vs {b}");
        }

        let wt = vec![1.0f32; 12];
        let (el_b, ec_b, ew_b) = blocked.eval(&w, &x, &y, &wt);
        let (el_p, ec_p, ew_p) = per.eval(&w, &x, &y, &wt);
        assert!((el_b - el_p).abs() <= 1e-3 * (1.0 + el_p.abs()));
        // A logit near-tie may flip one argmax between epochs; more than
        // one flip on random data means the kernels disagree for real.
        assert!((ec_b - ec_p).abs() <= 1.0, "correct: {ec_b} vs {ec_p}");
        assert_eq!(ew_b, ew_p);

        // Each epoch is itself bitwise reproducible call-to-call.
        let mut gb2 = vec![0.0f32; dim];
        let lb2 = blocked.grad_batch(&w, &x, &y, &mut gb2);
        assert_eq!(lb.to_bits(), lb2.to_bits());
        assert_eq!(gb, gb2);
    }
}
