//! §IV communication-model bench + verification table.
//!
//! Prints the paper's uplink cost for every scheme across models and α,
//! verifying the headline `O(3dq) → O(3kq+3d) → O(3kq+d)` reduction,
//! prints the **canonical eleven-id formula table** (asserted to cover
//! exactly [`fedadam_ssm::algorithms::CONFORMANCE_ZOO`] — the same table
//! as `rust/src/algorithms/mod.rs`, README and `docs/ARCHITECTURE.md`),
//! and times the real wire codecs (encode+decode round trips).
//!
//! Run: `cargo bench --bench comm_cost`.

use fedadam_ssm::algorithms::CONFORMANCE_ZOO;
use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::codec::{self, cost};
use fedadam_ssm::sparse::{top_k_indices, SparseVec};

/// The canonical per-device/round uplink formula per algorithm id, at a
/// reference point — one row per conformance-zoo id (`q = 32`,
/// `b = ceil(log₂ s)`; `onebit-adam` priced post-warmup).
fn zoo_cost_table(d: usize, k: usize, s: usize) -> Vec<(&'static str, &'static str, u64)> {
    vec![
        ("fedadam", "3dq", cost::fedadam_dense(d)),
        (
            "fedadam-top",
            "min{3(kq+d), 3k(q+log2 d)}",
            cost::fedadam_top(d, k),
        ),
        (
            "fedadam-ssm",
            "min{3kq+d, k(3q+log2 d)}",
            cost::fedadam_ssm(d, k),
        ),
        (
            "fedadam-ssm-m",
            "min{3kq+d, k(3q+log2 d)}",
            cost::fedadam_ssm(d, k),
        ),
        (
            "fedadam-ssm-v",
            "min{3kq+d, k(3q+log2 d)}",
            cost::fedadam_ssm(d, k),
        ),
        (
            "fairness-top",
            "min{3kq+d, k(3q+log2 d)}",
            cost::fedadam_ssm(d, k),
        ),
        (
            "fedadam-ssm-q",
            "min{3kb+d, k(3b+log2 d)} + 3q",
            cost::fedadam_ssm_q(d, k, s),
        ),
        (
            "fedadam-ssm-qef",
            "min{3kb+d, k(3b+log2 d)} + 3q",
            cost::fedadam_ssm_q(d, k, s),
        ),
        ("onebit-adam", "warmup 3dq, then d + q", cost::onebit(d)),
        ("efficient-adam", "d*ceil(log2 s) + q", cost::uniform(d, s)),
        ("fedsgd", "dq", cost::fedsgd_dense(d)),
    ]
}

fn main() {
    // --- canonical eleven-id table (doc-drift guard) ---------------------
    // The id set is asserted against algorithms::CONFORMANCE_ZOO so this
    // bench, the module-doc table in rust/src/algorithms/mod.rs, README
    // and docs/ARCHITECTURE.md can never silently diverge on WHICH ids
    // exist; the conformance suite pins each id's ledger to these exact
    // functions.
    let (d_ref, s_ref) = (176_778usize, 16usize);
    let k_ref = (d_ref as f64 * 0.05) as usize;
    let table = zoo_cost_table(d_ref, k_ref, s_ref);
    let mut ids: Vec<&str> = table.iter().map(|(id, _, _)| *id).collect();
    let mut zoo: Vec<&str> = CONFORMANCE_ZOO.to_vec();
    ids.sort_unstable();
    zoo.sort_unstable();
    assert_eq!(ids, zoo, "cost table must cover exactly the conformance zoo");
    println!("=== uplink per device/round: the eleven-id zoo (d = {d_ref}, alpha = 0.05, s = {s_ref}, q = 32) ===");
    println!("{:<17} {:>14}   formula", "id", "bits");
    for (id, formula, bits) in &table {
        println!("{id:<17} {bits:>14}   {formula}");
    }
    println!();
    // --- cost table (exact, no timing) ----------------------------------
    println!("=== §IV uplink bits per device/round (q = 32) ===");
    println!(
        "{:>10} {:>7} {:>14} {:>14} {:>14} {:>14} {:>12} {:>14}",
        "d", "alpha", "FedAdam", "FedAdam-Top", "FedAdam-SSM", "SSM-Q(16)", "1-bit", "Efficient(16)"
    );
    for &d in &[54_314usize, 176_778, 1_663_370, 9_750_922] {
        for &alpha in &[0.01f64, 0.05, 0.2] {
            let k = (d as f64 * alpha) as usize;
            println!(
                "{:>10} {:>7} {:>14} {:>14} {:>14} {:>14} {:>12} {:>14}",
                d,
                alpha,
                cost::fedadam_dense(d),
                cost::fedadam_top(d, k),
                cost::fedadam_ssm(d, k),
                cost::fedadam_ssm_q(d, k, 16),
                cost::onebit(d),
                cost::uniform(d, 16),
            );
            assert!(cost::fedadam_ssm_q(d, k, 16) < cost::fedadam_ssm(d, k));
            assert!(cost::fedadam_ssm(d, k) < cost::fedadam_top(d, k));
            assert!(cost::fedadam_top(d, k) < cost::fedadam_dense(d));
        }
    }
    println!("(SSM-Q < SSM < Top < dense verified at every point)");

    // --- codec timing ----------------------------------------------------
    let mut bench = from_env();
    let mut rng = Rng::new(1);
    let d = 176_778;
    for &alpha in &[0.01f64, 0.05, 0.5] {
        let k = (d as f64 * alpha) as usize;
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let idx = top_k_indices(&x, k);
        let sv = SparseVec::gather(&x, &idx);
        bench.run(format!("encode d={d} alpha={alpha}"), || {
            black_box(codec::encode(&sv));
        });
        let es = codec::encode(&sv);
        bench.run(format!("decode d={d} alpha={alpha} ({:?})", es.encoding), || {
            black_box(codec::decode(&es));
        });
    }
    bench.report("wire codec");
    println!("\n{}", bench.to_csv());
}
