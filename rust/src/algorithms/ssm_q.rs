//! FedAdam-SSM-Q / -QEF — the quantized shared-sparse-mask composition.
//!
//! The paper's Fig. 2 claims FedAdam-SSM beats *quantized* FedAdam
//! baselines by over 14.5% accuracy at matched uplink budgets, but the zoo
//! priced sparsification and quantization as disjoint families.  These two
//! ids compose them: the SSM mask (top-k of `|ΔW|`, eq. 28) picks the
//! lanes, and each of the three kept-value lists is s-level
//! uniform-quantized against its own max-magnitude scale
//! ([`crate::quant::sparse_uniform`]), tracing the accuracy/bit frontier
//! between the two isolated points.
//!
//! Uplink: `min{3k·ceil(log₂ s) + d, k(3·ceil(log₂ s) + log₂ d)} + 3q`
//! (one mask, three packed code lists, three f32 scales).  Every upload is
//! pushed through the real wire format — encode, bit-pack, decode — so the
//! server aggregates exactly what the bits carry; the priced ledger cost
//! is `debug_assert`ed against the encoded message size.
//!
//! `fedadam-ssm-qef` adds per-device error feedback on the **pre-mask
//! residual** (mirroring `ssm_ef.rs`): what the mask drops *and* what the
//! quantizer rounds away accumulates in a per-device memory and is added
//! back to the next round's deltas before mask selection — the
//! FedAMS-style compensation Wang et al. argue compressed FedAdam needs
//! for convergence.  Same wire cost as the plain variant.

use anyhow::Result;

use super::residual_store::ResidualStore;
use super::wire::{WireBody, WireUpload, KIND_SSM_Q};
use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::quant::sparse_uniform::ssm_q_encode_fused;
use crate::sparse::codec::cost;
use crate::sparse::{top_k_indices, SparseVec};
use crate::util::bytes::{ByteReader, ByteWriter};

/// Gather `src[indices]` as a plain value list (mask handled separately).
#[cfg(debug_assertions)]
fn gather_vals(src: &[f32], indices: &[u32]) -> Vec<f32> {
    indices.iter().map(|&i| src[i as usize]).collect()
}

/// Compress one dense `(ΔW, ΔM, ΔV)` triple under a shared mask through
/// the **fused** quantized wire encoder — one pass over the `k` kept
/// lanes writes the packed contiguous wire body and yields the exact
/// dequantized reconstructions (the transport path ships the former; the
/// in-process aggregation path consumes the latter).  Debug builds
/// re-run the staged `gather → ssm_q_encode → repack` oracle and assert
/// byte identity.
fn compress_triple(
    dim: usize,
    idx: &[u32],
    dw: &[f32],
    dm: &[f32],
    dv: &[f32],
    s_levels: u32,
) -> (WireBody, SparseVec, SparseVec, SparseVec, u64) {
    let fused = ssm_q_encode_fused(dim, idx, dw, dm, dv, s_levels);
    debug_assert_eq!(fused.bits, cost::fedadam_ssm_q(dim, idx.len(), s_levels as usize));
    #[cfg(debug_assertions)]
    {
        use crate::quant::sparse_uniform::{ssm_q_decode, ssm_q_encode};
        let staged = ssm_q_encode(
            dim,
            idx,
            &gather_vals(dw, idx),
            &gather_vals(dm, idx),
            &gather_vals(dv, idx),
            s_levels,
        );
        debug_assert_eq!(staged.wire_bits(), fused.bits);
        let (sw, sm, sv) = ssm_q_decode(&staged);
        debug_assert_eq!(sw.values, fused.w, "fused dequantization diverged from staged");
        debug_assert_eq!(sm.values, fused.m);
        debug_assert_eq!(sv.values, fused.v);
        debug_assert_eq!(
            WireBody::SsmQ(staged).encode(),
            fused.bytes,
            "fused SSM-Q encode is not byte-identical to the staged path"
        );
    }
    let bits = fused.bits;
    let body = WireBody::Packed {
        kind: KIND_SSM_Q,
        dim,
        k: idx.len(),
        levels: s_levels - 1,
        bytes: fused.bytes,
    };
    let mk = |values: Vec<f32>| SparseVec {
        dim,
        indices: idx.to_vec(),
        values,
    };
    (body, mk(fused.w), mk(fused.m), mk(fused.v), bits)
}

pub struct FedAdamSsmQ {
    dim: usize,
    k: usize,
    levels: u32,
}

impl FedAdamSsmQ {
    pub fn new(dim: usize, k: usize, levels: u32) -> Self {
        assert!(k >= 1 && k <= dim);
        assert!(levels >= 2, "need at least 2 quantization levels");
        FedAdamSsmQ { dim, k, levels }
    }

    /// Shared core of [`Algorithm::compress`] and
    /// [`Algorithm::compress_wire`] — one fused encode, both views.
    fn compress_inner(&mut self, delta: &LocalDelta) -> (WireBody, Upload) {
        let idx = top_k_indices(&delta.dw, self.k);
        let (body, sw, sm, sv, bits) =
            compress_triple(self.dim, &idx, &delta.dw, &delta.dm, &delta.dv, self.levels);
        let up = Upload {
            dw: Recon::Sparse(sw),
            dm: Some(Recon::Sparse(sm)),
            dv: Some(Recon::Sparse(sv)),
            weight: delta.weight,
            bits,
        };
        (body, up)
    }
}

impl Algorithm for FedAdamSsmQ {
    fn name(&self) -> &'static str {
        "fedadam-ssm-q"
    }

    fn compress(&mut self, _round: usize, _device: usize, delta: LocalDelta) -> Upload {
        self.compress_inner(&delta).1
    }

    fn compress_wire(
        &mut self,
        _round: usize,
        _device: usize,
        delta: LocalDelta,
    ) -> Result<WireUpload> {
        let (body, up) = self.compress_inner(&delta);
        Ok(WireUpload {
            body,
            weight: up.weight,
            bits: up.bits,
        })
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        // The broadcast carries the f32 FedAvg aggregate over the union
        // support (quantizing the *aggregate* is a different trade the
        // paper's downlink model does not take), so it prices like the
        // plain SSM on the union size carried through `Aggregate` (see
        // ssm.rs: a non-zero recount undercounts on exact cancellation).
        cost::fedadam_ssm(self.dim, agg.dw_support)
    }
}

pub struct FedAdamSsmQEf {
    dim: usize,
    k: usize,
    levels: u32,
    /// Per-device `[w | m | v]` pre-mask residual entries, materialized on
    /// first touch and spilled past `resident_cap` (see [`ResidualStore`]).
    memory: ResidualStore,
}

impl FedAdamSsmQEf {
    pub fn new(dim: usize, k: usize, levels: u32, resident_cap: usize, spill_dir: &str) -> Self {
        assert!(k >= 1 && k <= dim);
        assert!(levels >= 2, "need at least 2 quantization levels");
        FedAdamSsmQEf {
            dim,
            k,
            levels,
            memory: ResidualStore::new(3 * dim, resident_cap, spill_dir),
        }
    }

    /// Shared core of [`Algorithm::compress`] and
    /// [`Algorithm::compress_wire`] — the per-device EF memory mutates
    /// exactly once per call regardless of which view the caller takes.
    fn compress_inner(&mut self, device: usize, delta: &LocalDelta) -> (WireBody, Upload) {
        let dim = self.dim;
        let entry = self.memory.get_mut(device as u64);
        let (mem_w, rest) = entry.split_at_mut(dim);
        let (mem_m, mem_v) = rest.split_at_mut(dim);
        // Compensate: c = delta + residual (pre-mask, all d lanes).
        let cw: Vec<f32> = delta.dw.iter().zip(mem_w.iter()).map(|(a, b)| a + b).collect();
        let cm: Vec<f32> = delta.dm.iter().zip(mem_m.iter()).map(|(a, b)| a + b).collect();
        let cv: Vec<f32> = delta.dv.iter().zip(mem_v.iter()).map(|(a, b)| a + b).collect();
        // SSM from the compensated ΔW (eq. 28 on c_w), then quantize.
        let idx = top_k_indices(&cw, self.k);
        let (body, sw, sm, sv, bits) = compress_triple(dim, &idx, &cw, &cm, &cv, self.levels);
        // Residual = compensated − transmitted: subtracting the
        // *dequantized* kept values folds the quantization error into the
        // memory alongside the masked-out mass.
        mem_w.copy_from_slice(&cw);
        mem_m.copy_from_slice(&cm);
        mem_v.copy_from_slice(&cv);
        for (&i, (&vw, (&vm, &vv))) in idx
            .iter()
            .zip(sw.values.iter().zip(sm.values.iter().zip(sv.values.iter())))
        {
            mem_w[i as usize] -= vw;
            mem_m[i as usize] -= vm;
            mem_v[i as usize] -= vv;
        }
        let up = Upload {
            dw: Recon::Sparse(sw),
            dm: Some(Recon::Sparse(sm)),
            dv: Some(Recon::Sparse(sv)),
            weight: delta.weight,
            bits,
        };
        (body, up)
    }
}

impl Algorithm for FedAdamSsmQEf {
    fn name(&self) -> &'static str {
        "fedadam-ssm-qef"
    }

    fn compress(&mut self, _round: usize, device: usize, delta: LocalDelta) -> Upload {
        self.compress_inner(device, &delta).1
    }

    fn compress_wire(
        &mut self,
        _round: usize,
        device: usize,
        delta: LocalDelta,
    ) -> Result<WireUpload> {
        let (body, up) = self.compress_inner(device, &delta);
        Ok(WireUpload {
            body,
            weight: up.weight,
            bits: up.bits,
        })
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        cost::fedadam_ssm(self.dim, agg.dw_support)
    }

    fn save_state(&self, out: &mut ByteWriter) {
        self.memory.save_state(out);
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        self.memory.load_state(input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sparse_uniform::sparse_uniform_compress;

    fn delta(dw: Vec<f32>) -> LocalDelta {
        let d = dw.len();
        LocalDelta {
            dw,
            dm: vec![0.1; d],
            dv: vec![0.01; d],
            weight: 1.0,
        }
    }

    #[test]
    fn mask_shared_and_support_is_k_despite_quantization() {
        let mut a = FedAdamSsmQ::new(10, 3, 16);
        let up = a.compress(0, 0, delta((0..10).map(|i| i as f32).collect()));
        let idx = |r: &Recon| match r {
            Recon::Sparse(sv) => sv.indices.clone(),
            _ => panic!("expected sparse"),
        };
        assert_eq!(idx(&up.dw), vec![7, 8, 9]);
        assert_eq!(idx(up.dm.as_ref().unwrap()), vec![7, 8, 9]);
        assert_eq!(idx(up.dv.as_ref().unwrap()), vec![7, 8, 9]);
    }

    #[test]
    fn uplink_cost_is_quantized_ssm_formula() {
        for &s in &[2u32, 3, 16] {
            let mut a = FedAdamSsmQ::new(100_000, 5_000, s);
            let up = a.compress(0, 0, delta(vec![1.0; 100_000]));
            assert_eq!(up.bits, cost::fedadam_ssm_q(100_000, 5_000, s as usize));
            assert!(up.bits < cost::fedadam_ssm(100_000, 5_000), "s={s}");
        }
    }

    #[test]
    fn values_land_on_the_quantizer_grid() {
        let mut a = FedAdamSsmQ::new(8, 4, 4);
        let dw = vec![3.0f32, -1.0, 2.0, 0.5, 0.0, 0.0, 0.0, -2.5];
        let up = a.compress(0, 0, delta(dw.clone()));
        let (sv, vals) = match &up.dw {
            Recon::Sparse(sv) => (sv, sv.values.clone()),
            _ => panic!(),
        };
        // Kept lanes: |3.0|, |-2.5|, |2.0|, |-1.0| -> indices {0, 1, 2, 7}.
        assert_eq!(sv.indices, vec![0, 1, 2, 7]);
        let expect = sparse_uniform_compress(&[3.0, -1.0, 2.0, -2.5], 4);
        let grid = crate::quant::sparse_uniform::sparse_uniform_decompress(&expect);
        assert_eq!(vals, grid);
        // s = 4 over scale 3.0: ideal levels {-3, -1, 1, 3}.  The interior
        // levels are only approximately representable in f32 ((1/3)·2 − 1
        // is not exactly -1/3), so compare with a tolerance — the exact
        // contract is the bit-equality against the quantizer output above.
        for v in &vals {
            assert!(
                [-3.0f32, -1.0, 1.0, 3.0].iter().any(|g| (v - g).abs() < 1e-5),
                "{v} off grid"
            );
        }
    }

    /// `device`'s residual `w` slice — zeros if never touched.
    fn mem_w(a: &FedAdamSsmQEf, device: u64) -> Vec<f32> {
        a.memory
            .peek(device)
            .map(|e| e[..a.dim].to_vec())
            .unwrap_or_else(|| vec![0.0; a.dim])
    }

    #[test]
    fn ef_residual_carries_mask_and_quantization_error() {
        let mut a = FedAdamSsmQEf::new(4, 1, 2, 0, "");
        // Round 0: dw = [4, 3, 0, 0], s = 2 -> grid {-4, 4}; keep lane 0,
        // transmit exactly 4.0 -> residual w = [0, 3, 0, 0].
        let up0 = a.compress(0, 0, delta(vec![4.0, 3.0, 0.0, 0.0]));
        match &up0.dw {
            Recon::Sparse(sv) => {
                assert_eq!(sv.indices, vec![0]);
                assert_eq!(sv.values, vec![4.0]);
            }
            _ => panic!(),
        }
        assert_eq!(mem_w(&a, 0), vec![0.0, 3.0, 0.0, 0.0]);
        // Round 1: delta [2, 2, 0, 0]; compensated [2, 5, 0, 0] -> keep
        // lane 1, transmit 5.0; residual releases lane 1, keeps lane 0.
        let up1 = a.compress(1, 0, delta(vec![2.0, 2.0, 0.0, 0.0]));
        match &up1.dw {
            Recon::Sparse(sv) => {
                assert_eq!(sv.indices, vec![1]);
                assert_eq!(sv.values, vec![5.0]);
            }
            _ => panic!(),
        }
        assert_eq!(mem_w(&a, 0), vec![2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn ef_quantization_error_feeds_back_on_kept_lanes() {
        // k = 2, s = 2: scale = 4, grid {-4, 4}.  Lane 0 transmits 4.0
        // exactly; lane 1's 3.0 rounds up to 4.0, so its residual must be
        // the rounding error −1.0 — a KEPT lane with non-zero memory, which
        // the un-quantized ssm_ef can never produce.
        let mut a = FedAdamSsmQEf::new(4, 2, 2, 0, "");
        a.compress(0, 0, delta(vec![4.0, 3.0, 0.0, 0.0]));
        assert_eq!(mem_w(&a, 0)[0], 0.0);
        assert_eq!(mem_w(&a, 0)[1], -1.0, "quantization error must accumulate");
    }

    #[test]
    fn ef_memories_are_per_device() {
        let mut a = FedAdamSsmQEf::new(3, 1, 16, 0, "");
        a.compress(0, 0, delta(vec![1.0, 2.0, 3.0]));
        assert!(mem_w(&a, 0).iter().any(|&x| x != 0.0));
        assert_eq!(mem_w(&a, 1), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn ef_same_wire_cost_as_plain_variant() {
        let mut q = FedAdamSsmQ::new(1000, 50, 16);
        let mut qef = FedAdamSsmQEf::new(1000, 50, 16, 0, "");
        let b1 = q.compress(0, 0, delta(vec![1.0; 1000])).bits;
        let b2 = qef.compress(0, 0, delta(vec![1.0; 1000])).bits;
        assert_eq!(b1, b2);
        assert_eq!(b1, cost::fedadam_ssm_q(1000, 50, 16));
    }

    #[test]
    #[should_panic]
    fn one_level_rejected() {
        FedAdamSsmQ::new(10, 2, 1);
    }
}
