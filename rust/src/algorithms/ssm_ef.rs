//! FedAdam-SSM-EF — extension: the SSM sparsifier with per-device
//! error-feedback memory (sparsified-SGD-with-memory, the paper's ref [31],
//! applied to the FedAdam-SSM triple).
//!
//! Coordinates dropped by the mask are not lost: their mass accumulates in
//! a per-device residual and is added back to the *next* round's deltas
//! before mask selection.  This is the natural "future work" composition of
//! the paper's SSM with the memory mechanism its related-work section
//! credits for sparse-SGD convergence; the ablation bench
//! (`examples/ablation_ef.rs`) measures what it buys on top of eq. 28.
//!
//! The residuals live in a [`ResidualStore`] (one `[w | m | v]` entry of
//! `3 × dim` floats per *touched* device), so a million-device fleet costs
//! O(cohort) RAM and O(touched) snapshot bytes, not O(fleet) — see the
//! store's exact-rehydration contract.
//!
//! Wire cost is identical to FedAdam-SSM: `min{3kq + d, k(3q + log₂ d)}`.

use anyhow::Result;

use super::residual_store::ResidualStore;
use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::sparse::codec::cost;
use crate::sparse::{top_k_indices, SparseVec};
use crate::util::bytes::{ByteReader, ByteWriter};

pub struct FedAdamSsmEf {
    dim: usize,
    k: usize,
    /// Per-device `[w | m | v]` residual entries, materialized on first
    /// touch and spilled past `resident_cap` (see [`ResidualStore`]).
    memory: ResidualStore,
}

impl FedAdamSsmEf {
    pub fn new(dim: usize, k: usize, resident_cap: usize, spill_dir: &str) -> Self {
        assert!(k >= 1 && k <= dim);
        FedAdamSsmEf {
            dim,
            k,
            memory: ResidualStore::new(3 * dim, resident_cap, spill_dir),
        }
    }
}

impl Algorithm for FedAdamSsmEf {
    fn name(&self) -> &'static str {
        "fedadam-ssm-ef"
    }

    fn compress(&mut self, _round: usize, device: usize, delta: LocalDelta) -> Upload {
        let dim = self.dim;
        let entry = self.memory.get_mut(device as u64);
        let (mem_w, rest) = entry.split_at_mut(dim);
        let (mem_m, mem_v) = rest.split_at_mut(dim);
        // Compensate: c = delta + residual.
        let cw: Vec<f32> = delta.dw.iter().zip(mem_w.iter()).map(|(a, b)| a + b).collect();
        let cm: Vec<f32> = delta.dm.iter().zip(mem_m.iter()).map(|(a, b)| a + b).collect();
        let cv: Vec<f32> = delta.dv.iter().zip(mem_v.iter()).map(|(a, b)| a + b).collect();
        // SSM from the compensated ΔW (eq. 28 on c_w).
        let idx = top_k_indices(&cw, self.k);
        let sw = SparseVec::gather(&cw, &idx);
        let sm = SparseVec::gather(&cm, &idx);
        let sv = SparseVec::gather(&cv, &idx);
        // Residual = compensated − transmitted.
        mem_w.copy_from_slice(&cw);
        mem_m.copy_from_slice(&cm);
        mem_v.copy_from_slice(&cv);
        for (&i, (&vw, (&vm, &vv))) in idx
            .iter()
            .zip(sw.values.iter().zip(sm.values.iter().zip(sv.values.iter())))
        {
            mem_w[i as usize] -= vw;
            mem_m[i as usize] -= vm;
            mem_v[i as usize] -= vv;
        }
        Upload {
            dw: Recon::Sparse(sw),
            dm: Some(Recon::Sparse(sm)),
            dv: Some(Recon::Sparse(sv)),
            weight: delta.weight,
            bits: cost::fedadam_ssm(self.dim, self.k),
        }
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        // Union support carried through `Aggregate` (see ssm.rs: a recount
        // of non-zeros undercounts on exact-zero cancellation).
        cost::fedadam_ssm(self.dim, agg.dw_support)
    }

    fn save_state(&self, out: &mut ByteWriter) {
        self.memory.save_state(out);
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        self.memory.load_state(input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(dw: Vec<f32>) -> LocalDelta {
        let d = dw.len();
        LocalDelta {
            dw,
            dm: vec![0.1; d],
            dv: vec![0.01; d],
            weight: 1.0,
        }
    }

    /// `device`'s residual `(w, m, v)` — zeros if never touched.
    fn mem(a: &FedAdamSsmEf, device: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let entry = a
            .memory
            .peek(device)
            .unwrap_or_else(|| vec![0.0; 3 * a.dim]);
        let (w, rest) = entry.split_at(a.dim);
        let (m, v) = rest.split_at(a.dim);
        (w.to_vec(), m.to_vec(), v.to_vec())
    }

    #[test]
    fn residual_accumulates_and_releases() {
        let mut a = FedAdamSsmEf::new(4, 1, 0, "");
        // Round 0: [4, 3, 0, 0] -> keep idx 0; residual w = [0, 3, 0, 0].
        let up0 = a.compress(0, 0, delta(vec![4.0, 3.0, 0.0, 0.0]));
        match &up0.dw {
            Recon::Sparse(sv) => {
                assert_eq!(sv.indices, vec![0]);
                assert_eq!(sv.values, vec![4.0]);
            }
            _ => panic!(),
        }
        assert_eq!(mem(&a, 0).0, vec![0.0, 3.0, 0.0, 0.0]);
        // Round 1: delta [2, 2, 0, 0]; compensated = [2, 5, 0, 0] -> keep 1.
        let up1 = a.compress(1, 0, delta(vec![2.0, 2.0, 0.0, 0.0]));
        match &up1.dw {
            Recon::Sparse(sv) => {
                assert_eq!(sv.indices, vec![1]);
                assert_eq!(sv.values, vec![5.0]);
            }
            _ => panic!(),
        }
        assert_eq!(mem(&a, 0).0, vec![2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn memories_are_per_device() {
        let mut a = FedAdamSsmEf::new(3, 1, 0, "");
        a.compress(0, 0, delta(vec![1.0, 2.0, 3.0]));
        assert_eq!(mem(&a, 0).0, vec![1.0, 2.0, 0.0]);
        assert_eq!(mem(&a, 1).0, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn same_wire_cost_as_plain_ssm() {
        let mut a = FedAdamSsmEf::new(1000, 50, 0, "");
        let up = a.compress(0, 0, delta(vec![1.0; 1000]));
        assert_eq!(up.bits, cost::fedadam_ssm(1000, 50));
    }

    #[test]
    fn moment_residuals_tracked_too() {
        let mut a = FedAdamSsmEf::new(2, 1, 0, "");
        a.compress(0, 0, delta(vec![5.0, 1.0]));
        // dm = [0.1, 0.1]; kept lane 0 -> residual m = [0, 0.1].
        let m = mem(&a, 0).1;
        assert!((m[0]).abs() < 1e-6);
        assert!((m[1] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn capped_store_matches_unbounded_bit_for_bit() {
        let dir = std::env::temp_dir().join(format!("fedadam-ssmef-cap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut dense = FedAdamSsmEf::new(4, 2, 0, "");
        let mut capped = FedAdamSsmEf::new(4, 2, 1, dir.to_str().unwrap());
        for round in 0..4 {
            for device in [0usize, 3, 1] {
                let d = delta(vec![
                    round as f32 + 0.5,
                    -(device as f32),
                    0.25 * round as f32,
                    1.0,
                ]);
                let a = dense.compress(round, device, d.clone());
                let b = capped.compress(round, device, d);
                assert_eq!(a.bits, b.bits);
                match (&a.dw, &b.dw) {
                    (Recon::Sparse(x), Recon::Sparse(y)) => {
                        assert_eq!(x.indices, y.indices, "round {round} device {device}");
                        let xb: Vec<u32> = x.values.iter().map(|v| v.to_bits()).collect();
                        let yb: Vec<u32> = y.values.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(xb, yb, "round {round} device {device}");
                    }
                    _ => panic!(),
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
