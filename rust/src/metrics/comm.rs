//! Communication ledger: exact bit accounting per direction per round.

/// Running totals for one experiment.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommLedger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
}

impl CommLedger {
    /// Record one device's upload.
    pub fn up(&mut self, bits: u64) {
        self.uplink_bits += bits;
    }

    /// Record a broadcast to `devices` receivers.
    pub fn down(&mut self, bits_per_device: u64, devices: usize) {
        self.downlink_bits += bits_per_device * devices as u64;
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }

    pub fn uplink_mbit(&self) -> f64 {
        self.uplink_bits as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut l = CommLedger::default();
        l.up(100);
        l.up(50);
        l.down(10, 4);
        assert_eq!(l.uplink_bits, 150);
        assert_eq!(l.downlink_bits, 40);
        assert_eq!(l.total_bits(), 190);
        assert!((l.uplink_mbit() - 150e-6).abs() < 1e-15);
    }
}
