//! `fedadam-ssm` — the leader binary.
//!
//! Commands:
//! - `run`      one experiment (`--config exp.toml`, `--set key=value`…)
//! - `compare`  several algorithms on one workload (Fig.-2-style sweep)
//! - `models`   list AOT models in the manifest
//! - `info`     print resolved config and exit
//!
//! Example:
//! ```text
//! fedadam-ssm run --artifacts artifacts --set model=cnn_small \
//!     --set algorithm=fedadam-ssm --set rounds=30 --out results/
//! ```

use std::io::Write as _;

use anyhow::{bail, Result};

use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::runtime::Manifest;

/// Minimal stderr logger (offline build: no tracing-subscriber).
struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

static LOGGER: StderrLogger = StderrLogger;

fn init_logging(verbose: bool) {
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(if verbose {
        log::LevelFilter::Debug
    } else {
        log::LevelFilter::Info
    });
}

const USAGE: &str = "\
fedadam-ssm — communication-efficient federated Adam (FedAdam-SSM)

USAGE:
    fedadam-ssm <COMMAND> [OPTIONS]

COMMANDS:
    run       run one experiment
    compare   run several algorithms on the same workload
    models    list models available in the artifacts manifest
    info      print the resolved configuration

OPTIONS:
    --artifacts <dir>     AOT artifacts directory [default: artifacts]
    --config <file>       TOML experiment config
    --set key=value       override one config key (repeatable), e.g.
                          --set num_workers=4 (engine-pool threads; 0 = auto)
                          --set agg_shards=4 (server-reduce lane shards;
                          0 = one per pool worker)
                          --set pipeline_depth=2 (round-loop pipelining:
                          0 = barrier, 1 = streaming aggregation, >= 2 =
                          plus train/eval overlap).  Results are
                          bit-identical at any worker/shard/depth.
                          --set algorithm=fedadam-ssm-q --set quant_levels=4
                          (quantized shared-sparse-mask uplink: s-level
                          codes on the k kept lanes; -qef adds per-device
                          error feedback.  quant_levels must be >= 2 for
                          fedadam-ssm-q / fedadam-ssm-qef / efficient-adam)
                          --set participation_mode=importance (cohort
                          sampler: uniform = legacy bit-identical default,
                          importance = draws ~ local data size with
                          unbiased 1/(m*p_i) re-weighting, availability =
                          duty-cycle traces + over-selection; see also
                          duty_cycle / over_select)
                          --set simtime=true (simulated wall-clock column
                          sim_secs: per-device compute latency with a
                          sim_hetero straggler spread, uplink latency =
                          wire bits / sim_bandwidth_mbps; virtual time,
                          byte-identical at any worker count)
                          --set journal=results/j1 (event-journal the run:
                          append every round-loop transition to
                          journal.log and snapshot full state every
                          snapshot_every rounds; pure observation — the
                          run's bits are identical with journaling off)
                          --set resume=results/j1 (resume an interrupted
                          journaled run: restores the newest snapshot and
                          replays the log tail byte-exactly, then keeps
                          going — final model and CSV are bit-identical
                          to the uninterrupted run.  The journal must
                          come from the same config fingerprint)
                          --set snapshot_every=8 (snapshot cadence in
                          rounds; must be >= 1)
                          --set transport_listen=127.0.0.1:7070 (serve the
                          round loop over a socket: the coordinator binds
                          here — `host:port` TCP or `unix:/path` — and
                          waits for transport_agents `device-agent`
                          processes to register; devices train in the
                          agents, uplinks arrive as CRC-framed wire
                          messages, and the result is bit-identical to
                          the in-process run.  Port 0 picks a free port
                          (printed at startup).  Incompatible with
                          journal/resume)
                          --set transport_agents=2 (device-agent process
                          count; device d is owned by agent d mod N)
                          --set transport_timeout_secs=30 (per-connection
                          silence budget in seconds; agents reconnect
                          within it, and a round gives up after ~3x)
                          --set residual_resident_cap=1024 (max per-device
                          residual/moment entries held in RAM per store;
                          0 = unbounded (default).  Past the cap the
                          least-recently-used entry spills to disk and
                          rehydrates bit-identically on the next touch —
                          placement only, the run's bits never change.
                          At 10^5-10^6 registered devices set this to a
                          few x the cohort size so RAM stays O(cohort))
                          --set residual_spill_dir=/tmp/spill (where
                          evicted entries go; required when the cap is
                          nonzero — validate rejects a capped store
                          with nowhere to spill)
                          --set agent_state_dir=/tmp/astate (transport
                          agents only: each agent journals its per-device
                          compressor state to DIR/agent_<i>.state before
                          sending uplinks, so a killed agent process
                          restarted from nothing resumes bit-identically;
                          empty (default) = agents are in-memory only)
    --out <dir>           write per-round CSV logs here
    --algorithms a,b,c    (compare) comma-separated algorithm ids
    --verbose             debug logging
";

fn main() {
    let cli = match Cli::parse(std::env::args().skip(1)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if cli.flag("help") || cli.command.is_empty() {
        println!("{USAGE}");
        return;
    }
    init_logging(cli.flag("verbose"));
    if let Err(e) = dispatch(&cli) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(cli: &Cli) -> Result<ExperimentConfig> {
    let mut cfg = match cli.opt("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    for (k, v) in &cli.sets {
        cfg.set(k, v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn dispatch(cli: &Cli) -> Result<()> {
    let artifacts = cli.opt_or("artifacts", "artifacts");
    match cli.command.as_str() {
        "run" => {
            let cfg = load_config(cli)?;
            let name = cfg.name.clone();
            let mut coord = Coordinator::new(cfg, artifacts)?;
            let log_out = coord.run()?;
            println!("{}", log_out.summary());
            if let Some(out) = cli.opt("out") {
                std::fs::create_dir_all(out)?;
                let path = format!("{out}/{}_{}.csv", name, log_out.algorithm);
                log_out.write_csv(&path)?;
                println!("wrote {path}");
            }
            Ok(())
        }
        "compare" => {
            let base = load_config(cli)?;
            let algos: Vec<String> = cli
                .opt_or("algorithms", "fedadam-ssm,fedadam-top,fedadam")
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let mut summaries = Vec::new();
            for algo in algos {
                let mut cfg = base.clone();
                cfg.algorithm = algo.clone();
                let mut coord = Coordinator::new(cfg, artifacts)?;
                let log_out = coord.run()?;
                println!("{}", log_out.summary());
                if let Some(out) = cli.opt("out") {
                    std::fs::create_dir_all(out)?;
                    let path = format!("{out}/{}_{}.csv", base.name, algo);
                    log_out.write_csv(&path)?;
                }
                summaries.push(log_out);
            }
            println!("\n{:<18} {:>10} {:>14}", "algorithm", "best acc", "uplink Mbit");
            for s in &summaries {
                let up = s.rounds.last().map(|r| r.uplink_bits as f64 / 1e6).unwrap_or(0.0);
                println!("{:<18} {:>10.3} {:>14.2}", s.algorithm, s.best_accuracy(), up);
            }
            Ok(())
        }
        "models" => {
            let manifest = Manifest::load(artifacts)?;
            println!("{:<14} {:>10} {:>14} {:>8} {:>12}", "model", "params", "input", "batch", "programs");
            for (name, m) in &manifest.models {
                println!(
                    "{:<14} {:>10} {:>14} {:>8} {:>12}",
                    name,
                    m.dim,
                    format!("{:?}", m.input_shape),
                    m.batch,
                    m.artifacts.len()
                );
            }
            Ok(())
        }
        "info" => {
            let cfg = load_config(cli)?;
            println!("{cfg:#?}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}
