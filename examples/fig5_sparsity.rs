//! Fig. 5 reproduction: FedAdam-SSM accuracy for different sparsification
//! ratios α.
//!
//! The paper's finding (Theorem 2 / Remark 4): larger α (more coordinates
//! kept) → smaller sparsification error → better accuracy per round, but
//! proportionally more uplink.  The per-round curves in
//! `results/fig5_a*.csv` show the accuracy-vs-communication crossover.
//!
//! ```text
//! cargo run --release --example fig5_sparsity -- [--quick]
//! ```

use anyhow::Result;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    let quick = cli.flag("quick");

    let sweep: Vec<f64> = match cli.opt("alphas") {
        Some(s) => s.split(',').map(|x| x.trim().parse().unwrap()).collect(),
        None => {
            if quick {
                vec![0.01, 0.2]
            } else {
                vec![0.005, 0.01, 0.05, 0.1, 0.2, 0.5]
            }
        }
    };

    let mut base = ExperimentConfig::default();
    base.model = cli.opt_or("model", "cnn_small").to_string();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 5 } else { 15 });
    base.devices = if quick { 3 } else { 6 };
    base.train_samples = if quick { 512 } else { 2048 };
    base.test_samples = if quick { 128 } else { 512 };
    base.local_epochs = 2;

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("alpha,best_acc,final_loss,uplink_mbit\n");
    println!("{:>8} {:>10} {:>12} {:>14}", "alpha", "best acc", "final loss", "uplink Mbit");
    for &a in &sweep {
        let mut cfg = base.clone();
        cfg.sparsity = a;
        cfg.name = format!("fig5_a{a}");
        let mut coord = Coordinator::new(cfg, artifacts)?;
        let log = coord.run()?;
        let final_loss = log.rounds.last().unwrap().train_loss;
        let uplink = log.rounds.last().unwrap().uplink_bits as f64 / 1e6;
        println!(
            "{:>8} {:>10.3} {:>12.4} {:>14.2}",
            a,
            log.best_accuracy(),
            final_loss,
            uplink
        );
        csv.push_str(&format!("{a},{:.4},{final_loss:.4},{uplink:.2}\n", log.best_accuracy()));
        log.write_csv(format!("results/fig5_a{a}.csv"))?;
    }
    std::fs::write("results/fig5_summary.csv", csv)?;
    println!("\nwrote results/fig5_summary.csv");
    Ok(())
}
