//! Device-side local training: `L` local epochs through the AOT programs.
//!
//! A device's `train_round` is a pure function of `(mode, w, m, v, cfg)` —
//! it holds no cross-round state besides its immutable shard — which is
//! what lets the pipelined coordinator run many devices concurrently and
//! stream each finished upload straight into the server accumulator
//! without changing a single bit of the result.

use anyhow::Result;

use crate::algorithms::LocalMode;
use crate::data::Shard;
use crate::runtime::EngineHandle;

/// Knobs for one device's local run.
#[derive(Clone, Copy, Debug)]
pub struct LocalRunConfig {
    pub local_epochs: usize,
    /// 0 = no cap.
    pub max_batches_per_epoch: usize,
    pub lr: f32,
    /// Prefer the fused `epoch` program when a full chunk is available.
    pub use_epoch_program: bool,
}

/// Result of one local round.
#[derive(Clone, Debug)]
pub struct LocalResult {
    pub w: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Mean minibatch loss over the round.
    pub mean_loss: f64,
}

/// Batches one local epoch walks through for a `shard_len`-sample shard —
/// the one formula shared by [`Device::batches_per_epoch`] and the
/// coordinator's latency-model sizing, so the simulated compute cost can
/// never drift from the batches a materialized device actually runs.
pub(crate) fn batches_per_epoch_for(
    shard_len: usize,
    batch: usize,
    cfg: &LocalRunConfig,
) -> usize {
    let full = shard_len.max(1).div_ceil(batch);
    if cfg.max_batches_per_epoch == 0 {
        full
    } else {
        full.min(cfg.max_batches_per_epoch)
    }
}

/// One federated device: a shard plus an engine handle.
pub struct Device {
    pub id: usize,
    pub shard: Shard,
    engine: EngineHandle,
    // Reused batch buffers (no per-batch allocation).
    xbuf: Vec<f32>,
    ybuf: Vec<i32>,
}

impl Device {
    pub fn new(id: usize, shard: Shard, engine: EngineHandle) -> Self {
        Device {
            id,
            shard,
            engine,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
        }
    }

    /// FedAvg weight `|D̃_n|`.
    pub fn weight(&self) -> f64 {
        self.shard.data.len() as f64
    }

    /// Batches one local epoch walks through.
    pub fn batches_per_epoch(&self, cfg: &LocalRunConfig) -> usize {
        batches_per_epoch_for(self.shard.data.len(), self.engine.meta().batch, cfg)
    }

    /// Run `L` local epochs from `(w, m, v)`; Adam or SGD per `mode`.
    pub fn train_round(
        &mut self,
        mode: LocalMode,
        w: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        cfg: &LocalRunConfig,
    ) -> Result<LocalResult> {
        let meta = self.engine.meta().clone();
        let batch = meta.batch;
        let nb = self.batches_per_epoch(cfg);
        let mut w = w;
        let mut mm = m;
        let mut vv = v;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;

        for _epoch in 0..cfg.local_epochs {
            let mut b = 0;
            // Fused epoch program over full chunks (Adam only).
            while mode == LocalMode::Adam
                && cfg.use_epoch_program
                && b + meta.epoch_batches <= nb
            {
                let chunk = meta.epoch_batches;
                let mut xs = Vec::with_capacity(chunk * batch * meta.row());
                let mut ys = Vec::with_capacity(chunk * batch);
                for i in 0..chunk {
                    self.shard.fill_batch(b + i, batch, &mut self.xbuf, &mut self.ybuf);
                    xs.extend_from_slice(&self.xbuf);
                    ys.extend_from_slice(&self.ybuf);
                }
                let (w2, m2, v2, loss) = self.engine.epoch_step(w, mm, vv, xs, ys, cfg.lr)?;
                w = w2;
                mm = m2;
                vv = v2;
                loss_sum += loss as f64;
                loss_n += 1;
                b += chunk;
            }
            // Remainder (or the whole epoch when the fused path is off).
            while b < nb {
                self.shard.fill_batch(b, batch, &mut self.xbuf, &mut self.ybuf);
                let x = self.xbuf.clone();
                let y = self.ybuf.clone();
                match mode {
                    LocalMode::Adam => {
                        let (w2, m2, v2, loss) = self.engine.train_step(w, mm, vv, x, y, cfg.lr)?;
                        w = w2;
                        mm = m2;
                        vv = v2;
                        loss_sum += loss as f64;
                    }
                    LocalMode::Sgd => {
                        let (w2, loss) = self.engine.sgd_step(w, x, y, cfg.lr)?;
                        w = w2;
                        loss_sum += loss as f64;
                    }
                }
                loss_n += 1;
                b += 1;
            }
        }
        Ok(LocalResult {
            w,
            m: mm,
            v: vv,
            mean_loss: loss_sum / loss_n.max(1) as f64,
        })
    }
}
