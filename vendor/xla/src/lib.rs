//! Offline stub of the `xla` crate's PJRT API surface.
//!
//! This container image carries no XLA/PJRT shared libraries, so the real
//! `xla` crate cannot link here.  This stub keeps the whole repository
//! compiling and unit-testable: every PJRT *entry point*
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]) returns a
//! descriptive runtime error, and all downstream handle types are
//! uninhabited (built around an empty enum) so the dead paths cost nothing
//! and can never be reached by construction.
//!
//! Builds with the real toolchain swap the path dependency in the root
//! `Cargo.toml` for the actual `xla` crate; the engine/pool code is written
//! against the common API subset (`cpu`, `compile`, `execute`,
//! `to_literal_sync`, `Literal` constructors/accessors).

use std::fmt;

/// Error type mirroring the real crate's (Display-able) errors.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable — this build links the vendored \
         `xla` stub; build with the real `xla` crate (rust_pallas toolchain \
         image) to execute AOT artifacts"
    )))
}

/// Uninhabited: stub handles can never exist at runtime.
enum Never {}

/// Element types a [`Literal`] can carry (subset: what the runtime uses).
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

pub struct PjRtClient(Never);

impl PjRtClient {
    /// The CPU client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }
}

pub struct PjRtLoadedExecutable(Never);

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

pub struct PjRtBuffer(Never);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text. Always errors in the stub (reached only if a caller
    /// probes artifacts before opening a client; the engine opens the
    /// client first, so in practice [`PjRtClient::cpu`] errors earlier).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal(Never);

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        unreachable!("xla stub: literals cannot exist without a PJRT runtime")
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        unreachable!("xla stub: literals cannot exist without a PJRT runtime")
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        match self.0 {}
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self.0 {}
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_error_cleanly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("PJRT runtime unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
