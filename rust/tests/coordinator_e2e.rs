//! Integration: the full FL loop on `mlp_tiny` for every algorithm —
//! convergence, exact comm accounting, determinism, backend cross-check.
//!
//! Requires `make artifacts`.

use fedadam_ssm::algorithms::ALL_ALGORITHMS;
use fedadam_ssm::config::{ExperimentConfig, ParticipationMode, SparsifyBackend};
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::runtime::Manifest;
use fedadam_ssm::sparse::codec::cost;

fn have_artifacts() -> bool {
    match Manifest::load("artifacts") {
        Ok(m) => m.models.contains_key("mlp_tiny"),
        Err(_) => {
            eprintln!("skipping: run `make artifacts` first");
            false
        }
    }
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.rounds = 6;
    cfg.devices = 3;
    cfg.local_epochs = 2;
    cfg.max_batches_per_epoch = 2;
    cfg.train_samples = 384;
    cfg.test_samples = 128;
    cfg.lr = 0.01;
    cfg.seed = 5;
    // CI determinism matrix: FEDADAM_NUM_WORKERS / FEDADAM_AGG_SHARDS
    // sweep the whole suite across the worker/shard grid.
    cfg.apply_env_overrides();
    cfg
}

#[test]
fn every_algorithm_learns() {
    if !have_artifacts() {
        return;
    }
    for algo in ALL_ALGORITHMS {
        let mut cfg = base_cfg();
        cfg.algorithm = algo.into();
        if algo == "fedsgd" {
            // Plain SGD needs a larger step than Adam at this tiny budget.
            cfg.lr = 0.1;
        }
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        let log = coord.run().unwrap();
        let first = log.rounds.first().unwrap().train_loss;
        let last = log.rounds.last().unwrap().train_loss;
        assert!(
            last < first,
            "{algo}: loss should fall, got {first:.4} -> {last:.4}"
        );
        assert!(
            log.best_accuracy() > 0.3,
            "{algo}: accuracy stuck at {:.3}",
            log.best_accuracy()
        );
        // Every round's uplink grows monotonically.
        for w in log.rounds.windows(2) {
            assert!(w[1].uplink_bits > w[0].uplink_bits, "{algo}");
        }
    }
}

#[test]
fn comm_accounting_matches_formulas() {
    if !have_artifacts() {
        return;
    }
    let d = 2410usize; // mlp_tiny
    let n = 3u64;
    let cases: Vec<(&str, u64)> = vec![
        ("fedadam", cost::fedadam_dense(d)),
        ("fedadam-top", cost::fedadam_top(d, 121)), // k = round(0.05 * 2410)
        ("fedadam-ssm", cost::fedadam_ssm(d, 121)),
        ("fedadam-ssm-m", cost::fedadam_ssm(d, 121)),
        ("fairness-top", cost::fedadam_ssm(d, 121)),
        ("fedsgd", cost::fedsgd_dense(d)),
        ("efficient-adam", cost::uniform(d, 16)),
    ];
    for (algo, per_device) in cases {
        let mut cfg = base_cfg();
        // `n × formula` needs the full cohort every round: pin the
        // uniform sampler regardless of FEDADAM_PARTICIPATION_MODE.
        cfg.participation_mode = ParticipationMode::Uniform;
        cfg.rounds = 2;
        cfg.algorithm = algo.into();
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        let log = coord.run().unwrap();
        let expected = per_device * n * 2; // 2 rounds, 3 devices
        assert_eq!(
            log.rounds.last().unwrap().uplink_bits,
            expected,
            "{algo}: uplink mismatch"
        );
    }
}

#[test]
fn onebit_phases_price_differently() {
    if !have_artifacts() {
        return;
    }
    let d = 2410usize;
    let mut cfg = base_cfg();
    // Per-round `3 × formula` needs all 3 devices every round.
    cfg.participation_mode = ParticipationMode::Uniform;
    cfg.algorithm = "onebit-adam".into();
    cfg.rounds = 4;
    cfg.warmup_rounds = 2;
    let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
    let log = coord.run().unwrap();
    let per_round: Vec<u64> = std::iter::once(log.rounds[0].uplink_bits)
        .chain(
            log.rounds
                .windows(2)
                .map(|w| w[1].uplink_bits - w[0].uplink_bits),
        )
        .collect();
    assert_eq!(per_round[0], 3 * cost::fedadam_dense(d)); // warmup: dense
    assert_eq!(per_round[1], per_round[0]);
    assert_eq!(per_round[2], 3 * cost::onebit(d)); // compression: 1 bit
    assert_eq!(per_round[3], per_round[2]);
    assert!(per_round[2] < per_round[0] / 50);
}

#[test]
fn runs_are_deterministic() {
    if !have_artifacts() {
        return;
    }
    let run = || {
        let mut cfg = base_cfg();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.rounds = 3;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        let log = coord.run().unwrap();
        (
            log.rounds
                .iter()
                .map(|r| (r.train_loss, r.test_accuracy))
                .collect::<Vec<_>>(),
            coord.global().w.clone(),
        )
    };
    let (a_log, a_w) = run();
    let (b_log, b_w) = run();
    assert_eq!(a_log, b_log);
    assert_eq!(a_w, b_w);
}

#[test]
fn pool_workers_are_bit_identical() {
    if !have_artifacts() {
        return;
    }
    // The engine pool must change wall-clock only: every logged number —
    // losses, accuracies, uplink/downlink bits, update norms — and the
    // final global model must match bit-for-bit between a 1-worker and a
    // 4-worker run.  Covers an aggregated-moments algorithm, a stateful
    // per-device EF algorithm, and a device-local-moments phase switcher.
    for algo in ["fedadam-ssm", "fedadam-ssm-ef", "onebit-adam"] {
        let run = |workers: usize| {
            let mut cfg = base_cfg();
            cfg.algorithm = algo.into();
            cfg.rounds = 4;
            cfg.devices = 4;
            cfg.warmup_rounds = 2;
            cfg.participation = 0.75; // exercise the sampler path too
            cfg.num_workers = workers;
            let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
            let log = coord.run().unwrap();
            (log, coord.global().w.clone())
        };
        let (log1, w1) = run(1);
        let (log4, w4) = run(4);
        assert_eq!(w1, w4, "{algo}: global weights must be bit-identical");
        assert_eq!(log1.rounds.len(), log4.rounds.len());
        for (a, b) in log1.rounds.iter().zip(&log4.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{algo}");
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{algo}");
            assert_eq!(
                a.test_accuracy.to_bits(),
                b.test_accuracy.to_bits(),
                "{algo}"
            );
            assert_eq!(a.uplink_bits, b.uplink_bits, "{algo}");
            assert_eq!(a.downlink_bits, b.downlink_bits, "{algo}");
            assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits(), "{algo}");
        }
    }
}

#[test]
fn sharded_aggregation_and_parallel_eval_are_bit_identical() {
    if !have_artifacts() {
        return;
    }
    // Tentpole contract on the real PJRT backend: (num_workers,
    // agg_shards, pipeline_depth) may change wall-clock only.  Compare the
    // fully-sequential barrier run against parallel / streaming /
    // overlapped runs.
    let run = |workers: usize, shards: usize, depth: usize| {
        let mut cfg = base_cfg();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.rounds = 3;
        cfg.devices = 4;
        cfg.num_workers = workers;
        cfg.agg_shards = shards;
        cfg.pipeline_depth = depth;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        let log = coord.run().unwrap();
        (log, coord.global().w.clone())
    };
    let (log1, w1) = run(1, 1, 0);
    let grid = [(1, 4, 0), (4, 1, 0), (4, 4, 0), (1, 1, 1), (1, 1, 2), (4, 4, 2)];
    for (workers, shards, depth) in grid {
        let (log, w) = run(workers, shards, depth);
        assert_eq!(w1, w, "{workers}w/{shards}s/depth{depth}: weights diverged");
        for (a, b) in log1.rounds.iter().zip(&log.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
            assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits());
            assert_eq!(a.uplink_bits, b.uplink_bits);
            assert_eq!(a.downlink_bits, b.downlink_bits);
            assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits());
        }
    }
}

#[test]
fn xla_and_native_sparsify_agree() {
    if !have_artifacts() {
        return;
    }
    let run = |backend: SparsifyBackend| {
        let mut cfg = base_cfg();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.rounds = 3;
        cfg.sparsify_backend = backend;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        coord.run().unwrap();
        coord.global().w.clone()
    };
    let native = run(SparsifyBackend::Native);
    let xla = run(SparsifyBackend::Xla);
    // Same selection rule; tiny numeric jitter allowed (f32 threshold path,
    // possible tie handling at measure-zero inputs).
    let max_diff = native
        .iter()
        .zip(&xla)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "backends diverged: {max_diff}");
}

#[test]
fn conv_models_run_one_round() {
    // The paper's other two workloads (VGG/CIFAR-shape, ResNet/SVHN-shape)
    // through the full loop — one round each to keep CI fast.
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => return,
    };
    for model in ["vgg_mini", "resnet_mini"] {
        if !manifest.models.contains_key(model) {
            eprintln!("skipping {model}: not exported");
            continue;
        }
        let mut cfg = base_cfg();
        cfg.model = model.into();
        cfg.rounds = 1;
        cfg.devices = 2;
        cfg.local_epochs = 1;
        cfg.max_batches_per_epoch = 1;
        cfg.train_samples = 128;
        cfg.test_samples = 64;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        let r = coord.step_round().unwrap();
        assert!(r.train_loss.is_finite(), "{model}");
        assert!(r.test_accuracy.is_finite(), "{model}");
        assert!(r.uplink_bits > 0, "{model}");
    }
}

#[test]
fn partial_participation_scales_uplink() {
    if !have_artifacts() {
        return;
    }
    let run = |part: f64| {
        let mut cfg = base_cfg();
        cfg.algorithm = "fedadam".into();
        // Exact-cohort-size expectation: pin the uniform sampler
        // regardless of the CI lane's FEDADAM_PARTICIPATION_MODE.
        cfg.participation_mode = ParticipationMode::Uniform;
        cfg.participation = part;
        cfg.rounds = 3;
        cfg.devices = 4;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        coord.run().unwrap().rounds.last().unwrap().uplink_bits
    };
    let full = run(1.0);
    let half = run(0.5);
    assert_eq!(half * 2, full, "half participation must upload half the bits");
}

#[test]
fn ssm_ef_extension_learns_at_extreme_sparsity() {
    if !have_artifacts() {
        return;
    }
    let run = |algo: &str| {
        let mut cfg = base_cfg();
        cfg.algorithm = algo.into();
        cfg.sparsity = 0.005; // keep 0.5% of coordinates
        cfg.rounds = 8;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        coord.run().unwrap()
    };
    let ef = run("fedadam-ssm-ef");
    let first = ef.rounds.first().unwrap().train_loss;
    let last = ef.rounds.last().unwrap().train_loss;
    assert!(last < first, "EF variant should still learn: {first} -> {last}");
}

#[test]
fn noniid_is_harder_than_iid() {
    if !have_artifacts() {
        return;
    }
    let run = |iid: bool| {
        let mut cfg = base_cfg();
        cfg.algorithm = "fedadam-ssm".into();
        cfg.iid = iid;
        cfg.rounds = 8;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        coord.run().unwrap().best_accuracy()
    };
    let iid_acc = run(true);
    let noniid_acc = run(false);
    // Theorem 2's data-heterogeneity term: non-IID must not beat IID by a
    // margin; typically it is clearly worse.
    assert!(
        noniid_acc <= iid_acc + 0.05,
        "non-IID ({noniid_acc:.3}) unexpectedly beat IID ({iid_acc:.3})"
    );
}

#[test]
fn ssm_beats_dense_on_comm_to_accuracy() {
    if !have_artifacts() {
        return;
    }
    // The paper's headline (Table I): to reach the same accuracy,
    // FedAdam-SSM needs far less uplink than dense FedAdam.
    let run = |algo: &str| {
        let mut cfg = base_cfg();
        cfg.algorithm = algo.into();
        cfg.rounds = 8;
        let mut coord = Coordinator::new(cfg, "artifacts").unwrap();
        coord.run().unwrap()
    };
    let ssm = run("fedadam-ssm");
    let dense = run("fedadam");
    let target = ssm.best_accuracy().min(dense.best_accuracy()) * 0.9;
    let c_ssm = ssm.comm_to_accuracy(target).expect("ssm hits target");
    let c_dense = dense.comm_to_accuracy(target).expect("dense hits target");
    assert!(
        c_ssm * 2.0 < c_dense,
        "SSM should need <1/2 the uplink: ssm {c_ssm:.3} Mbit vs dense {c_dense:.3} Mbit"
    );
}
