//! The round coordinator: Algorithm 2's outer loop, pipelined.
//!
//! Owns the engine pool, data, devices, algorithm and ledger; each round it
//! (1) hands devices the global state per the algorithm's momentum policy,
//! (2) runs `L` local epochs per device through the AOT programs —
//!     **concurrently**, on scoped threads, load-balanced across the
//!     engine pool's workers,
//! (3) compresses and "uploads" each delta (bit-accurately priced),
//! (4) FedAvg-aggregates — **streaming**, each upload folded into the
//!     [`ShardedAccumulator`] the moment it lands — post-processes,
//!     applies, and
//! (5) evaluates + logs, with the eval fan-out **overlapping the next
//!     round's training dispatch** when `pipeline_depth >= 2`.
//!
//! ## Pipeline stages (`pipeline_depth` knob)
//!
//! - `0` — legacy barrier: train all → aggregate once → eval inline.
//! - `1` — streaming aggregation: a per-round folder thread accumulates
//!   uploads while later training chunks still run; eval stays inline.
//! - `>= 2` — plus train/eval overlap: round `t`'s eval fans out through
//!   the pool (at `Eval` priority, so it never starves training)
//!   concurrently with round `t+1`'s local-training dispatch; at most
//!   `pipeline_depth - 1` evals stay in flight.  The model eval reads is
//!   snapshotted right after round `t`'s apply — exactly the state round
//!   `t+1` trains from.
//!
//! ## Remote mode (`transport_listen`)
//!
//! When `transport_listen` names an address, step (2) runs on remote
//! **device-agent processes** instead of in-process scoped threads: the
//! coordinator broadcasts each round over [`crate::transport`] and
//! collects validated, compressed uplinks from `transport_agents` agent
//! processes (each owning the devices with `device % agents == index`).
//! Everything else — aggregation, apply, eval, ledger, simulated time —
//! is unchanged, and the run is byte-identical to the in-process run of
//! the same config.
//!
//! ## Participation and simulated time
//!
//! Each round's cohort comes from a pluggable [`sampler`]
//! ([`sampler::ParticipationSampler`], selected by the
//! `participation_mode` knob): uniform without replacement (the default,
//! bit-identical to the original loop), data-size-proportional importance
//! sampling with unbiased `1/(m·p_i)` re-weighting carried through the
//! cohort-weight path, or duty-cycle availability traces with
//! over-selection and a deadline.  A [`crate::simtime`] latency model
//! prices every round in deterministic *virtual* seconds (slowest
//! participant's compute + uplink, eval inline or overlapped per the
//! schedule), logged as the `sim_secs` column when `simtime` is on.
//!
//! ## Fleet scaling
//!
//! A *registered* fleet is cheap; only the *cohort* does work.  The
//! coordinator holds one copy of the training corpus plus a
//! [`ShardPlan`] index (O(fleet) at registration), synthesizes a sampled
//! device's `Device` + shard data on demand each round (O(cohort)), and
//! keeps per-device state — Adam moments here, error-feedback residuals
//! inside the algorithms — in lazily-materialized, disk-spillable
//! [`ResidualStore`]s (O(touched), bounded in RAM by
//! `residual_resident_cap`).  See `docs/ARCHITECTURE.md`'s "Scaling to
//! the fleet" chapter and `benches/fleet_scaling.rs` for the pinned
//! flatness numbers.
//!
//! ## The round state machine and the event journal
//!
//! Each round is an explicit walk through [`RunState`]:
//! `WaitingForCohort → Training → Aggregating → Applying → Evaluating →
//! RoundDone → WaitingForCohort`, one cycle per `step_round`, at every
//! `pipeline_depth` (the depths differ only in *where* work overlaps, not
//! in which transitions fire).  When the `journal` knob names a
//! directory, every transition appends a typed, versioned, checksummed
//! event to [`journal`]'s append-only log, and every `snapshot_every`
//! completed rounds the coordinator's full mutable state (global model +
//! moments, per-device EF residuals, sampler cursors, ledger, clock, log
//! rows, in-flight eval snapshots) is written as `snapshot_<round>.bin`.
//! [`Coordinator::resume`] restores the newest durable snapshot and
//! re-executes the logged tail under a byte-exact replay oracle — see the
//! [`journal`] module docs and `docs/ARCHITECTURE.md`'s crash-recovery
//! chapter.  Journaling is pure observation: a journaled run is
//! bit-identical to an unjournaled one.
//!
//! ## Determinism
//!
//! Local training for every participant starts from the same downloaded
//! global state, so per-device results do not depend on scheduling.
//! Training results are collected and processed in ascending device
//! order, and compression (which may hold per-device algorithm state such
//! as error-feedback memories) plus ledger accounting stay sequential in
//! that same order.  The streaming accumulator folds per lane in device
//! slot order (buffering early arrivals), eval reduces in ascending batch
//! order over the pre-sliced [`EvalPlan`], and an overlapped eval is a
//! pure function of its snapshotted `(w, test set)` — so every f32/f64
//! sum keeps one fixed association order and the experiment log, comm
//! ledger and final model are byte-identical at any
//! `num_workers` / `agg_shards` / `pipeline_depth`.  Cohorts and the
//! simulated clock are pure functions of `(config, data partition,
//! round, wire bits)` — never of scheduling or host time — so the same
//! holds with every `participation_mode` and with `simtime` on.

pub mod device;
pub mod journal;
pub mod sampler;
pub mod server;

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use crate::algorithms::residual_store::ResidualStore;
use crate::algorithms::{self, Aggregate, Algorithm, LocalDelta, MomentumPolicy, Upload};
use crate::config::{ExperimentConfig, SparsifyBackend};
use crate::data::{synthetic, Dataset, Partition, Shard, ShardPlan};
use crate::metrics::comm::CommLedger;
use crate::metrics::{ExperimentLog, RoundRecord};
use crate::runtime::{EngineHandle, EnginePool, Manifest, ModelMeta};
use crate::simtime::{LatencyModel, SimClock};
use crate::tensor;
use crate::transport::msg::Assignment;
use crate::transport::{RoundLatency, TransportServer};
use crate::util::bytes::{ByteReader, ByteWriter};

pub use device::{Device, LocalRunConfig};
pub use sampler::{Cohort, ParticipationSampler};
pub use server::{aggregate, aggregate_sharded, GlobalState, ShardedAccumulator};

/// The round loop's explicit state machine.  One cycle per
/// [`Coordinator::step_round`], the same six transitions at every
/// `pipeline_depth`; each transition is journaled as a typed
/// [`journal::Event`] when journaling is on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunState {
    /// Between rounds: the next step begins with cohort selection.
    WaitingForCohort,
    /// Local training in flight (at `pipeline_depth >= 1` the streaming
    /// folder aggregates concurrently under this state).
    Training,
    /// Every upload folded; the reduce finalizes.
    Aggregating,
    /// Post-process + broadcast accounting + global apply.
    Applying,
    /// The eval decision point: inline, launched overlapped, or skipped.
    Evaluating,
    /// Clock advanced, record logged, snapshot-if-due.
    RoundDone,
}

impl RunState {
    /// Whether `self → next` is a legal round-loop transition (the loop
    /// is a single fixed cycle).
    pub fn can_step(self, next: RunState) -> bool {
        use RunState::*;
        matches!(
            (self, next),
            (WaitingForCohort, Training)
                | (Training, Aggregating)
                | (Aggregating, Applying)
                | (Applying, Evaluating)
                | (Evaluating, RoundDone)
                | (RoundDone, WaitingForCohort)
        )
    }
}

/// A fully-wired experiment ready to run.
pub struct Coordinator {
    pub cfg: ExperimentConfig,
    pool: EnginePool,
    /// The shared training corpus — ONE copy for the whole fleet.  No
    /// per-device shard data is held between rounds; a sampled device's
    /// dataset is synthesized from this corpus + `shard_plan` on demand.
    train: Dataset,
    /// Registration-time index of which samples belong to which device
    /// (see [`ShardPlan`]) — O(corpus) index words, zero pixels.
    shard_plan: ShardPlan,
    /// Test-set length, kept for the slice-boundary regression assert
    /// (the samples themselves live only in the padded [`EvalPlan`] —
    /// holding the raw `Dataset` too would double test-set memory).
    test_len: usize,
    /// Test set pre-sliced into padded eval batches — built once, reused
    /// every eval round (and shared with overlapped eval threads).
    eval_plan: Arc<EvalPlan>,
    algorithm: Box<dyn Algorithm>,
    global: GlobalState,
    /// Per-device `[m | v]` Adam moments (one `2·dim` entry) for
    /// `MomentumPolicy::DeviceLocal` algorithms — lazily materialized on
    /// first touch and spillable past `residual_resident_cap`, so an
    /// Aggregated-policy run pays nothing and a million-device DeviceLocal
    /// fleet costs O(touched) (see [`ResidualStore`]).
    device_moments: ResidualStore,
    ledger: CommLedger,
    log: ExperimentLog,
    round: usize,
    /// Per-round cohort selection (`participation_mode` knob).
    sampler: Box<dyn ParticipationSampler>,
    /// Deterministic per-device latency model (always built; prices the
    /// availability deadline and, when `simtime` is on, the clock).
    latency: LatencyModel,
    /// The virtual round clock — `Some` only when `cfg.simtime` is on.
    sim: Option<SimClock>,
    /// Overlapped evals still in flight, oldest first.
    pending_evals: VecDeque<PendingEval>,
    /// Where the round loop stands (see [`RunState`]); always
    /// `WaitingForCohort` between `step_round` calls.
    state: RunState,
    /// The event journal — `Some` when the `journal` knob (or a resume)
    /// names a directory.
    journal: Option<journal::Journal>,
    /// The wire transport — `Some` when `transport_listen` names an
    /// address; rounds then train on remote device agents instead of
    /// in-process scoped threads.
    transport: Option<TransportServer>,
}

/// One overlapped eval: joins to `(test_loss, test_accuracy)` for `round`.
struct PendingEval {
    round: usize,
    /// The model snapshot the eval reads.  Kept so a journal snapshot can
    /// persist the in-flight eval as `(round, w)` — results are never
    /// persisted; a resume re-launches the eval from these weights.
    w: Arc<Vec<f32>>,
    join: std::thread::JoinHandle<Result<(f64, f64)>>,
}

/// What one participant's scoped-thread training run produces.
struct TrainOutput {
    mean_loss: f64,
    delta: LocalDelta,
    /// `(m, v)` to write back when the policy is `DeviceLocal`.
    moments: Option<(Vec<f32>, Vec<f32>)>,
}

impl Coordinator {
    /// Build everything: engine pool, data, shards, algorithm, initial model.
    pub fn new(cfg: ExperimentConfig, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        // Validate before the (expensive) pool build; `with_pool` validates
        // again because it is itself a public entry point.
        cfg.validate()?;
        let manifest = Manifest::load(artifacts_dir)?;
        // Concurrency is bounded by participant count, so never spin up
        // (and compile executables for) more workers than devices.
        let workers = crate::runtime::pool::resolve_workers(cfg.num_workers).min(cfg.devices);
        let pool = EnginePool::load(&manifest, &cfg.model, workers)
            .with_context(|| format!("loading model {:?}", cfg.model))?;
        Self::with_pool(cfg, pool)
    }

    /// Build an experiment on an already-constructed engine pool.
    ///
    /// This is the backend-injection seam: tests and benches hand in an
    /// [`EnginePool`] built from any [`crate::runtime::Executor`] factory
    /// (e.g. the pure-Rust [`crate::runtime::ReferenceExecutor`], which
    /// needs no PJRT artifacts), and the full round loop — training,
    /// compression, streaming aggregation, overlapped eval, ledger — runs
    /// against it.
    ///
    /// When `cfg.resume` names a journal directory this transparently
    /// delegates to [`Self::resume_with_pool`], so every entry point
    /// (CLI, tests, benches) resumes the same way.  Otherwise a non-empty
    /// `cfg.journal` starts a fresh event journal there.
    pub fn with_pool(cfg: ExperimentConfig, pool: EnginePool) -> Result<Self> {
        cfg.validate()?;
        if !cfg.resume.is_empty() {
            return Self::resume_with_pool(cfg, pool);
        }
        let mut c = Self::fresh(cfg, pool)?;
        if !c.cfg.journal.is_empty() {
            let dir = std::path::Path::new(&c.cfg.journal);
            c.journal = Some(journal::Journal::create(dir, c.cfg.fingerprint())?);
        }
        Ok(c)
    }

    /// The journal-free construction path shared by fresh runs and the
    /// resume restore (which overwrites the state this builds).
    fn fresh(cfg: ExperimentConfig, pool: EnginePool) -> Result<Self> {
        let meta = pool.meta().clone();

        let (task, shard_plan) = build_task_and_plan(&cfg, &pool);
        let handle = pool.handle();

        let algorithm = algorithms::build(&cfg, meta.dim)?;
        let w0 = handle.init(cfg.seed as i32)?;
        let global = GlobalState::new(w0);
        // DeviceLocal moments materialize lazily: first touch is zeros,
        // exactly the old dense Vec's initialization.
        let device_moments = ResidualStore::new(
            2 * meta.dim,
            cfg.residual_resident_cap,
            &cfg.residual_spill_dir,
        );

        // Hoisted out of the round loop: the eval slicing depends only on
        // `(test set, eval_batch)`, both fixed for the experiment's life.
        let eval_plan = Arc::new(EvalPlan::new(&task.test, &meta));

        // The latency model is a pure function of (config, shard sizes):
        // built unconditionally so the availability sampler's deadline
        // ranking exists even when the simulated clock is off.  The
        // per-device batch count comes from the SAME helper and the SAME
        // run config the training loop uses, so the priced compute can
        // never drift from the samples a device actually walks through —
        // and it needs only the plan's shard *sizes*, no materialized
        // shard data.
        let run_cfg = local_run_cfg(&cfg);
        let samples_per_round: Vec<usize> = (0..cfg.devices)
            .map(|d| {
                device::batches_per_epoch_for(shard_plan.shard_len(d), meta.batch, &run_cfg)
                    * meta.batch
                    * cfg.local_epochs
            })
            .collect();
        let latency = LatencyModel::new(&cfg, &samples_per_round, task.test.len());
        let data_weights: Vec<f64> = (0..cfg.devices)
            .map(|d| shard_plan.shard_len(d) as f64)
            .collect();
        let sampler = sampler::build(&cfg, &data_weights, latency.device_compute_secs());
        let sim = cfg.simtime.then(|| SimClock::new(cfg.pipeline_depth));

        // Remote mode: bind the accept socket up front so the resolved
        // address (port 0 → real port) is available to launch agents
        // against before the first round blocks on registration.
        let transport = if cfg.transport_listen.is_empty() {
            None
        } else {
            Some(TransportServer::bind(
                &cfg.transport_listen,
                cfg.transport_agents,
                cfg.transport_timeout_secs,
                cfg.fingerprint(),
                meta.dim,
            )?)
        };

        let log = ExperimentLog {
            name: cfg.name.clone(),
            algorithm: cfg.algorithm.clone(),
            model: cfg.model.clone(),
            iid: cfg.iid,
            rounds: Vec::new(),
        };
        Ok(Coordinator {
            cfg,
            pool,
            test_len: task.test.len(),
            train: task.train,
            shard_plan,
            eval_plan,
            algorithm,
            global,
            device_moments,
            ledger: CommLedger::default(),
            log,
            round: 0,
            sampler,
            latency,
            sim,
            pending_evals: VecDeque::new(),
            state: RunState::WaitingForCohort,
            journal: None,
            transport,
        })
    }

    /// Resume an interrupted journaled run: `cfg.resume` must name the
    /// journal directory of a compatible earlier run (same config
    /// fingerprint).  Restores the newest durable snapshot, then
    /// re-executes the logged tail under the byte-exact replay oracle —
    /// the returned coordinator stands exactly where the original stood
    /// when its log ended, in-flight overlapped evals re-launched from
    /// their logged model snapshots.  Convenience wrapper over
    /// [`Self::new`] (which delegates through [`Self::with_pool`]).
    pub fn resume(cfg: ExperimentConfig, artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        ensure!(
            !cfg.resume.is_empty(),
            "Coordinator::resume needs cfg.resume to name the journal directory"
        );
        Self::new(cfg, artifacts_dir)
    }

    /// [`Self::resume`] on an injected engine pool (the test/bench seam).
    pub fn resume_with_pool(cfg: ExperimentConfig, pool: EnginePool) -> Result<Self> {
        cfg.validate()?;
        ensure!(
            !cfg.resume.is_empty(),
            "resume_with_pool needs cfg.resume to name the journal directory"
        );
        let dir = std::path::PathBuf::from(cfg.resume.clone());
        let (mut jrnl, contents) = journal::Journal::open_resume(&dir, cfg.fingerprint())?;
        let mut c = Self::fresh(cfg, pool)?;
        // Newest snapshot that is durable: its file exists AND its
        // SnapshotWritten record landed in the log (a crash between the
        // file write and the event append falls back to the previous one).
        let mut snap: Option<(u64, usize)> = None;
        for (i, ev) in contents.events.iter().enumerate() {
            if let journal::Event::SnapshotWritten { round } = ev {
                if journal::snapshot_path(&dir, *round).is_file() {
                    snap = Some((*round, i));
                }
            }
        }
        let tail_from = match snap {
            Some((round, i)) => {
                let bytes = journal::read_snapshot(&journal::snapshot_path(&dir, round))?;
                c.restore_snapshot(&bytes)
                    .with_context(|| format!("restoring snapshot_{round}.bin"))?;
                i + 1
            }
            // No durable snapshot yet: re-execute from round 0, with the
            // whole log past the RunStarted header as the oracle.
            None => 1,
        };
        jrnl.set_replay(contents.payloads[tail_from..].to_vec());
        c.journal = Some(jrnl);
        // Re-execute the tail: every re-emitted event must byte-match the
        // log (anything else errors as a determinism violation); once the
        // tail is exhausted the journal switches back to appending and
        // the run continues as if never interrupted.
        while c.journal.as_ref().is_some_and(|j| j.replaying()) && c.round < c.cfg.rounds {
            c.step_round()
                .with_context(|| format!("re-executing journaled round {}", c.round))?;
        }
        Ok(c)
    }

    /// Immutable view of the global state.
    pub fn global(&self) -> &GlobalState {
        &self.global
    }

    /// The wire transport's resolved listen address (`transport_listen`
    /// with port 0 replaced by the real port), or `None` in-process.
    /// Launch device agents against this before the first `step_round`
    /// — registration blocks until `transport_agents` have connected.
    pub fn transport_addr(&self) -> Option<String> {
        self.transport.as_ref().map(|t| t.addr().to_string())
    }

    /// Broadcast a best-effort `Shutdown` to every connected device
    /// agent so their processes exit cleanly.  Idempotent; called by
    /// [`Self::run`] and on drop.
    pub fn shutdown_transport(&mut self) {
        if let Some(transport) = self.transport.as_mut() {
            transport.shutdown();
        }
    }

    pub fn handle(&self) -> EngineHandle {
        self.pool.handle()
    }

    /// Worker threads in the engine pool.
    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    /// Run one communication round; returns its record.
    ///
    /// With `pipeline_depth >= 2` an eval-due round *launches* its eval
    /// instead of running it inline: the returned record (and the log row)
    /// carries `NaN` eval cells until the overlapped eval is reaped by a
    /// later round, [`Self::drain_pending_evals`] or [`Self::run`].
    pub fn step_round(&mut self) -> Result<RoundRecord> {
        assert_eq!(
            self.state,
            RunState::WaitingForCohort,
            "step_round re-entered mid-round"
        );
        let t = self.round;
        let start = Instant::now();
        let dim = self.global.dim();

        // WaitingForCohort → Training: pick this round's participants.
        let cohort = self.sampler.sample(t);
        self.emit(journal::Event::CohortSelected {
            round: t as u64,
            devices: cohort.devices.iter().map(|&d| d as u64).collect(),
            weights: cohort.weights.iter().map(|w| w.to_bits()).collect(),
        })?;
        self.transition(RunState::Training);

        let shards = if self.cfg.agg_shards == 0 {
            self.pool.num_workers()
        } else {
            self.cfg.agg_shards
        };

        // Training → Aggregating (1-4 (+5): train → delta → compress →
        // upload → aggregate).
        let (loss_sum, mut agg, round_secs, measured, folded, expected) = if self.cfg.pipeline_depth
            == 0
        {
            // Legacy barrier: hold every upload, reduce once at the end.
            // Slot-placed, not pushed: the in-process sink fires in
            // ascending slot order, but the wire transport delivers in
            // arrival order, and the reduce must see cohort order either
            // way.
            let mut uploads: Vec<Option<Upload>> = (0..cohort.len()).map(|_| None).collect();
            let (loss_sum, round_secs, measured) =
                self.train_and_upload(t, &cohort, |slot, upload| {
                    debug_assert!(uploads[slot].is_none(), "slot {slot} uploaded twice");
                    uploads[slot] = Some(upload);
                    Ok(())
                })?;
            self.transition(RunState::Aggregating);
            let uploads: Vec<Upload> = uploads
                .into_iter()
                .map(|u| u.expect("train_and_upload returned Ok with a slot missing"))
                .collect();
            let n = uploads.len();
            (
                loss_sum,
                aggregate_sharded(&uploads, dim, shards),
                round_secs,
                measured,
                n,
                n,
            )
        } else {
            // Streaming aggregation: a folder thread owns the
            // ShardedAccumulator and folds each upload as it lands, while
            // the main thread keeps dispatching later training chunks.
            // FedAvg coefficients need the cohort's total weight up
            // front — cohort weights come from the sampler (static shard
            // sizes, importance-re-weighted shares, …), known before any
            // training finishes.
            let weights: Vec<f64> = cohort.weights.clone();
            let (tx, rx) = mpsc::channel::<(usize, Upload)>();
            std::thread::scope(
                |scope| -> Result<(f64, Aggregate, f64, RoundLatency, usize, usize)> {
                    // The folder returns the accumulator rather than the
                    // finalized aggregate: if training errors mid-round, the
                    // early `?` below drops `tx`, the stream ends with slots
                    // missing, and finalizing here would (rightly) panic —
                    // the error path must stay an error.
                    let folder = scope.spawn(move || {
                        let mut acc = ShardedAccumulator::new(dim, shards, &weights);
                        for (slot, upload) in rx {
                            acc.push(slot, upload);
                        }
                        acc
                    });
                    let (loss_sum, round_secs, measured) =
                        self.train_and_upload(t, &cohort, |slot, upload| {
                            tx.send((slot, upload))
                                .map_err(|_| anyhow!("upload folder thread hung up"))
                        })?;
                    drop(tx); // close the stream so the folder drains out
                    let acc = folder
                        .join()
                        .unwrap_or_else(|p| std::panic::resume_unwind(p));
                    self.transition(RunState::Aggregating);
                    let (folded, expected) = (acc.folded(), acc.expected());
                    Ok((loss_sum, acc.finalize(), round_secs, measured, folded, expected))
                },
            )?
        };
        self.emit(journal::Event::Aggregated {
            round: t as u64,
            folded: folded as u64,
            expected: expected as u64,
            uplink_bits: self.ledger.uplink_bits,
        })?;

        // Aggregating → Applying: post-process + broadcast accounting +
        // apply.
        self.transition(RunState::Applying);
        self.algorithm.postprocess(&mut agg);
        self.ledger
            .down(self.algorithm.downlink_bits(&agg), cohort.len());
        let update_norm = tensor::l2_norm(&agg.dw);
        self.global.apply(&agg);
        self.emit(journal::Event::Applied {
            round: t as u64,
            update_norm: update_norm.to_bits(),
            downlink_bits: self.ledger.downlink_bits,
        })?;

        // Applying → Evaluating — inline at `pipeline_depth <= 1`,
        // otherwise overlapped with the next round's training dispatch.
        // The overlapped eval snapshots the just-applied model, so it
        // reads exactly the state round `t+1` trains from.
        self.transition(RunState::Evaluating);
        let eval_due = t % self.cfg.eval_every == 0 || t + 1 == self.cfg.rounds;
        let in_flight_cap = self.cfg.pipeline_depth.saturating_sub(1);
        let (test_loss, test_acc) = if !eval_due {
            self.emit(journal::Event::EvalSkipped { round: t as u64 })?;
            (f64::NAN, f64::NAN)
        } else if in_flight_cap == 0 {
            let (l, a) = self.evaluate()?;
            self.emit(journal::Event::EvalInline {
                round: t as u64,
                test_loss: l.to_bits(),
                test_accuracy: a.to_bits(),
            })?;
            (l, a)
        } else {
            while self.pending_evals.len() >= in_flight_cap {
                self.reap_oldest_eval()?;
            }
            self.spawn_eval(t);
            self.emit(journal::Event::EvalLaunched { round: t as u64 })?;
            (f64::NAN, f64::NAN)
        };

        // Evaluating → RoundDone.  Simulated wall-clock: the slowest
        // participant's compute + uplink gates the round; eval runs
        // inline (barrier/streaming) or hides under the next round's
        // training (overlap).  Pure virtual time — never the host clock.
        self.transition(RunState::RoundDone);
        let sim_secs = match self.sim.as_mut() {
            Some(clock) => {
                let eval_cost = if eval_due {
                    Some(self.latency.eval_secs())
                } else {
                    None
                };
                clock.advance_round(round_secs, eval_cost);
                clock.now()
            }
            None => f64::NAN,
        };

        let record = RoundRecord {
            round: t,
            train_loss: loss_sum / cohort.len() as f64,
            test_loss,
            test_accuracy: test_acc,
            uplink_bits: self.ledger.uplink_bits,
            downlink_bits: self.ledger.downlink_bits,
            wall_secs: start.elapsed().as_secs_f64(),
            sim_secs,
            update_norm,
            fleet_devices: self.cfg.devices as u64,
            cohort_devices: cohort.len() as u64,
            meas_uplink_max_secs: measured.max_secs,
            meas_uplink_mean_secs: measured.mean_secs,
        };
        self.log.rounds.push(record.clone());
        self.round += 1;
        self.emit(journal::Event::RoundDone {
            round: t as u64,
            train_loss: record.train_loss.to_bits(),
            sim_secs: sim_secs.to_bits(),
        })?;
        self.snapshot_if_due()?;

        // RoundDone → WaitingForCohort: ready for the next step.
        self.transition(RunState::WaitingForCohort);
        Ok(record)
    }

    /// Step the state machine, asserting the transition is legal.
    fn transition(&mut self, next: RunState) {
        assert!(
            self.state.can_step(next),
            "illegal round-loop transition {:?} -> {next:?}",
            self.state
        );
        self.state = next;
    }

    /// Append `event` to the journal (or, while a resume replays, verify
    /// it byte-exactly against the logged tail).  No-op when journaling
    /// is off.  Journaling is pure observation — nothing here touches
    /// RNGs, the clock, or any state the round loop reads — so a
    /// journaled run is bit-identical to an unjournaled one.
    fn emit(&mut self, event: journal::Event) -> Result<()> {
        match self.journal.as_mut() {
            Some(j) => j.record(&event),
            None => Ok(()),
        }
    }

    /// Take a full-state snapshot every `snapshot_every` completed rounds
    /// (journaling only).  The file is written *before* its
    /// [`journal::Event::SnapshotWritten`] record: a crash between the
    /// two leaves a file no resume will trust, falling back to the
    /// previous snapshot.
    fn snapshot_if_due(&mut self) -> Result<()> {
        if self.journal.is_none() || self.round == 0 || self.round % self.cfg.snapshot_every != 0 {
            return Ok(());
        }
        let payload = self.save_snapshot();
        let round = self.round as u64;
        self.journal.as_ref().unwrap().write_snapshot(round, &payload)?;
        self.emit(journal::Event::SnapshotWritten { round })
    }

    /// Serialize the coordinator's full mutable state — everything
    /// [`Self::restore_snapshot`] needs to continue the run bit-exactly
    /// (floats as raw bits throughout).  In-flight overlapped evals
    /// persist as `(round, model snapshot)` pairs: results are
    /// recomputed on restore, never persisted.
    fn save_snapshot(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.round as u64);
        self.global.save_state(&mut w);
        // Touched entries only — an Aggregated-policy run writes a bare
        // count of zero here, and a million-device fleet pays O(touched),
        // not O(fleet) (format change behind `JOURNAL_VERSION` 2).
        self.device_moments.save_state(&mut w);
        self.algorithm.save_state(&mut w);
        self.sampler.save_state(&mut w);
        w.put_u64(self.ledger.uplink_bits);
        w.put_u64(self.ledger.downlink_bits);
        match &self.sim {
            Some(clock) => {
                w.put_bool(true);
                let (now, pending) = clock.state();
                w.put_f64(now);
                w.put_f64(pending);
            }
            None => w.put_bool(false),
        }
        w.put_usize(self.log.rounds.len());
        for r in &self.log.rounds {
            w.put_u64(r.round as u64);
            w.put_f64(r.train_loss);
            w.put_f64(r.test_loss);
            w.put_f64(r.test_accuracy);
            w.put_u64(r.uplink_bits);
            w.put_u64(r.downlink_bits);
            w.put_f64(r.wall_secs);
            w.put_f64(r.sim_secs);
            w.put_f64(r.update_norm);
            w.put_u64(r.fleet_devices);
            w.put_u64(r.cohort_devices);
            w.put_f64(r.meas_uplink_max_secs);
            w.put_f64(r.meas_uplink_mean_secs);
        }
        w.put_usize(self.pending_evals.len());
        for p in &self.pending_evals {
            w.put_u64(p.round as u64);
            w.put_f32s(&p.w);
        }
        w.into_inner()
    }

    /// Restore the state written by [`Self::save_snapshot`] over a
    /// freshly-built coordinator, re-launching any persisted in-flight
    /// evals from their logged model snapshots.
    fn restore_snapshot(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        self.round = r.take_u64()? as usize;
        self.global.load_state(&mut r)?;
        // Touched entries only; untouched devices rehydrate to zeros on
        // first contact, bit-identical to the dense-state format.
        self.device_moments.load_state(&mut r)?;
        self.algorithm.load_state(&mut r)?;
        self.sampler.load_state(&mut r)?;
        self.ledger.uplink_bits = r.take_u64()?;
        self.ledger.downlink_bits = r.take_u64()?;
        let has_clock = r.take_bool()?;
        ensure!(
            has_clock == self.sim.is_some(),
            "snapshot simtime presence disagrees with the config"
        );
        if has_clock {
            let now = r.take_f64()?;
            let pending = r.take_f64()?;
            self.sim = Some(SimClock::from_state(self.cfg.pipeline_depth, now, pending));
        }
        let rows = r.take_usize()?;
        self.log.rounds.clear();
        for _ in 0..rows {
            self.log.rounds.push(RoundRecord {
                round: r.take_u64()? as usize,
                train_loss: r.take_f64()?,
                test_loss: r.take_f64()?,
                test_accuracy: r.take_f64()?,
                uplink_bits: r.take_u64()?,
                downlink_bits: r.take_u64()?,
                wall_secs: r.take_f64()?,
                sim_secs: r.take_f64()?,
                update_norm: r.take_f64()?,
                fleet_devices: r.take_u64()?,
                cohort_devices: r.take_u64()?,
                meas_uplink_max_secs: r.take_f64()?,
                meas_uplink_mean_secs: r.take_f64()?,
            });
        }
        let pend = r.take_usize()?;
        for _ in 0..pend {
            let round = r.take_u64()? as usize;
            let w = Arc::new(r.take_f32s()?);
            self.spawn_eval_of(round, w);
        }
        r.finish()?;
        self.state = RunState::WaitingForCohort;
        Ok(())
    }

    /// Steps 1-4 of a round for the `cohort`: local training on scoped
    /// threads in bounded chunks of participants, so peak memory stays
    /// O(chunk · d) rather than O(N · d) (dense deltas are 3·d f32 each;
    /// at 100+ devices and ResNet-scale d an unbounded barrier would hold
    /// gigabytes).  Each finished [`Upload`] is handed to `sink` with its
    /// slot (position in the cohort) the moment it is ready — the
    /// streaming seam the pipelined aggregator folds through.  Every
    /// upload carries the *cohort* weight the sampler assigned to its
    /// slot (for uniform/availability that is the device's data size; for
    /// importance sampling it is the unbiased `1/(m·p_i)` share).
    ///
    /// Within a chunk, local training runs on one scoped thread per
    /// participant; threads block inside the engine pool's queue, so
    /// concurrency is governed by `num_workers`, and each result is a
    /// pure function of its inputs — scheduling cannot change any bit of
    /// the output.  Chunks, result collection, compression (which may
    /// mutate per-device algorithm state such as EF memories), ledger
    /// accounting and the sink calls all proceed in ascending device
    /// order, so the wire log is byte-identical at any worker count.
    ///
    /// Returns `(loss_sum, round_secs, latency)` where `round_secs` is
    /// the round's simulated critical path — the slowest participant's
    /// `compute + uplink` seconds under the latency model — and
    /// `latency` is the *measured* host-clock uplink round-trip
    /// ([`RoundLatency`]).  In-process there is no wire, so the measured
    /// cells are `NaN`; only the remote path fills them.
    fn train_and_upload(
        &mut self,
        t: usize,
        cohort: &Cohort,
        mut sink: impl FnMut(usize, Upload) -> Result<()>,
    ) -> Result<(f64, f64, RoundLatency)> {
        if self.transport.is_some() {
            return self.train_and_upload_remote(t, cohort, sink);
        }
        let participants = &cohort.devices;
        let run_cfg = local_run_cfg(&self.cfg);
        let mode = self.algorithm.local_mode(t);
        let policy = self.algorithm.momentum_policy(t);
        let keep_moments = policy == MomentumPolicy::DeviceLocal;
        let dim = self.global.dim();
        let chunk_size = (self.pool.num_workers() * 2).max(8);
        let handle = self.pool.handle();
        let mut loss_sum = 0.0f64;
        let mut round_secs = 0.0f64;
        let mut slot = 0usize;
        for chunk in participants.chunks(chunk_size) {
            // Download: snapshot starting moments before any training runs
            // (matches the sequential schedule — a device only ever
            // observed its own pre-round state anyway).  DeviceLocal
            // moments come out of the residual store; first touch is
            // zeros, identical to the old dense Vec's initialization.
            let mut downloads: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(chunk.len());
            for &di in chunk {
                downloads.push(match policy {
                    MomentumPolicy::Aggregated => (self.global.m.clone(), self.global.v.clone()),
                    MomentumPolicy::DeviceLocal => {
                        let entry = self.device_moments.get_mut(di as u64);
                        let (m, v) = entry.split_at(dim);
                        (m.to_vec(), v.to_vec())
                    }
                });
            }
            // Synthesize this chunk's devices on demand from the shard
            // plan — O(chunk · shard samples), independent of fleet size.
            // (The old code held every device materialized for the run's
            // life and rescanned that O(fleet) vector once per chunk.)
            let mut chunk_devices: Vec<Device> = Vec::with_capacity(chunk.len());
            for &di in chunk {
                let data = self.shard_plan.materialize(&self.train, di);
                chunk_devices.push(Device::new(di, Shard { data }, handle.clone()));
            }
            let global_w = &self.global.w;
            // The sampler's per-slot FedAvg weights for this chunk
            // (uniform mode: exactly the device data sizes the legacy
            // loop used, so the wire stays bit-identical).
            let chunk_weights = &cohort.weights[slot..slot + chunk.len()];
            let outputs: Vec<Result<TrainOutput>> = std::thread::scope(|scope| {
                let handles: Vec<_> = chunk_devices
                    .iter_mut()
                    .zip(downloads)
                    .zip(chunk_weights)
                    .map(|((dev, (m0, v0)), &weight)| {
                        scope.spawn(move || -> Result<TrainOutput> {
                            let result = dev.train_round(
                                mode,
                                global_w.clone(),
                                m0.clone(),
                                v0.clone(),
                                &run_cfg,
                            )?;
                            let delta = LocalDelta {
                                dw: tensor::sub(&result.w, global_w),
                                dm: tensor::sub(&result.m, &m0),
                                dv: tensor::sub(&result.v, &v0),
                                weight,
                            };
                            Ok(TrainOutput {
                                mean_loss: result.mean_loss,
                                delta,
                                moments: keep_moments.then(|| (result.m, result.v)),
                            })
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
                    .collect()
            });
            for (&di, output) in chunk.iter().zip(outputs) {
                let output = output.with_context(|| format!("device {di} local round"))?;
                loss_sum += output.mean_loss;
                if let Some((m, v)) = output.moments {
                    let entry = self.device_moments.get_mut(di as u64);
                    entry[..dim].copy_from_slice(&m);
                    entry[dim..].copy_from_slice(&v);
                }
                let upload = self.compress_upload(t, di, output.delta)?;
                // Simulated critical path: this device finishes when its
                // local compute AND its (bits-priced) uplink are done.
                round_secs = round_secs
                    .max(self.latency.compute_secs(di) + self.latency.upload_secs(upload.bits));
                self.ledger.up(upload.bits);
                sink(slot, upload)?;
                slot += 1;
            }
        }
        Ok((loss_sum, round_secs, RoundLatency::unmeasured()))
    }

    /// Compress via the configured backend (native quickselect, or the
    /// AOT Pallas sparsifier for the plain SSM algorithm).
    fn compress_upload(&mut self, t: usize, di: usize, delta: LocalDelta) -> Result<Upload> {
        let handle = self.pool.handle();
        compress_upload_with(&self.cfg, &handle, self.algorithm.as_mut(), t, di, delta)
    }

    /// One round over the wire transport instead of in-process scoped
    /// threads.  Takes the transport out of `self` for the duration so
    /// the sink closure can borrow the coordinator's other fields.
    fn train_and_upload_remote(
        &mut self,
        t: usize,
        cohort: &Cohort,
        sink: impl FnMut(usize, Upload) -> Result<()>,
    ) -> Result<(f64, f64, RoundLatency)> {
        let mut transport = self
            .transport
            .take()
            .expect("remote dispatch without a transport");
        let out = self.remote_round(&mut transport, t, cohort, sink);
        self.transport = Some(transport);
        out
    }

    /// Broadcast the round, collect every slot's validated upload, and
    /// account losses / latency / ledger exactly as the in-process loop
    /// does.  Uplinks land in arbitrary arrival order; everything folded
    /// here is arrival-order-independent (per-slot loss cells summed
    /// ascending at the end, an f64 `max` and a u64 ledger add), and the
    /// sink receives the slot index so downstream accumulation stays
    /// slot-fixed.
    fn remote_round(
        &mut self,
        transport: &mut TransportServer,
        t: usize,
        cohort: &Cohort,
        mut sink: impl FnMut(usize, Upload) -> Result<()>,
    ) -> Result<(f64, f64, RoundLatency)> {
        let policy = self.algorithm.momentum_policy(t);
        let assignments: Vec<Assignment> = cohort
            .devices
            .iter()
            .zip(&cohort.weights)
            .enumerate()
            .map(|(slot, (&device, &weight))| Assignment {
                slot: slot as u32,
                device: device as u32,
                weight,
            })
            .collect();
        let (m, v) = match policy {
            MomentumPolicy::Aggregated => {
                (Some(self.global.m.as_slice()), Some(self.global.v.as_slice()))
            }
            // Device-local moments live with the owning agent.
            MomentumPolicy::DeviceLocal => (None, None),
        };
        let mut losses = vec![0.0f64; cohort.len()];
        let mut round_secs = 0.0f64;
        let ledger = &mut self.ledger;
        let latency = &self.latency;
        let measured = transport.run_round(
            t as u64,
            &self.global.w,
            m,
            v,
            &assignments,
            |slot, device, mean_loss, upload| {
                losses[slot] = mean_loss;
                round_secs = round_secs
                    .max(latency.compute_secs(device) + latency.upload_secs(upload.bits));
                ledger.up(upload.bits);
                sink(slot, upload)
            },
        )?;
        Ok((losses.iter().sum(), round_secs, measured))
    }

    /// Launch round `t`'s eval on a background thread: it snapshots the
    /// current global model and fans batches through the pool at `Eval`
    /// priority, overlapping the next round's training dispatch.
    fn spawn_eval(&mut self, t: usize) {
        self.spawn_eval_of(t, Arc::new(self.global.w.clone()));
    }

    /// Launch an eval of the given model snapshot for round `t` — the
    /// shared seam between a live launch ([`Self::spawn_eval`]) and a
    /// resume re-launching a persisted in-flight eval.
    fn spawn_eval_of(&mut self, t: usize, w: Arc<Vec<f32>>) {
        self.assert_eval_plan_fresh();
        let engine = self.pool.handle();
        let plan = Arc::clone(&self.eval_plan);
        let workers = self.pool.num_workers();
        let join = {
            let w = Arc::clone(&w);
            std::thread::spawn(move || evaluate_plan(&engine, &w, &plan, workers))
        };
        self.pending_evals.push_back(PendingEval { round: t, w, join });
    }

    /// Join the oldest overlapped eval and patch its log row in place.
    fn reap_oldest_eval(&mut self) -> Result<()> {
        let Some(pending) = self.pending_evals.pop_front() else {
            return Ok(());
        };
        let (test_loss, test_acc) = pending
            .join
            .join()
            .unwrap_or_else(|p| std::panic::resume_unwind(p))
            .with_context(|| format!("round {} overlapped eval", pending.round))?;
        // The row exists by now: records are pushed at the end of the very
        // step_round that spawned the eval.  (Tolerate a missing row all
        // the same — a drain after a mid-round error must not panic.)
        if let Some(rec) = self
            .log
            .rounds
            .iter_mut()
            .find(|r| r.round == pending.round)
        {
            rec.test_loss = test_loss;
            rec.test_accuracy = test_acc;
        }
        // Journaled at the deterministic reap point (the round that
        // joined it), never at thread completion time.
        self.emit(journal::Event::EvalReaped {
            round: pending.round as u64,
            test_loss: test_loss.to_bits(),
            test_accuracy: test_acc.to_bits(),
        })?;
        Ok(())
    }

    /// Join every overlapped eval still in flight and fold the results
    /// into the log.  No-op at `pipeline_depth <= 1` or when idle.
    ///
    /// Also drains the simulated clock: an overlapped eval with no next
    /// round to hide under still costs virtual time, so the pending eval
    /// is folded in and the **last** log row's `sim_secs` is patched to
    /// the drained clock (mirroring how eval cells are patched).  At
    /// `pipeline_depth <= 1` nothing pends and the patch is a no-op.
    pub fn drain_pending_evals(&mut self) -> Result<()> {
        while !self.pending_evals.is_empty() {
            self.reap_oldest_eval()?;
        }
        if let Some(clock) = self.sim.as_mut() {
            let drained = clock.drain();
            if let Some(last) = self.log.rounds.last_mut() {
                last.sim_secs = drained;
            }
        }
        Ok(())
    }

    /// Regression guard for the hoisted eval slicing: the pre-sliced
    /// plan's batch boundaries must be identical to a fresh re-slice on
    /// every eval — i.e. identical across rounds.
    fn assert_eval_plan_fresh(&self) {
        debug_assert_eq!(
            self.eval_plan.boundaries(),
            EvalPlan::slice_boundaries(self.test_len, self.pool.meta().eval_batch).as_slice(),
            "eval slice boundaries drifted between rounds"
        );
    }

    /// Evaluate the global model on the held-out test set, fanning the
    /// pre-sliced eval batches out across the engine pool.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.assert_eval_plan_fresh();
        evaluate_plan(
            &self.pool.handle(),
            &self.global.w,
            &self.eval_plan,
            self.pool.num_workers(),
        )
    }

    /// Run all configured rounds, returning the full log (every overlapped
    /// eval drained, so eval-round rows are complete).
    pub fn run(&mut self) -> Result<ExperimentLog> {
        while self.round < self.cfg.rounds {
            let r = self.step_round()?;
            log::info!(
                "[{}] round {:>3}: loss {:.4} acc {} uplink {:.2} Mbit ({:.1}s)",
                self.cfg.algorithm,
                r.round,
                r.train_loss,
                if r.test_accuracy.is_nan() {
                    "-".to_string()
                } else {
                    format!("{:.3}", r.test_accuracy)
                },
                r.uplink_bits as f64 / 1e6,
                r.wall_secs,
            );
        }
        self.drain_pending_evals()?;
        self.shutdown_transport();
        Ok(self.log.clone())
    }

    /// The log accumulated so far.
    pub fn log(&self) -> &ExperimentLog {
        &self.log
    }

    /// Where the round state machine stands — `WaitingForCohort` between
    /// `step_round` calls.
    pub fn run_state(&self) -> RunState {
        self.state
    }

    /// The round the next `step_round` call will run.
    pub fn round(&self) -> usize {
        self.round
    }
}

/// The one place a [`LocalRunConfig`] is derived from the experiment
/// config — both the training loop and the latency-model sizing go
/// through here (and the remote device agent, via
/// [`crate::transport::agent`]), so the simulated compute cost cannot
/// drift from the batches a device actually trains on.
pub(crate) fn local_run_cfg(cfg: &ExperimentConfig) -> LocalRunConfig {
    LocalRunConfig {
        local_epochs: cfg.local_epochs,
        max_batches_per_epoch: cfg.max_batches_per_epoch,
        lr: cfg.lr as f32,
        use_epoch_program: cfg.use_epoch_program,
    }
}

/// The one recipe for turning `(config, pool)` into the synthetic task
/// and the fleet's [`ShardPlan`] — shared by [`Coordinator::fresh`] and
/// the remote device agent ([`crate::transport::agent`]), so every
/// process derives the byte-identical shards from the same seeds.  The
/// plan is the lazy form: which samples belong to which device, with no
/// shard data materialized yet; both sides synthesize a sampled
/// device's shard on demand via [`ShardPlan::materialize`] (pinned to
/// equal the old eager `partition()` output), so memory stays
/// O(cohort), not O(fleet), on either side of the wire.
pub(crate) fn build_task_and_plan(
    cfg: &ExperimentConfig,
    pool: &EnginePool,
) -> (synthetic::SyntheticTask, ShardPlan) {
    let meta = pool.meta();
    // Synthetic stand-in corpus shaped for this model.
    let spec = synthetic::SyntheticSpec::for_input_shape(
        &meta.input_shape,
        cfg.train_samples,
        cfg.test_samples,
    );
    let task = synthetic::generate(&spec, cfg.seed);
    let how = Partition::parse(cfg.iid, cfg.dirichlet_theta);
    let plan = ShardPlan::build(&task.train, cfg.devices, how, cfg.seed);
    (task, plan)
}

/// Compress one delta via the configured backend — the native algorithm
/// implementation, or the AOT Pallas sparsifier for the plain SSM
/// algorithm.  Free-standing (rather than a `Coordinator` method) so the
/// remote device agent compresses through the exact same path.
pub(crate) fn compress_upload_with(
    cfg: &ExperimentConfig,
    handle: &EngineHandle,
    algorithm: &mut dyn Algorithm,
    t: usize,
    di: usize,
    delta: LocalDelta,
) -> Result<Upload> {
    if cfg.sparsify_backend == SparsifyBackend::Xla && cfg.algorithm == "fedadam-ssm" {
        // Cross-layer path: run eq. 10-12 + 28 inside XLA, then encode.
        use crate::algorithms::Recon;
        use crate::sparse::{codec::cost, top_k_indices, SparseVec};
        let dim = delta.dw.len();
        let k = cfg.k_for(dim);
        // The shared mask's support comes from the threshold indices,
        // NOT from the kernel output's non-zeros: a kept lane whose
        // value is exactly 0.0 is still transmitted (and priced), and
        // `SparseVec::from_dense` would silently drop it, making
        // `nnz < k` while `bits` charges for k.  Gathering the masked
        // kernel outputs at the top-k indices keeps the encoded wire
        // format bit-for-bit consistent with `cost::fedadam_ssm(d, k)`.
        // (The kernel keeps ties at the threshold, so its support is a
        // superset of these exactly-k indices; values at them agree.)
        let idx = top_k_indices(&delta.dw, k);
        let (sw, sm, sv) = handle.sparsify(delta.dw, delta.dm, delta.dv, k as i32)?;
        return Ok(Upload {
            dw: Recon::Sparse(SparseVec::gather(&sw, &idx)),
            dm: Some(Recon::Sparse(SparseVec::gather(&sm, &idx))),
            dv: Some(Recon::Sparse(SparseVec::gather(&sv, &idx))),
            weight: delta.weight,
            bits: cost::fedadam_ssm(dim, k),
        });
    }
    Ok(algorithm.compress(t, di, delta))
}

/// [`compress_upload_with`], but producing the transport's typed wire
/// message.  Algorithms with a native wire encoding go straight to
/// [`Algorithm::compress_wire`]; the XLA sparsify path converts its
/// upload after the fact (same bits either way).
pub(crate) fn compress_wire_with(
    cfg: &ExperimentConfig,
    handle: &EngineHandle,
    algorithm: &mut dyn Algorithm,
    t: usize,
    di: usize,
    delta: LocalDelta,
) -> Result<crate::algorithms::wire::WireUpload> {
    if cfg.sparsify_backend == SparsifyBackend::Xla && cfg.algorithm == "fedadam-ssm" {
        let upload = compress_upload_with(cfg, handle, algorithm, t, di, delta)?;
        return crate::algorithms::wire::WireUpload::from_upload(upload);
    }
    algorithm.compress_wire(t, di, delta)
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Overlapped evals hold a PoolHandle; `Drop::drop` runs before the
        // pool field drops, so join them here for a clean shutdown (their
        // results are discarded — the experiment is being abandoned).
        for pending in self.pending_evals.drain(..) {
            let _ = pending.join.join();
        }
        // Agents block reading the socket; tell them the run is over so
        // their processes exit instead of erroring on a dropped stream.
        self.shutdown_transport();
    }
}

/// One pre-sliced eval batch, zero-weight-padded to the program's fixed
/// `eval_batch` shape.
pub struct EvalBatch {
    /// Flattened input rows, `eval_batch · row` long.
    pub x: Vec<f32>,
    /// Labels (padded lanes carry `0`).
    pub y: Vec<i32>,
    /// Per-lane weights: `1.0` for real samples, `0.0` for padding.
    pub wt: Vec<f32>,
}

/// The test set pre-sliced into `ceil(len / eval_batch)` fixed batches.
///
/// Built once per experiment (hoisted out of the round loop — the slicing
/// depends only on the test set and the program's eval batch shape, both
/// immutable) and shared with overlapped eval threads via `Arc`.
pub struct EvalPlan {
    batches: Vec<EvalBatch>,
    boundaries: Vec<(usize, usize)>,
}

impl EvalPlan {
    /// Slice `data` into padded batches for `meta`'s eval program.
    pub fn new(data: &Dataset, meta: &ModelMeta) -> EvalPlan {
        let e = meta.eval_batch.max(1);
        let row = meta.row();
        let boundaries = Self::slice_boundaries(data.len(), meta.eval_batch);
        let batches = boundaries
            .iter()
            .map(|&(start, end)| {
                let mut x = Vec::with_capacity(e * row);
                let mut y = Vec::with_capacity(e);
                let mut wt = Vec::with_capacity(e);
                for i in 0..e {
                    if start + i < end {
                        x.extend_from_slice(data.image(start + i));
                        y.push(data.labels[start + i]);
                        wt.push(1.0);
                    } else {
                        x.extend(std::iter::repeat(0.0).take(row));
                        y.push(0);
                        wt.push(0.0);
                    }
                }
                EvalBatch { x, y, wt }
            })
            .collect();
        EvalPlan {
            batches,
            boundaries,
        }
    }

    /// The sample range `[b·e, min((b+1)·e, len))` of every batch.
    pub fn slice_boundaries(len: usize, eval_batch: usize) -> Vec<(usize, usize)> {
        let e = eval_batch.max(1);
        let nb = len.div_ceil(e);
        (0..nb).map(|b| (b * e, ((b + 1) * e).min(len))).collect()
    }

    pub fn boundaries(&self) -> &[(usize, usize)] {
        &self.boundaries
    }

    pub fn num_batches(&self) -> usize {
        self.batches.len()
    }
}

/// Run pre-sliced eval batch `b` of `plan`.
fn eval_planned_batch(
    engine: &EngineHandle,
    w: &[f32],
    plan: &EvalPlan,
    b: usize,
) -> Result<(f64, f64, f64)> {
    let batch = &plan.batches[b];
    engine.eval_batch(w, batch.x.clone(), batch.y.clone(), batch.wt.clone())
}

/// Evaluate `w` over a pre-sliced [`EvalPlan`], fanning the batches out
/// across the engine pool.
///
/// Batches are dispatched concurrently in chunks of `workers` scoped
/// threads (each blocks inside the pool's queue at `Eval` priority, so
/// device-level concurrency is governed by the pool and queued training
/// work is served first), and the per-batch `(loss_sum, correct, weight)`
/// triples are reduced **in ascending batch order**.  Each batch is a
/// pure function of its inputs and the f64 reduction order is fixed, so
/// the result is bit-identical to the sequential path (`workers = 1`) at
/// any worker count.
pub fn evaluate_plan(
    engine: &EngineHandle,
    w: &[f32],
    plan: &EvalPlan,
    workers: usize,
) -> Result<(f64, f64)> {
    let nb = plan.batches.len();
    let workers = workers.max(1);

    let mut parts: Vec<(f64, f64, f64)> = Vec::with_capacity(nb);
    if workers == 1 {
        for b in 0..nb {
            parts.push(eval_planned_batch(engine, w, plan, b)?);
        }
    } else {
        for chunk_start in (0..nb).step_by(workers) {
            let chunk_end = (chunk_start + workers).min(nb);
            let outs: Vec<Result<(f64, f64, f64)>> = std::thread::scope(|scope| {
                let handles: Vec<_> = (chunk_start..chunk_end)
                    .map(|b| {
                        let h = engine.clone();
                        scope.spawn(move || eval_planned_batch(&h, w, plan, b))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
                    .collect()
            });
            for out in outs {
                parts.push(out?);
            }
        }
    }

    let mut loss_sum = 0.0;
    let mut correct = 0.0;
    let mut weight = 0.0;
    for (ls, c, wsum) in parts {
        loss_sum += ls;
        correct += c;
        weight += wsum;
    }
    if weight == 0.0 {
        return Ok((f64::NAN, f64::NAN));
    }
    Ok((loss_sum / weight, correct / weight))
}

/// Evaluate `w` over `data` in fixed-size weighted eval batches (slices
/// built on the fly; the coordinator's round loop uses its hoisted
/// [`EvalPlan`] instead).
pub fn evaluate_model(
    engine: &EngineHandle,
    w: &[f32],
    data: &Dataset,
    workers: usize,
) -> Result<(f64, f64)> {
    let plan = EvalPlan::new(data, engine.meta());
    evaluate_plan(engine, w, &plan, workers)
}
