//! Small self-contained substrates (the offline build has no serde):
//! a JSON parser for the AOT manifest, a TOML-subset parser for
//! experiment configs, and the binary codec the event journal's records
//! and snapshots are framed with.

pub mod bytes;
pub mod json;
pub mod toml;
