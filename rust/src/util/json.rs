//! Minimal JSON parser — enough for `artifacts/manifest.json`.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are decoded
//! as-is (the manifest is ASCII).  No serialization beyond what
//! [`Value::render`] needs for metrics output.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that errors with the full path context.
    pub fn expect(&self, key: &str) -> Result<&Value, ParseError> {
        self.get(key)
            .ok_or_else(|| ParseError(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization (metrics files).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Value::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse failure with a short description and byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError(pub String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.bytes.len());
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_render() {
        let src = r#"{"a":[1,2.5,"x"],"b":true,"c":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"caf\u{e9} \\u0041\"").unwrap();
        assert_eq!(v.as_str(), Some("café A"));
    }
}
