//! Differential suite for the PR-10 blocked reference kernels.
//!
//! [`KernelMode::Blocked`] (the default float epoch: blocked logits via
//! fixed-order 8-lane partial accumulators) is checked against the
//! retained [`KernelMode::PerSample`] oracle (the seed-era scalar
//! loops) on full coordinator trajectories: losses and accuracy must
//! agree within float-reassociation tolerance, never bit-for-bit — and
//! the blocked path must itself hold the repo's determinism contract,
//! bit-identical across `num_workers` × `agg_shards` × `pipeline_depth`.

use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool_with_mode, KernelMode, ModelMeta};

const INPUT_SHAPE: [usize; 3] = [4, 4, 1]; // row 16
const CLASSES: usize = 10;

fn meta() -> ModelMeta {
    // dim = 10 * (16 + 1) = 170
    reference_meta(&INPUT_SHAPE, CLASSES, 4, 8, 2)
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "reference-kernels".into();
    cfg.model = "reference-linear".into();
    cfg.algorithm = "fedadam-ssm".into();
    cfg.participation_mode = ParticipationMode::Uniform;
    cfg.rounds = 4;
    cfg.devices = 3;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 2;
    cfg.lr = 0.02;
    cfg.train_samples = 96;
    cfg.test_samples = 50; // ragged final eval batch: pads every eval
    cfg.seed = 7;
    cfg.eval_every = 1;
    cfg.warmup_rounds = 2;
    cfg.num_workers = 2;
    cfg.agg_shards = 0;
    cfg
}

fn run(cfg: ExperimentConfig, mode: KernelMode) -> (ExperimentLog, Vec<f32>, Vec<f32>, Vec<f32>) {
    let pool = reference_pool_with_mode(meta(), cfg.num_workers, mode).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg, pool).expect("coordinator");
    let log = coord.run().expect("run");
    let gs = coord.global();
    (log, gs.w.clone(), gs.m.clone(), gs.v.clone())
}

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn blocked_trajectory_tracks_the_per_sample_oracle() {
    // The two float epochs differ only in the association order of the
    // logit dot products, so full training trajectories must stay close
    // — a kernel bug (wrong lane, dropped tail, bad block boundary)
    // diverges by orders of magnitude, while legitimate reassociation
    // noise stays in the low decimals over 4 rounds of this model.
    let (log_b, w_b, _, _) = run(base_cfg(), KernelMode::Blocked);
    let (log_p, w_p, _, _) = run(base_cfg(), KernelMode::PerSample);
    assert_eq!(log_b.rounds.len(), log_p.rounds.len());
    for (a, b) in log_b.rounds.iter().zip(&log_p.rounds) {
        assert!(a.train_loss.is_finite() && b.train_loss.is_finite());
        assert!(
            rel_close(a.train_loss, b.train_loss, 0.05),
            "round {}: train loss diverged: {} vs {}",
            a.round,
            a.train_loss,
            b.train_loss
        );
        assert!(
            rel_close(a.test_loss, b.test_loss, 0.05),
            "round {}: test loss diverged: {} vs {}",
            a.round,
            a.test_loss,
            b.test_loss
        );
        // 50 test samples: each argmax flip moves accuracy by 0.02.
        assert!(
            (a.test_accuracy - b.test_accuracy).abs() <= 0.2,
            "round {}: accuracy diverged: {} vs {}",
            a.round,
            a.test_accuracy,
            b.test_accuracy
        );
        // The ledger prices wire bits, not floats: both epochs must
        // charge exactly the same bits every round.
        assert_eq!(a.uplink_bits, b.uplink_bits, "round {}", a.round);
        assert_eq!(a.downlink_bits, b.downlink_bits, "round {}", a.round);
    }
    // Final models agree lane-by-lane within reassociation tolerance.
    assert_eq!(w_b.len(), w_p.len());
    for (i, (a, b)) in w_b.iter().zip(&w_p).enumerate() {
        assert!(
            (a - b).abs() <= 0.05 * (1.0 + a.abs().max(b.abs())),
            "final W lane {i} diverged: {a} vs {b}"
        );
    }
}

#[test]
fn blocked_path_is_bit_identical_across_workers_shards_depth() {
    // The new epoch inherits the full determinism contract: blocked
    // kernels are pure functions of their arguments, so every logged
    // number and the final (W, M, V) are byte-identical at any
    // (num_workers, agg_shards, pipeline_depth).
    let run_with = |workers: usize, shards: usize, depth: usize| {
        let mut cfg = base_cfg();
        cfg.rounds = 5;
        cfg.eval_every = 2;
        cfg.participation = 0.75; // exercise the sampler path too
        cfg.num_workers = workers;
        cfg.agg_shards = shards;
        cfg.pipeline_depth = depth;
        run(cfg, KernelMode::Blocked)
    };
    let (log1, w1, m1, v1) = run_with(1, 1, 0);
    for (workers, shards, depth) in [(2, 1, 0), (1, 4, 1), (3, 7, 2), (2, 170, 3)] {
        let (log, w, m, v) = run_with(workers, shards, depth);
        let tag = format!("({workers}w/{shards}s/d{depth})");
        assert_eq!(w1, w, "{tag}: global W diverged");
        assert_eq!(m1, m, "{tag}: global M diverged");
        assert_eq!(v1, v, "{tag}: global V diverged");
        assert_eq!(log1.rounds.len(), log.rounds.len());
        for (a, b) in log1.rounds.iter().zip(&log.rounds) {
            let tag = format!("{tag} round {}", a.round);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{tag}");
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "{tag}");
            assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits(), "{tag}");
            assert_eq!(a.uplink_bits, b.uplink_bits, "{tag}");
            assert_eq!(a.downlink_bits, b.downlink_bits, "{tag}");
            assert_eq!(a.update_norm.to_bits(), b.update_norm.to_bits(), "{tag}");
        }
    }
}

#[test]
fn per_sample_oracle_is_itself_reproducible() {
    // The retired epoch stays a valid oracle only if it is still a pure
    // function of its inputs: two independent runs must be bit-identical.
    let (log_a, w_a, _, _) = run(base_cfg(), KernelMode::PerSample);
    let (log_b, w_b, _, _) = run(base_cfg(), KernelMode::PerSample);
    assert_eq!(w_a, w_b);
    for (a, b) in log_a.rounds.iter().zip(&log_b.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits());
        assert_eq!(a.uplink_bits, b.uplink_bits);
    }
}
