"""ResNet-18 (GroupNorm variant) for SVHN-shaped inputs (paper §VII-A).

Paper description: "a 2x2 convolutional layer, two pooling layers, eight
residual units (each with two 3x3 convolutional layers), a fully connected
layer, and a final softmax output layer" — i.e. standard ResNet-18 with the
CIFAR-style 3x3 stem.  BatchNorm is replaced by GroupNorm so the federated
state is exactly (W, M, V) — no running statistics to aggregate
(DESIGN.md §Substitutions).

``scale`` divides the stage widths (``scale=8`` -> ``resnet_mini``).
"""

from __future__ import annotations

import jax

from compile.models.common import (
    Model,
    ParamSpec,
    avg_pool_global,
    conv2d,
    dense,
    group_norm,
    max_pool,
)

# (width, stride) per residual unit; standard ResNet-18: 4 stages x 2 units.
_UNITS = ((64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2), (512, 1))


def make_resnet(scale=1, name="resnet18", input_shape=(32, 32, 3), classes=10):
    """Build ResNet-18/GroupNorm with stage widths divided by ``scale``."""
    specs = []
    stem = max(4, 64 // scale)
    cin = input_shape[2]
    specs.append(ParamSpec("stem/kernel", (3, 3, cin, stem), "he"))
    specs.append(ParamSpec("stem/bias", (stem,), "zeros"))
    specs.append(ParamSpec("stem/gn_scale", (1, 1, 1, stem), "ones"))
    specs.append(ParamSpec("stem/gn_bias", (1, 1, 1, stem), "zeros"))

    cin = stem
    unit_meta = []  # (width, stride, has_proj)
    for ui, (w0, stride) in enumerate(_UNITS):
        w = max(4, w0 // scale)
        has_proj = stride != 1 or cin != w
        p = f"unit{ui}"
        specs.append(ParamSpec(f"{p}/conv1/kernel", (3, 3, cin, w), "he"))
        specs.append(ParamSpec(f"{p}/conv1/bias", (w,), "zeros"))
        specs.append(ParamSpec(f"{p}/gn1_scale", (1, 1, 1, w), "ones"))
        specs.append(ParamSpec(f"{p}/gn1_bias", (1, 1, 1, w), "zeros"))
        specs.append(ParamSpec(f"{p}/conv2/kernel", (3, 3, w, w), "he"))
        specs.append(ParamSpec(f"{p}/conv2/bias", (w,), "zeros"))
        specs.append(ParamSpec(f"{p}/gn2_scale", (1, 1, 1, w), "ones"))
        specs.append(ParamSpec(f"{p}/gn2_bias", (1, 1, 1, w), "zeros"))
        if has_proj:
            specs.append(ParamSpec(f"{p}/proj/kernel", (1, 1, cin, w), "he"))
            specs.append(ParamSpec(f"{p}/proj/bias", (w,), "zeros"))
        unit_meta.append((w, stride, has_proj))
        cin = w

    specs.append(ParamSpec("fc/kernel", (cin, classes), "he"))
    specs.append(ParamSpec("fc/bias", (classes,), "zeros"))
    specs = tuple(specs)
    meta = tuple(unit_meta)

    def apply(flat, x):
        model = _self[0]
        params = model.unflatten(flat)
        i = 0

        def take(n):
            nonlocal i
            out = params[i : i + n]
            i += n
            return out

        k, b, gs, gb = take(4)
        y = conv2d(x, k, b)
        y = jax.nn.relu(group_norm(y, gs, gb))
        y = max_pool(y)  # first pooling layer (paper: "two pooling layers")

        for w, stride, has_proj in meta:
            k1, b1, g1s, g1b, k2, b2, g2s, g2b = take(8)
            shortcut = y
            z = conv2d(y, k1, b1, stride=stride)
            z = jax.nn.relu(group_norm(z, g1s, g1b))
            z = conv2d(z, k2, b2)
            z = group_norm(z, g2s, g2b)
            if has_proj:
                pk, pb = take(2)
                shortcut = conv2d(y, pk, pb, stride=stride)
            y = jax.nn.relu(z + shortcut)

        y = avg_pool_global(y)  # second pooling layer
        fk, fb = take(2)
        return dense(y, fk, fb)

    model = Model(name=name, specs=specs, apply=apply, input_shape=input_shape, num_classes=classes)
    _self = [model]
    return model
