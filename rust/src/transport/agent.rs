//! The device-agent side of the wire: connect, register, train the
//! devices this agent owns, upload compressed deltas.
//!
//! One agent process hosts a *shard* of the device population: agent
//! `i` of `n` owns every device with `device % n == i`.  Each round the
//! server broadcasts the full cohort ([`Msg::RoundStart`]); the agent
//! filters down to its own slots, runs local training through the same
//! executor seam the in-process coordinator uses, compresses through
//! the same algorithm implementations, and uploads one
//! [`Msg::Uplink`] per slot.
//!
//! ## Bit-identity
//!
//! A remote run reproduces the in-process run byte for byte because
//! every input to a device's round is identical:
//!
//! - the data shards come from [`crate::coordinator::build_task_and_devices`] —
//!   the *same* synthetic generation + partition the coordinator runs,
//!   seeded by the shared config (the fingerprint handshake refuses a
//!   drifted config before any training happens);
//! - local training is a pure function of `(w, m₀, v₀, run_cfg, shard)`;
//! - all per-device compression state (error-feedback memories, moment
//!   residuals) lives with the device's *owning agent*, and ownership is
//!   static — so each device sees exactly the state history it would
//!   have seen in process, regardless of how agents interleave.
//!
//! ## Duplicate rounds
//!
//! After a connection drop the server replays the current round's
//! `RoundStart` on reconnect.  Retraining would mutate error-feedback
//! state twice and break bit-identity, so the agent caches the encoded
//! uplink frames of its latest round and replays them verbatim for a
//! duplicate round number.  (A *fresh process* reconnecting mid-run is
//! only bit-identical for stateless algorithms with `Aggregated`
//! moments — stateful compressors live and die with their process.)

use std::io::Write;

use anyhow::{bail, ensure, Context, Result};

use crate::algorithms::{self, LocalDelta, MomentumPolicy};
use crate::config::ExperimentConfig;
use crate::coordinator::{build_task_and_devices, compress_wire_with, local_run_cfg};
use crate::runtime::{EnginePool, Manifest};
use crate::tensor;

use super::frame::{read_frame, write_frame, FrameError};
use super::msg::{Msg, Uplink, PROTOCOL_VERSION};
use super::net::Stream;

/// [`run_agent`] with the engine pool built from AOT artifacts — the
/// `device-agent` binary's entry point.  Worker resolution mirrors
/// [`crate::coordinator::Coordinator::new`]; the worker count has no
/// bearing on the bits produced (each device's round is a pure function
/// of its inputs).
pub fn run_agent_from_artifacts(
    cfg: &ExperimentConfig,
    artifacts: impl AsRef<std::path::Path>,
    addr: &str,
    index: usize,
) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let workers = crate::runtime::pool::resolve_workers(cfg.num_workers).min(cfg.devices);
    let pool = EnginePool::load(&manifest, &cfg.model, workers)
        .with_context(|| format!("loading model {:?}", cfg.model))?;
    run_agent(cfg, &pool, addr, index)
}

/// Connect to the server at `addr`, register as agent `index`, and
/// serve rounds until the server sends [`Msg::Shutdown`].
pub fn run_agent(
    cfg: &ExperimentConfig,
    pool: &EnginePool,
    addr: &str,
    index: usize,
) -> Result<()> {
    cfg.validate()?;
    let meta = pool.meta().clone();
    let mut stream = Stream::connect(addr)?;
    write_frame(
        &mut stream,
        &Msg::Hello {
            version: PROTOCOL_VERSION,
            fingerprint: cfg.fingerprint(),
            agent: index as u32,
        }
        .encode(),
    )
    .map_err(|e| anyhow::anyhow!("sending Hello: {e}"))?;
    let ack = read_frame(&mut stream).map_err(|e| anyhow::anyhow!("reading HelloAck: {e}"))?;
    let Msg::HelloAck { agents, dim } = Msg::decode(&ack)? else {
        bail!("expected HelloAck");
    };
    let agents = agents as usize;
    ensure!(index < agents, "agent index {index} out of range ({agents} agents)");
    ensure!(
        dim as usize == meta.dim,
        "model dimension mismatch: server says {dim}, local model has {}",
        meta.dim
    );
    log::info!("agent {index}/{agents} registered with {addr} (dim {dim})");

    // The agent's world: the same devices, algorithm state and run
    // config the in-process coordinator would build from this config.
    let (_task, mut devices) = build_task_and_devices(cfg, pool);
    let mut algorithm = algorithms::build(cfg, meta.dim)?;
    let mut device_moments: Vec<(Vec<f32>, Vec<f32>)> = (0..cfg.devices)
        .map(|_| (vec![0.0f32; meta.dim], vec![0.0f32; meta.dim]))
        .collect();
    let run_cfg = local_run_cfg(cfg);
    let handle = pool.handle();

    // The latest round's encoded uplink frames, replayed verbatim if the
    // server re-sends that round (see the module docs).
    let mut cached: Option<(u64, Vec<Vec<u8>>)> = None;

    loop {
        let payload = match read_frame(&mut stream) {
            Ok(p) => p,
            Err(FrameError::Closed) => bail!("server closed the connection without Shutdown"),
            Err(e) => bail!("reading from server: {e}"),
        };
        match Msg::decode(&payload).context("decoding server message")? {
            Msg::RoundStart { round, w, m, v, assignments } => {
                if let Some((r, frames)) = &cached {
                    if *r == round {
                        log::info!("agent {index}: replaying cached uplinks for round {round}");
                        for frame in frames {
                            stream.write_all(frame)?;
                        }
                        stream.flush()?;
                        continue;
                    }
                }
                let t = round as usize;
                let mode = algorithm.local_mode(t);
                let policy = algorithm.momentum_policy(t);
                let mut frames: Vec<Vec<u8>> = Vec::new();
                for a in assignments.iter().filter(|a| a.device as usize % agents == index) {
                    let di = a.device as usize;
                    ensure!(
                        di < devices.len(),
                        "assignment names device {di} but only {} exist",
                        devices.len()
                    );
                    let (m0, v0) = match policy {
                        MomentumPolicy::Aggregated => {
                            let m = m
                                .as_ref()
                                .context("Aggregated moments missing from RoundStart")?;
                            let v = v
                                .as_ref()
                                .context("Aggregated moments missing from RoundStart")?;
                            (m.clone(), v.clone())
                        }
                        MomentumPolicy::DeviceLocal => device_moments[di].clone(),
                    };
                    let result =
                        devices[di].train_round(mode, w.clone(), m0.clone(), v0.clone(), &run_cfg)?;
                    let delta = LocalDelta {
                        dw: tensor::sub(&result.w, &w),
                        dm: tensor::sub(&result.m, &m0),
                        dv: tensor::sub(&result.v, &v0),
                        weight: a.weight,
                    };
                    let mean_loss = result.mean_loss;
                    if policy == MomentumPolicy::DeviceLocal {
                        device_moments[di] = (result.m, result.v);
                    }
                    let wire = compress_wire_with(cfg, &handle, algorithm.as_mut(), t, di, delta)?;
                    let body = wire.encode_body()?;
                    let msg = Msg::Uplink(Uplink {
                        round,
                        slot: a.slot,
                        device: a.device,
                        mean_loss,
                        weight: wire.weight,
                        kind: wire.body.kind(),
                        k: wire.body.k() as u64,
                        levels: wire.body.levels(),
                        bits: wire.bits,
                        body,
                    });
                    let mut frame = Vec::new();
                    write_frame(&mut frame, &msg.encode())
                        .expect("Vec<u8> writes cannot fail");
                    stream.write_all(&frame)?;
                    frames.push(frame);
                }
                stream.flush()?;
                cached = Some((round, frames));
            }
            Msg::Shutdown => {
                log::info!("agent {index}: server sent Shutdown, exiting");
                return Ok(());
            }
            other => bail!("unexpected message from server: {other:?}"),
        }
    }
}
