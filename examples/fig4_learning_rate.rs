//! Fig. 4 reproduction: FedAdam-SSM accuracy for different learning rates η.
//!
//! The paper's finding (and Remark 7): small η converges slowly, large η
//! destabilizes — the sweet spot sits in between.  η is a *runtime* scalar
//! input to the AOT programs, so the whole sweep reuses one compiled
//! artifact set.
//!
//! ```text
//! cargo run --release --example fig4_learning_rate -- [--quick]
//! ```

use anyhow::Result;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    let quick = cli.flag("quick");

    let sweep: Vec<f64> = match cli.opt("lrs") {
        Some(s) => s.split(',').map(|x| x.trim().parse().unwrap()).collect(),
        None => {
            if quick {
                vec![1e-3, 1e-1]
            } else {
                vec![1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 2e-1]
            }
        }
    };

    let mut base = ExperimentConfig::default();
    base.model = cli.opt_or("model", "cnn_small").to_string();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 5 } else { 15 });
    base.devices = if quick { 3 } else { 6 };
    base.train_samples = if quick { 512 } else { 2048 };
    base.test_samples = if quick { 128 } else { 512 };
    base.local_epochs = 2;

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("lr,best_acc,final_loss\n");
    println!("{:>10} {:>10} {:>12}", "lr", "best acc", "final loss");
    for &lr in &sweep {
        let mut cfg = base.clone();
        cfg.lr = lr;
        cfg.name = format!("fig4_lr{lr}");
        let mut coord = Coordinator::new(cfg, artifacts)?;
        let log = coord.run()?;
        let final_loss = log.rounds.last().unwrap().train_loss;
        println!("{:>10} {:>10.3} {:>12.4}", lr, log.best_accuracy(), final_loss);
        csv.push_str(&format!("{lr},{:.4},{final_loss:.4}\n", log.best_accuracy()));
        log.write_csv(format!("results/fig4_lr{lr}.csv"))?;
    }
    std::fs::write("results/fig4_summary.csv", csv)?;
    println!("\nwrote results/fig4_summary.csv");
    Ok(())
}
