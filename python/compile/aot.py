"""AOT compiler: lower every Layer-2 program to HLO text + manifest.

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts \
        --models cnn_small,vgg_mini,resnet_mini [--batch 32] [--eval-batch 256]

Emits one ``<prog>_<model>.hlo.txt`` per (program, model) and a
``manifest.json`` the rust runtime reads to learn shapes, parameter counts
and artifact paths.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly.  Programs are lowered with ``return_tuple=True``; the rust side
unwraps with ``to_tupleN``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import train
from compile.models import get_model

DEFAULT_MODELS = "mlp_tiny,cnn_small"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tuplize(fn):
    """Wrap so the output is a flat tuple (stable rust-side unwrap order)."""

    def wrapped(*args):
        out = fn(*args)
        return tuple(out) if isinstance(out, (tuple, list)) else (out,)

    return wrapped


def lower_program(fn, example_args):
    return jax.jit(_tuplize(fn)).lower(*example_args)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def export_model(model_name: str, out_dir: str, batch: int, eval_batch: int, epoch_batches: int):
    """Export all programs for one model; returns its manifest entry."""
    model = get_model(model_name)
    d = model.dim
    ish = model.input_shape
    flat = f32(d)
    programs = {
        "init": (train.make_init(model), (i32(),)),
        "train": (
            train.make_train_step(model),
            (flat, flat, flat, f32(batch, *ish), i32(batch), f32()),
        ),
        "epoch": (
            train.make_epoch_step(model, epoch_batches),
            (flat, flat, flat, f32(epoch_batches, batch, *ish), i32(epoch_batches, batch), f32()),
        ),
        "eval": (
            train.make_eval(model),
            (flat, f32(eval_batch, *ish), i32(eval_batch), f32(eval_batch)),
        ),
        "sgd": (train.make_sgd_step(model), (flat, f32(batch, *ish), i32(batch), f32())),
        "grads": (train.make_grads(model), (flat, f32(batch, *ish), i32(batch))),
        "sparsify": (train.make_sparsify(), (flat, flat, flat, i32())),
    }
    artifacts = {}
    for prog, (fn, args) in programs.items():
        fname = f"{prog}_{model_name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = to_hlo_text(lower_program(fn, args))
        with open(path, "w") as f:
            f.write(text)
        artifacts[prog] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"  {fname}: {len(text)} chars", file=sys.stderr)
    return {
        "dim": d,
        "input_shape": list(ish),
        "num_classes": model.num_classes,
        "batch": batch,
        "eval_batch": eval_batch,
        "epoch_batches": epoch_batches,
        "params": [{"name": s.name, "shape": list(s.shape)} for s in model.specs],
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=DEFAULT_MODELS)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=256)
    ap.add_argument("--epoch-batches", type=int, default=4)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text/v1",
        "adam": {"beta1": train.BETA1, "beta2": train.BETA2, "eps": train.EPS},
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        print(f"[aot] exporting {name}", file=sys.stderr)
        manifest["models"][name] = export_model(
            name, args.out_dir, args.batch, args.eval_batch, args.epoch_batches
        )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest with {len(manifest['models'])} models", file=sys.stderr)


if __name__ == "__main__":
    main()
