"""Pure-jnp oracles for every Layer-1 kernel.

These are the CORE correctness signal: each Pallas kernel in this package
must agree with its oracle to float32 tolerance across the shape/dtype sweep
in ``python/tests/test_kernels.py`` (hypothesis drives the sweep).  The
oracles are deliberately written as straight-line jnp — no Pallas, no
blocking, no padding — so a disagreement always implicates the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def adam_update_ref(w, m, v, g, eta, beta1=0.9, beta2=0.999, eps=1e-6):
    """Paper eq. 3-5 (eps inside the sqrt, no bias correction)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    w_new = w - eta * m_new / jnp.sqrt(v_new + eps)
    return w_new, m_new, v_new


def topk_threshold_ref(x, k):
    """k-th largest |x| via a full sort."""
    mag = jnp.abs(x)
    k = int(k)
    k = max(1, min(k, x.shape[0]))
    return jnp.sort(mag)[::-1][k - 1]


def topk_mask_ref(x, k):
    """Binary mask keeping every element with |x| >= (k-th largest |x|)."""
    tau = topk_threshold_ref(x, k)
    return (jnp.abs(x) >= tau).astype(jnp.float32)


def ssm_sparsify3_ref(dw, dm, dv, k):
    """Eq. 10-12 with the optimal SSM of eq. 28 (mask from |dw|)."""
    mask = topk_mask_ref(dw, k)
    return dw * mask, dm * mask, dv * mask


def onebit_quantize_ref(x, err):
    """Error-compensated sign quantization (1-bit Adam compressor)."""
    c = x + err
    scale = jnp.mean(jnp.abs(c))
    q = jnp.where(c >= 0.0, scale, -scale)
    return q, c - q


def uniform_quantize_ref(x, s_levels):
    """Deterministic s-level uniform quantization on [-max|x|, max|x|]."""
    scale = jnp.max(jnp.abs(x))
    levels = jnp.float32(s_levels) - 1.0
    safe = jnp.maximum(scale, 1e-30)
    t = jnp.clip(x / safe, -1.0, 1.0)
    q = jnp.round((t + 1.0) * 0.5 * levels)
    deq = (q / levels * 2.0 - 1.0) * safe
    return jnp.where(scale > 0.0, deq, jnp.zeros_like(x))
