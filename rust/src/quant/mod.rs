//! Quantizers for the baseline algorithms (mirrors of the Layer-1 kernels).
//!
//! - [`onebit`] — error-compensated sign quantization (1-bit Adam [29]);
//! - [`uniform`] — s-level uniform quantization (Efficient-Adam [28]);
//! - [`sparse_uniform`] — s-level quantization of the SSM's kept lanes
//!   (the FedAdam-SSM-Q composition: one shared mask, three packed
//!   `k·ceil(log₂ s)`-bit value lists, three f32 scales).
//!
//! All come with real bit-packing so the algorithms pay (and we account)
//! their true wire cost, plus an [`ErrorFeedback`] memory shared by the
//! error-compensated variants.

pub mod onebit;
pub mod sparse_uniform;
pub mod uniform;

pub use onebit::{onebit_compress, onebit_decompress, try_onebit_decompress, OneBitPacket};
pub use sparse_uniform::{
    sparse_uniform_compress, sparse_uniform_decompress, ssm_q_decode, ssm_q_encode,
    try_sparse_uniform_decompress, try_ssm_q_decode, SparseUniformPacket, SsmQUplink,
};
pub use uniform::{try_uniform_decompress, uniform_compress, uniform_decompress, UniformPacket};

/// Per-device error-feedback memory `e_t` (residual accumulator).
#[derive(Clone, Debug, Default)]
pub struct ErrorFeedback {
    pub residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> Self {
        ErrorFeedback {
            residual: vec![0.0; dim],
        }
    }

    /// `x + e` — the compensated input to the compressor.
    pub fn compensate(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.residual.len());
        x.iter().zip(&self.residual).map(|(a, b)| a + b).collect()
    }

    /// Store `compensated - quantized` for the next round.
    pub fn update(&mut self, compensated: &[f32], quantized: &[f32]) {
        for ((r, &c), &q) in self.residual.iter_mut().zip(compensated).zip(quantized) {
            *r = c - q;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_feedback_accumulates_residual() {
        let mut ef = ErrorFeedback::new(3);
        let x = vec![1.0, -2.0, 0.5];
        let c = ef.compensate(&x);
        assert_eq!(c, x);
        let q = vec![1.5, -1.5, 1.5]; // pretend quantizer
        ef.update(&c, &q);
        assert_eq!(ef.residual, vec![-0.5, -0.5, -1.0]);
        let c2 = ef.compensate(&x);
        assert_eq!(c2, vec![0.5, -2.5, -0.5]);
    }
}
