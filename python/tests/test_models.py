"""Layer-2 model zoo: shapes, initialization, gradients, trainability."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.models import REGISTRY, get_model
from compile.models.common import softmax_xent

SMALL = ["mlp_tiny", "cnn_small", "vgg_mini", "resnet_mini"]
FULL = ["cnn", "vgg11", "resnet18"]

# Reference parameter counts: cnn/vgg11/resnet18 must match the real
# architectures (vgg11 CIFAR ~9.75M, resnet18 ~11.2M).
EXPECTED_DIMS = {
    "mlp_tiny": 2410,
    "cnn_small": 54_314,
    "cnn": 1_663_370,
    "vgg11": 9_750_922,
    "resnet18": 11_176_970,
}


@pytest.mark.parametrize("name", SMALL)
def test_forward_shapes(name):
    m = get_model(name)
    w = m.init_flat(jax.random.PRNGKey(0))
    assert w.shape == (m.dim,)
    x = jnp.zeros((3,) + m.input_shape, jnp.float32)
    logits = m.apply(w, x)
    assert logits.shape == (3, m.num_classes)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", list(EXPECTED_DIMS))
def test_param_counts(name):
    assert get_model(name).dim == EXPECTED_DIMS[name]


@pytest.mark.parametrize("name", SMALL)
def test_init_deterministic_and_seed_sensitive(name):
    m = get_model(name)
    a = m.init_flat(jax.random.PRNGKey(7))
    b = m.init_flat(jax.random.PRNGKey(7))
    c = m.init_flat(jax.random.PRNGKey(8))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("name", SMALL)
def test_unflatten_roundtrip(name):
    m = get_model(name)
    w = m.init_flat(jax.random.PRNGKey(1))
    parts = m.unflatten(w)
    assert len(parts) == len(m.specs)
    for p, s in zip(parts, m.specs):
        assert p.shape == s.shape, s.name
    flat_again = jnp.concatenate([p.reshape(-1) for p in parts])
    np.testing.assert_array_equal(np.asarray(flat_again), np.asarray(w))


@pytest.mark.parametrize("name", SMALL)
def test_gradients_flow_to_all_params(name):
    """No dead parameters: every tensor gets nonzero gradient signal."""
    m = get_model(name)
    w = m.init_flat(jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8,) + m.input_shape), jnp.float32)
    y = jnp.asarray(rng.integers(0, m.num_classes, 8), jnp.int32)

    g = jax.grad(lambda w: softmax_xent(m.apply(w, x), y))(w)
    assert bool(jnp.isfinite(g).all())
    parts = m.unflatten(g)
    for p, s in zip(parts, m.specs):
        # Norm-layer biases can be tiny but must not be identically zero.
        assert float(jnp.abs(p).max()) > 0.0, f"dead parameter {s.name}"


def test_registry_complete():
    for name in SMALL + FULL:
        assert name in REGISTRY
    with pytest.raises(KeyError):
        get_model("not-a-model")


def test_mlp_overfits_tiny_task():
    """Sanity: a few hundred full-batch Adam steps drive loss near zero."""
    from compile import train

    m = get_model("mlp_tiny")
    step = jax.jit(train.make_train_step(m))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32,) + m.input_shape), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 32), jnp.int32)
    w = m.init_flat(jax.random.PRNGKey(3))
    mm = jnp.zeros_like(w)
    vv = jnp.zeros_like(w)
    losses = []
    for _ in range(150):
        w, mm, vv, loss = step(w, mm, vv, x, y, jnp.float32(0.01))
        losses.append(float(loss))
    assert losses[-1] < 0.1, f"failed to overfit: {losses[::30]}"
    assert losses[-1] < losses[0] / 10
