//! FedSGD baseline: plain local SGD, dense ΔW uplink, no moments.

use super::{Aggregate, Algorithm, LocalDelta, LocalMode, Recon, Upload};
use crate::sparse::codec::cost;

pub struct FedSgd {
    dim: usize,
}

impl FedSgd {
    pub fn new(dim: usize) -> Self {
        FedSgd { dim }
    }
}

impl Algorithm for FedSgd {
    fn name(&self) -> &'static str {
        "fedsgd"
    }

    fn local_mode(&self, _round: usize) -> LocalMode {
        LocalMode::Sgd
    }

    fn compress(&mut self, _round: usize, _device: usize, delta: LocalDelta) -> Upload {
        Upload {
            dw: Recon::Dense(delta.dw),
            dm: None,
            dv: None,
            weight: delta.weight,
            bits: cost::fedsgd_dense(self.dim),
        }
    }

    fn downlink_bits(&self, _agg: &Aggregate) -> u64 {
        cost::fedsgd_dense(self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_mode_and_cost() {
        let mut a = FedSgd::new(64);
        assert_eq!(a.local_mode(0), LocalMode::Sgd);
        let delta = LocalDelta {
            dw: vec![1.0; 64],
            dm: vec![0.0; 64],
            dv: vec![0.0; 64],
            weight: 1.0,
        };
        let up = a.compress(0, 0, delta);
        assert_eq!(up.bits, 64 * 32);
        assert!(up.dm.is_none() && up.dv.is_none());
    }
}
