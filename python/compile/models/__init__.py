"""Layer-2 model zoo (paper §VII-A workloads).

Every model is a :class:`compile.models.common.Model`: a named list of
parameter specs plus a pure ``apply(flat_params, x) -> logits`` function.
All parameters live in ONE flat ``f32[d]`` vector so the rust runtime's ABI
is a plain buffer; the unflatten happens inside the traced function and is
free after XLA fusion.

Registry
--------
- ``cnn`` / ``cnn_small``        paper's Fashion-MNIST CNN (2x conv5x5 + 2 FC)
- ``vgg11`` / ``vgg_mini``       VGG-11 for CIFAR-10-shaped inputs
- ``resnet18`` / ``resnet_mini`` ResNet-18 (GroupNorm variant) for SVHN-shaped inputs
- ``mlp_tiny``                   2-layer MLP used by fast unit tests

The ``*_small`` / ``*_mini`` variants shrink channel widths so the CPU +
interpret-mode-Pallas testbed trains in minutes; the full-size definitions
are identical code with the paper's widths (DESIGN.md §Substitutions).
"""

from compile.models.common import Model, ParamSpec
from compile.models.cnn import make_cnn, make_mlp_tiny
from compile.models.vgg import make_vgg
from compile.models.resnet import make_resnet

REGISTRY = {
    "mlp_tiny": lambda: make_mlp_tiny(),
    "cnn_small": lambda: make_cnn(width=(8, 16), hidden=64, name="cnn_small"),
    "cnn": lambda: make_cnn(width=(32, 64), hidden=512, name="cnn"),
    "vgg_mini": lambda: make_vgg(scale=8, name="vgg_mini"),
    "vgg11": lambda: make_vgg(scale=1, name="vgg11"),
    "resnet_mini": lambda: make_resnet(scale=8, name="resnet_mini"),
    "resnet18": lambda: make_resnet(scale=1, name="resnet18"),
}


def get_model(name: str) -> Model:
    """Instantiate a model from the registry by name."""
    try:
        return REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown model {name!r}; have {sorted(REGISTRY)}") from None


__all__ = ["Model", "ParamSpec", "REGISTRY", "get_model"]
