//! Kill-at-round-k / resume conformance on the pure-Rust reference
//! backend (no PJRT artifacts needed).
//!
//! The contract under test, across `pipeline_depth ∈ {0, 1, 2}` ×
//! `{fedadam-ssm, fedadam-ssm-qef}` × `{uniform, importance}`:
//!
//! - a journaled run killed mid-experiment and resumed from its journal
//!   finishes with a final global model and per-round CSV **byte-identical**
//!   to the same run never interrupted (host-time `wall_secs` excluded —
//!   it is the one column outside the determinism contract);
//! - journaling is pure observation: a journaled run is bit-identical to
//!   an unjournaled one;
//! - a journal with no durable snapshot yet resumes by re-executing from
//!   round 0 under the replay oracle.
//!
//! The kill point (3 completed rounds, `snapshot_every = 2`) lands one
//! round past the newest snapshot, so every resume exercises both the
//! snapshot restore and tail replay; at `pipeline_depth = 2` the snapshot
//! carries an in-flight overlapped eval, exercising the re-launch path.

use std::path::PathBuf;

use fedadam_ssm::config::{ExperimentConfig, ParticipationMode};
use fedadam_ssm::coordinator::{Coordinator, RunState};
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool, EnginePool};

const INPUT: [usize; 3] = [4, 4, 1]; // row 16, dim = 4 * 17 = 68
const CLASSES: usize = 4;

fn grid_cfg(depth: usize, algo: &str, mode: ParticipationMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "resume-conformance".into();
    cfg.model = "reference-linear".into();
    cfg.algorithm = algo.into();
    cfg.rounds = 6;
    cfg.devices = 3;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 2;
    cfg.train_samples = 192;
    cfg.test_samples = 64;
    cfg.eval_every = 2; // mixes EvalSkipped rounds into the event stream
    cfg.seed = 11;
    cfg.participation = 0.75; // exercise the sampler cursor snapshot
    cfg.participation_mode = mode;
    cfg.simtime = true; // the clock state must survive the snapshot too
    cfg.pipeline_depth = depth;
    cfg.snapshot_every = 2;
    cfg.num_workers = 2;
    // CI lane pinning: FEDADAM_PIPELINE_DEPTH / FEDADAM_NUM_WORKERS etc.
    // collapse the in-test grid onto the lane's point (same idiom as the
    // conformance and e2e base configs).
    cfg.apply_env_overrides();
    cfg
}

fn pool_for(cfg: &ExperimentConfig) -> EnginePool {
    let meta = reference_meta(&INPUT, CLASSES, 8, 16, 1);
    reference_pool(meta, cfg.num_workers).expect("reference pool")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedadam-resume-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The CSV with host time zeroed: `wall_secs` is real elapsed time and is
/// deliberately outside the replay/determinism contract (it is likewise
/// excluded from every journal event).
fn csv_no_wall(log: &ExperimentLog) -> String {
    let mut log = log.clone();
    for r in &mut log.rounds {
        r.wall_secs = 0.0;
    }
    log.to_csv()
}

fn run_uninterrupted(cfg: ExperimentConfig) -> (ExperimentLog, Vec<f32>) {
    let pool = pool_for(&cfg);
    let mut coord = Coordinator::with_pool(cfg, pool).expect("coordinator");
    let log = coord.run().expect("run");
    let w = coord.global().w.clone();
    (log, w)
}

#[test]
fn kill_and_resume_is_bit_identical_across_the_grid() {
    for depth in [0usize, 1, 2] {
        for algo in ["fedadam-ssm", "fedadam-ssm-qef"] {
            for mode in [ParticipationMode::Uniform, ParticipationMode::Importance] {
                let tag = format!("{depth}-{algo}-{mode:?}");

                // Ground truth: the same experiment, never interrupted,
                // journaling off.
                let (base_log, base_w) = run_uninterrupted(grid_cfg(depth, algo, mode));

                // Journaled run, "killed" after 3 completed rounds (the
                // drop abandons any in-flight overlapped eval, exactly
                // like a crash would — its result must not be needed).
                let dir = tmp_dir(&tag);
                let mut cfg = grid_cfg(depth, algo, mode);
                cfg.journal = dir.to_string_lossy().into_owned();
                let pool = pool_for(&cfg);
                let mut coord = Coordinator::with_pool(cfg, pool).expect("journaled coordinator");
                for _ in 0..3 {
                    coord.step_round().expect("pre-kill round");
                }
                assert_eq!(coord.run_state(), RunState::WaitingForCohort);
                assert_eq!(coord.round(), 3);
                drop(coord);
                assert!(dir.join("journal.log").is_file(), "{tag}: no event log");
                assert!(dir.join("snapshot_2.bin").is_file(), "{tag}: no snapshot");

                // Resume from the journal and finish the experiment.
                let mut cfg = grid_cfg(depth, algo, mode);
                cfg.resume = dir.to_string_lossy().into_owned();
                let pool = pool_for(&cfg);
                let mut resumed = Coordinator::with_pool(cfg, pool).expect("resumed coordinator");
                assert!(resumed.round() >= 3, "{tag}: resume lost completed rounds");
                let resumed_log = resumed.run().expect("resumed run");
                let resumed_w = resumed.global().w.clone();

                assert_eq!(base_w, resumed_w, "{tag}: final weights diverged");
                assert_eq!(
                    csv_no_wall(&base_log),
                    csv_no_wall(&resumed_log),
                    "{tag}: per-round CSV diverged"
                );
                std::fs::remove_dir_all(&dir).ok();
            }
        }
    }
}

#[test]
fn journaling_is_pure_observation() {
    for depth in [0usize, 2] {
        let tag = format!("pure-{depth}");
        let (off_log, off_w) =
            run_uninterrupted(grid_cfg(depth, "fedadam-ssm", ParticipationMode::Uniform));

        let dir = tmp_dir(&tag);
        let mut cfg = grid_cfg(depth, "fedadam-ssm", ParticipationMode::Uniform);
        cfg.journal = dir.to_string_lossy().into_owned();
        let (on_log, on_w) = run_uninterrupted(cfg);

        assert_eq!(off_w, on_w, "depth {depth}: journaling changed the model");
        assert_eq!(
            csv_no_wall(&off_log),
            csv_no_wall(&on_log),
            "depth {depth}: journaling changed the log"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn resume_before_any_snapshot_replays_from_round_zero() {
    let (base_log, base_w) =
        run_uninterrupted(grid_cfg(1, "fedadam-ssm", ParticipationMode::Uniform));

    let dir = tmp_dir("nosnap");
    let mut cfg = grid_cfg(1, "fedadam-ssm", ParticipationMode::Uniform);
    cfg.snapshot_every = 100; // never due within 6 rounds
    cfg.journal = dir.to_string_lossy().into_owned();
    let pool = pool_for(&cfg);
    let mut coord = Coordinator::with_pool(cfg, pool).expect("journaled coordinator");
    coord.step_round().expect("pre-kill round");
    drop(coord);

    let mut cfg = grid_cfg(1, "fedadam-ssm", ParticipationMode::Uniform);
    cfg.snapshot_every = 100;
    cfg.resume = dir.to_string_lossy().into_owned();
    let pool = pool_for(&cfg);
    let mut resumed = Coordinator::with_pool(cfg, pool).expect("resumed coordinator");
    let resumed_log = resumed.run().expect("resumed run");
    let resumed_w = resumed.global().w.clone();

    assert_eq!(base_w, resumed_w, "weights diverged");
    assert_eq!(csv_no_wall(&base_log), csv_no_wall(&resumed_log), "CSV diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resuming_a_finished_run_is_a_noop_with_the_same_results() {
    let dir = tmp_dir("finished");
    let mut cfg = grid_cfg(2, "fedadam-ssm", ParticipationMode::Uniform);
    cfg.journal = dir.to_string_lossy().into_owned();
    let (full_log, full_w) = run_uninterrupted(cfg);

    let mut cfg = grid_cfg(2, "fedadam-ssm", ParticipationMode::Uniform);
    cfg.resume = dir.to_string_lossy().into_owned();
    let pool = pool_for(&cfg);
    let mut resumed = Coordinator::with_pool(cfg, pool).expect("resumed coordinator");
    let resumed_log = resumed.run().expect("resumed run");
    let resumed_w = resumed.global().w.clone();

    assert_eq!(full_w, resumed_w, "weights diverged");
    assert_eq!(csv_no_wall(&full_log), csv_no_wall(&resumed_log), "CSV diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kill_with_spilled_residuals_on_disk_resumes_bit_identically() {
    // The out-of-core tiering must survive a crash: cap the EF residual
    // store below the device count so that, at the kill point, at least
    // one device's residual lives ONLY in the spill file — then resume
    // and require byte-identity with a never-interrupted, never-spilling
    // (cap = 0) ground truth.  Snapshots serialize touched entries
    // id-keyed regardless of tier, so placement cannot leak into them.
    let tag = "spilled";
    let spill = tmp_dir("spill-store");
    std::fs::create_dir_all(&spill).expect("spill dir");

    // Ground truth: dense residuals, no journal, never interrupted.
    let (base_log, base_w) =
        run_uninterrupted(grid_cfg(2, "fedadam-ssm-ef", ParticipationMode::Uniform));

    // Journaled run with a 2-entry cap across 3 devices.
    let dir = tmp_dir(tag);
    let mut cfg = grid_cfg(2, "fedadam-ssm-ef", ParticipationMode::Uniform);
    cfg.journal = dir.to_string_lossy().into_owned();
    cfg.residual_resident_cap = 2;
    cfg.residual_spill_dir = spill.to_string_lossy().into_owned();
    let pool = pool_for(&cfg);
    let mut coord = Coordinator::with_pool(cfg, pool).expect("journaled coordinator");
    for _ in 0..3 {
        coord.step_round().expect("pre-kill round");
    }
    assert_eq!(coord.run_state(), RunState::WaitingForCohort);
    assert_eq!(coord.round(), 3);
    let spilled_files = std::fs::read_dir(&spill)
        .expect("spill dir readable")
        .count();
    assert!(
        spilled_files > 0,
        "kill point must have residuals on disk for this test to mean anything"
    );
    drop(coord); // the "crash" — also removes the store's spill files
    assert!(dir.join("snapshot_2.bin").is_file(), "no snapshot at the kill");

    // Resume under the same cap and finish.
    let mut cfg = grid_cfg(2, "fedadam-ssm-ef", ParticipationMode::Uniform);
    cfg.resume = dir.to_string_lossy().into_owned();
    cfg.residual_resident_cap = 2;
    cfg.residual_spill_dir = spill.to_string_lossy().into_owned();
    let pool = pool_for(&cfg);
    let mut resumed = Coordinator::with_pool(cfg, pool).expect("resumed coordinator");
    assert!(resumed.round() >= 3, "resume lost completed rounds");
    let resumed_log = resumed.run().expect("resumed run");
    let resumed_w = resumed.global().w.clone();

    assert_eq!(base_w, resumed_w, "spilled-residual resume diverged from dense ground truth");
    assert_eq!(
        csv_no_wall(&base_log),
        csv_no_wall(&resumed_log),
        "spilled-residual resume CSV diverged"
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&spill).ok();
}
