//! s-level uniform quantization — the Efficient-Adam compressor [28].
//!
//! Deterministic rounding over `[-max|x|, max|x|]` with `s` representable
//! levels; wire format is `ceil(log2 s)` bits per lane + one f32 scale.
//! Matches `compile/kernels/quantize.py::uniform_quantize`.

use crate::sparse::codec::{index_bits, BitPacker, BitUnpacker};

/// Packed s-level payload.
#[derive(Clone, Debug)]
pub struct UniformPacket {
    pub dim: usize,
    pub scale: f32,
    pub levels: u32,
    pub codes: Vec<u8>,
}

impl UniformPacket {
    /// Wire size: `d * ceil(log2 s)` bits + 32-bit scale.
    pub fn wire_bits(&self) -> u64 {
        self.dim as u64 * index_bits(self.levels as usize + 1) + 32
    }
}

/// Quantize to `s_levels` representable values (`s_levels >= 2`).
pub fn uniform_compress(x: &[f32], s_levels: u32) -> UniformPacket {
    assert!(s_levels >= 2, "need at least 2 levels");
    let levels = s_levels - 1; // number of bins
    let scale = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let bits = index_bits(s_levels as usize);
    let mut packer = BitPacker::with_capacity(x.len() * bits as usize);
    let safe = scale.max(1e-30);
    for &v in x {
        let t = (v / safe).clamp(-1.0, 1.0);
        let q = ((t + 1.0) * 0.5 * levels as f32).round() as u64;
        packer.push(q, bits);
    }
    UniformPacket {
        dim: x.len(),
        scale,
        levels,
        codes: packer.finish(),
    }
}

/// Dequantize.
pub fn uniform_decompress(p: &UniformPacket) -> Vec<f32> {
    dequantize_codes(&p.codes, p.dim, p.scale, p.levels)
}

/// Unpack `n` codes and map them back onto the s-level grid — the shared
/// back half of the dense ([`uniform_decompress`]) and sparse
/// (`super::sparse_uniform`) decompressors, so the grid math lives once.
pub(crate) fn dequantize_codes(codes: &[u8], n: usize, scale: f32, levels: u32) -> Vec<f32> {
    if scale == 0.0 {
        // All inputs were exactly 0.0 — reconstruct them exactly.
        return vec![0.0; n];
    }
    let bits = index_bits(levels as usize + 1);
    let mut u = BitUnpacker::new(codes);
    (0..n)
        .map(|_| {
            let q = u.pull(bits) as f32;
            (q / levels as f32 * 2.0 - 1.0) * scale
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_by_bin_width() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        for &s in &[2u32, 4, 16, 256] {
            let p = uniform_compress(&x, s);
            let y = uniform_decompress(&p);
            let bin = 2.0 * p.scale / (s - 1) as f32;
            for (xi, yi) in x.iter().zip(&y) {
                assert!(
                    (xi - yi).abs() <= bin / 2.0 + 1e-5,
                    "s={s} x={xi} y={yi} bin={bin}"
                );
            }
        }
    }

    #[test]
    fn zero_vector() {
        let p = uniform_compress(&[0.0; 16], 16);
        assert_eq!(p.scale, 0.0);
        assert_eq!(uniform_decompress(&p), vec![0.0; 16]);
    }

    #[test]
    fn wire_bits_counts_levels() {
        let x = vec![1.0f32; 64];
        let p = uniform_compress(&x, 16); // 4 bits per lane
        assert_eq!(p.wire_bits(), 64 * 4 + 32);
        let p2 = uniform_compress(&x, 2); // 1 bit per lane
        assert_eq!(p2.wire_bits(), 64 + 32);
    }

    #[test]
    fn extremes_map_to_extremes() {
        let x = vec![-3.0f32, 3.0, 0.0];
        let p = uniform_compress(&x, 3); // levels at -3, 0, +3
        let y = uniform_decompress(&p);
        assert_eq!(y, vec![-3.0, 3.0, 0.0]);
    }
}
