//! Wire encodings and the paper's bit-cost model (§IV, §VII-A).
//!
//! Positions of non-zeros can be sent either as a `d`-bit **bitmask** or as
//! `k` indices of `ceil(log2 d)` bits each; the experiments use
//! `min{...}` of the two (paper §VII-A *Implementation*).  Values are `q`
//! = 32-bit floats.  This module provides both the **cost model** (used by
//! every algorithm's accounting) and real encoders/decoders so the wire
//! format is exercised, not just priced.

use super::SparseVec;

/// Floating-point precision `q` in bits (paper uses f32).
pub const Q: u64 = 32;

/// `ceil(log2 d)` — bits to address one coordinate.
pub fn index_bits(dim: usize) -> u64 {
    if dim <= 1 {
        1
    } else {
        (usize::BITS - (dim - 1).leading_zeros()) as u64
    }
}

/// Which position encoding `min{}` picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskEncoding {
    /// `d` bits, one per coordinate.
    Bitmap,
    /// `k * ceil(log2 d)` bits.
    IndexList,
}

/// Cost in bits of transmitting the positions of `k` non-zeros out of `d`.
pub fn mask_bits(dim: usize, k: usize) -> (u64, MaskEncoding) {
    let bitmap = dim as u64;
    let index = k as u64 * index_bits(dim);
    if bitmap <= index {
        (bitmap, MaskEncoding::Bitmap)
    } else {
        (index, MaskEncoding::IndexList)
    }
}

/// Uplink bits for ONE device/round under each scheme of §IV + §VII-A.
pub mod cost {
    use super::{index_bits, Q};

    /// Standard FedAdam (Algorithm 1): three dense vectors — `3dq`.
    pub fn fedadam_dense(d: usize) -> u64 {
        3 * d as u64 * Q
    }

    /// FedAdam-Top: three sparse vectors, three masks —
    /// `min{3(kq+d), 3k(q+log2 d)}`.
    pub fn fedadam_top(d: usize, k: usize) -> u64 {
        let bitmap = 3 * (k as u64 * Q + d as u64);
        let index = 3 * k as u64 * (Q + index_bits(d));
        bitmap.min(index)
    }

    /// SSM family (FedAdam-SSM / SSM_M / SSM_V / Fairness-Top): three sparse
    /// value lists, ONE mask — `min{3kq+d, k(3q+log2 d)}`.
    pub fn fedadam_ssm(d: usize, k: usize) -> u64 {
        let bitmap = 3 * k as u64 * Q + d as u64;
        let index = k as u64 * (3 * Q + index_bits(d));
        bitmap.min(index)
    }

    /// FedSGD: one dense vector — `dq`.
    pub fn fedsgd_dense(d: usize) -> u64 {
        d as u64 * Q
    }

    /// 1-bit Adam compression phase: 1 bit per lane + one f32 scale.
    pub fn onebit(d: usize) -> u64 {
        d as u64 + Q
    }

    /// Efficient-Adam with `s`-level uniform quantization:
    /// `ceil(log2 s)` bits per lane + one f32 scale.
    pub fn uniform(d: usize, s_levels: usize) -> u64 {
        d as u64 * index_bits(s_levels) + Q
    }
}

/// A bit-exact encoded sparse vector (positions + f32 payloads).
#[derive(Clone, Debug)]
pub struct EncodedSparse {
    pub dim: usize,
    pub encoding: MaskEncoding,
    /// Packed position bits (bitmap or index list).
    pub positions: Vec<u8>,
    /// Raw little-endian f32 payloads, `k` of them.
    pub payload: Vec<u8>,
    pub k: usize,
}

impl EncodedSparse {
    /// Total size on the wire in bits.
    pub fn wire_bits(&self) -> u64 {
        let (pos_bits, _) = mask_bits_for(self.encoding, self.dim, self.k);
        pos_bits + self.payload.len() as u64 * 8
    }
}

fn mask_bits_for(enc: MaskEncoding, dim: usize, k: usize) -> (u64, MaskEncoding) {
    match enc {
        MaskEncoding::Bitmap => (dim as u64, enc),
        MaskEncoding::IndexList => (k as u64 * index_bits(dim), enc),
    }
}

/// Encode with the cheaper position encoding.
pub fn encode(sv: &SparseVec) -> EncodedSparse {
    let (_, enc) = mask_bits(sv.dim, sv.nnz());
    let positions = match enc {
        MaskEncoding::Bitmap => {
            let mut bytes = vec![0u8; sv.dim.div_ceil(8)];
            for &i in &sv.indices {
                bytes[i as usize / 8] |= 1 << (i % 8);
            }
            bytes
        }
        MaskEncoding::IndexList => {
            let bits = index_bits(sv.dim);
            let mut packer = BitPacker::with_capacity(sv.nnz() * bits as usize);
            for &i in &sv.indices {
                packer.push(i as u64, bits);
            }
            packer.finish()
        }
    };
    let mut payload = Vec::with_capacity(sv.nnz() * 4);
    for &v in &sv.values {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    EncodedSparse {
        dim: sv.dim,
        encoding: enc,
        positions,
        payload,
        k: sv.nnz(),
    }
}

/// Decode back to a [`SparseVec`].
pub fn decode(es: &EncodedSparse) -> SparseVec {
    let indices: Vec<u32> = match es.encoding {
        MaskEncoding::Bitmap => {
            let mut out = Vec::with_capacity(es.k);
            for i in 0..es.dim {
                if es.positions[i / 8] & (1 << (i % 8)) != 0 {
                    out.push(i as u32);
                }
            }
            out
        }
        MaskEncoding::IndexList => {
            let bits = index_bits(es.dim);
            let mut unpacker = BitUnpacker::new(&es.positions);
            (0..es.k).map(|_| unpacker.pull(bits) as u32).collect()
        }
    };
    let values = es
        .payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    SparseVec {
        dim: es.dim,
        indices,
        values,
    }
}

/// LSB-first bit packer used by the index-list encoding and quantizers.
pub struct BitPacker {
    bytes: Vec<u8>,
    bitpos: usize,
}

impl BitPacker {
    pub fn with_capacity(bits: usize) -> Self {
        BitPacker {
            bytes: Vec::with_capacity(bits.div_ceil(8)),
            bitpos: 0,
        }
    }

    /// Append the low `n` bits of `v` (byte-at-a-time, not bit-at-a-time —
    /// the quantizer hot path packs d×log₂s bits per upload; §Perf L3).
    pub fn push(&mut self, v: u64, n: u64) {
        debug_assert!(n <= 64);
        let mut v = if n == 64 { v } else { v & ((1u64 << n) - 1) };
        let mut remaining = n;
        while remaining > 0 {
            let off = self.bitpos % 8;
            if off == 0 {
                self.bytes.push(0);
            }
            let take = (8 - off).min(remaining as usize) as u64;
            let last = self.bytes.last_mut().unwrap();
            *last |= ((v & ((1u64 << take) - 1)) as u8) << off;
            v >>= take;
            self.bitpos += take as usize;
            remaining -= take;
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.bytes
    }
}

/// Matching LSB-first unpacker.
pub struct BitUnpacker<'a> {
    bytes: &'a [u8],
    bitpos: usize,
}

impl<'a> BitUnpacker<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitUnpacker { bytes, bitpos: 0 }
    }

    /// Read the next `n` bits (byte-at-a-time, mirroring `push`).
    pub fn pull(&mut self, n: u64) -> u64 {
        debug_assert!(n <= 64);
        let mut out = 0u64;
        let mut got = 0u64;
        while got < n {
            let off = self.bitpos % 8;
            let take = (8 - off).min((n - got) as usize) as u64;
            let byte = self.bytes[self.bitpos / 8] as u64;
            let bits = (byte >> off) & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            self.bitpos += take as usize;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::top_k_indices;

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(1024), 10);
        assert_eq!(index_bits(1025), 11);
    }

    #[test]
    fn mask_encoding_crossover() {
        // Small k: index list wins. Large k: bitmap wins.
        let d = 1 << 20;
        let (_, enc_small) = mask_bits(d, 10);
        assert_eq!(enc_small, MaskEncoding::IndexList);
        let (_, enc_large) = mask_bits(d, d / 2);
        assert_eq!(enc_large, MaskEncoding::Bitmap);
    }

    #[test]
    fn ssm_cheaper_than_top_cheaper_than_dense() {
        // The paper's headline: O(3dq) -> O(3kq+3d) -> O(3kq+d).
        for &(d, alpha) in &[(100_000usize, 0.05f64), (1_000_000, 0.01)] {
            let k = (d as f64 * alpha) as usize;
            let dense = cost::fedadam_dense(d);
            let top = cost::fedadam_top(d, k);
            let ssm = cost::fedadam_ssm(d, k);
            assert!(ssm < top, "ssm {ssm} !< top {top}");
            assert!(top < dense, "top {top} !< dense {dense}");
        }
    }

    #[test]
    fn encode_decode_roundtrip_both_encodings() {
        let mut rng = Rng::new(11);
        for &d in &[64usize, 1000, 4096] {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            for &k in &[1usize, d / 100 + 1, d / 2, d] {
                let idx = top_k_indices(&x, k);
                let sv = SparseVec::gather(&x, &idx);
                let es = encode(&sv);
                let back = decode(&es);
                assert_eq!(back, sv, "d={d} k={k} enc={:?}", es.encoding);
            }
        }
    }

    #[test]
    fn wire_bits_matches_cost_model() {
        let d = 10_000;
        let k = 500;
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let idx = top_k_indices(&x, k);
        let sv = SparseVec::gather(&x, &idx);
        let es = encode(&sv);
        let (pos_bits, _) = mask_bits(d, k);
        assert_eq!(es.wire_bits(), pos_bits + k as u64 * Q);
    }

    #[test]
    fn bitpacker_roundtrip() {
        let mut p = BitPacker::with_capacity(0);
        let vals = [(5u64, 3u64), (1023, 10), (0, 1), (77, 7)];
        for &(v, n) in &vals {
            p.push(v, n);
        }
        let bytes = p.finish();
        let mut u = BitUnpacker::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(u.pull(n), v);
        }
    }
}
