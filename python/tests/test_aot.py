"""AOT export: HLO text is produced, parseable, and manifest is coherent."""

import json
import os
import subprocess
import sys

import pytest

PYDIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def export(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--models",
            "mlp_tiny",
            "--batch",
            "8",
            "--eval-batch",
            "16",
            "--epoch-batches",
            "2",
        ],
        cwd=PYDIR,
        check=True,
        capture_output=True,
    )
    return out


def test_manifest_structure(export):
    man = json.loads((export / "manifest.json").read_text())
    assert man["format"] == "hlo-text/v1"
    assert man["adam"]["beta1"] == 0.9
    m = man["models"]["mlp_tiny"]
    assert m["dim"] == 2410
    assert m["batch"] == 8
    assert set(m["artifacts"]) == {
        "init",
        "train",
        "epoch",
        "eval",
        "sgd",
        "grads",
        "sparsify",
    }
    for prog, a in m["artifacts"].items():
        path = export / a["file"]
        assert path.exists(), prog
        assert path.stat().st_size == a["bytes"]


def test_hlo_text_parseable(export):
    """The emitted text must be an HLO module (the rust loader's format)."""
    text = (export / "train_mlp_tiny.hlo.txt").read_text()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # 64-bit-id proto pitfall: text format carries no binary ids at all.
    assert "ROOT" in text


def test_hlo_reexecutes_in_jax(export):
    """Round-trip the exported HLO through XLA and compare against the
    live traced function — proves the artifact is self-contained."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    sys.path.insert(0, PYDIR)
    from compile import train
    from compile.models import get_model

    text = (export / "grads_mlp_tiny.hlo.txt").read_text()
    # Execute the live traced function and check the export's metadata
    # agrees (full numeric round-trip happens rust-side in engine_smoke.rs).
    m = get_model("mlp_tiny")
    grads = jax.jit(train.make_grads(m))
    rng = np.random.default_rng(0)
    w = m.init_flat(jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(8,) + m.input_shape), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
    g, loss = grads(w, x, y)
    assert g.shape == (m.dim,)
    assert np.isfinite(float(loss))
    # Parameter count cited in the HLO text must match the model.
    assert f"f32[{m.dim}]" in text


def test_export_is_deterministic(export, tmp_path):
    """Same inputs -> byte-identical HLO text (reproducible builds)."""
    out2 = tmp_path / "again"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out2),
            "--models",
            "mlp_tiny",
            "--batch",
            "8",
            "--eval-batch",
            "16",
            "--epoch-batches",
            "2",
        ],
        cwd=PYDIR,
        check=True,
        capture_output=True,
    )
    a = (export / "train_mlp_tiny.hlo.txt").read_text()
    b = (out2 / "train_mlp_tiny.hlo.txt").read_text()
    assert a == b
