//! Durable per-agent compressor state: the crash-safe append log that
//! lets a **fresh device-agent process** resume a stateful run
//! bit-identically.
//!
//! FedAdam-SSM's compressors are stateful *on the device side*:
//! error-feedback residuals, 1-bit warmup counters, and (for
//! `DeviceLocal`-policy ids) per-device Adam moments all accumulate
//! across rounds inside the agent process.  This module persists that
//! state to `<agent_state_dir>/agent_<index>.state` so the state
//! survives the process.
//!
//! ## File format
//!
//! The journal's record framing, reused verbatim: each record is
//! `[len: u32 le][crc32(payload): u32 le][payload]`
//! (see [`crate::coordinator::journal`]).  A torn final record —
//! truncated frame, short payload, or CRC mismatch — is dropped on
//! load, exactly like the journal's event log.  Payloads are tagged:
//!
//! - **Header** (tag 1, always record 0): format version, config
//!   fingerprint, agent index, agent count, model dimension.  A file
//!   whose header disagrees with the opening config is *foreign* and
//!   rejected loudly — resuming someone else's state would silently
//!   break bit-identity.
//! - **State** (tag 2): one [`AgentSnapshot`] — the last completed
//!   round, the algorithm's `save_state` bytes, the device-moment
//!   store's `save_state` bytes, and the round's encoded uplink frames.
//!
//! ## Durability ordering
//!
//! The agent appends one state record per completed round **after
//! training but before sending** that round's uplinks.  That ordering
//! is what makes every crash window safe:
//!
//! - *Crash before the append*: the server saw no frames for the round,
//!   so on reconnect it replays `RoundStart` and the restored agent
//!   (at end-of-previous-round state) retrains it — deterministically
//!   identical, since training mutated nothing durable.
//! - *Crash after the append, before (or during) the send*: the
//!   restored agent holds the round's frames verbatim and replays them
//!   without retraining — retraining would mutate error-feedback state
//!   twice.  Slots the server already accepted treat the replay as a
//!   benign duplicate.
//! - *Crash after the send*: the restored agent is simply at
//!   end-of-round state and continues with the next `RoundStart`.
//!
//! Because the server only ever replays the *current* round, the record
//! cadence must be every round; `snapshot_every` instead controls how
//! often the log is **compacted** (rewritten as header + latest record
//! via a temp file and an atomic rename) so it stays O(state), not
//! O(rounds).  A clean [`Msg::Shutdown`](super::msg::Msg) also
//! compacts.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::util::bytes::{crc32, ByteReader, ByteWriter};

/// Agent state-log format version.  Independent of
/// [`crate::coordinator::journal::JOURNAL_VERSION`]: the file shares the
/// journal's *framing*, not its schema.
pub const AGENT_STATE_VERSION: u32 = 1;

/// Record tags (first payload byte).
const TAG_HEADER: u8 = 1;
const TAG_STATE: u8 = 2;

/// One durable agent checkpoint: everything a fresh process needs to
/// stand exactly where the old one stood at the end of `round`.
#[derive(Clone, Debug, PartialEq)]
pub struct AgentSnapshot {
    /// Last completed round.
    pub round: u64,
    /// The algorithm's `save_state` bytes (error-feedback residuals,
    /// 1-bit warmup, quantizer state, ...).
    pub algorithm: Vec<u8>,
    /// The device-moment `ResidualStore`'s `save_state` bytes
    /// (touched entries only — `Aggregated`-policy runs stay empty).
    pub moments: Vec<u8>,
    /// The round's encoded uplink frames, replayed verbatim if the
    /// server re-sends this round after a reconnect.
    pub frames: Vec<Vec<u8>>,
}

/// The open per-agent state log: appends one framed [`AgentSnapshot`]
/// record per completed round, compacting every `compact_every` appends
/// and on demand (clean shutdown).
pub struct AgentStateLog {
    file: File,
    path: PathBuf,
    /// The encoded header payload (rewritten first on every compaction).
    header: Vec<u8>,
    compact_every: usize,
    /// State records appended since the last compaction (or open).
    records_since_compact: usize,
}

impl AgentStateLog {
    /// Open (or create) `dir/agent_<agent>.state` for agent `agent` of
    /// `agents` under config `fingerprint` / model dimension `dim`.
    ///
    /// Returns the log plus the latest durable [`AgentSnapshot`], if the
    /// file already held one: a fresh process restores it and resumes
    /// bit-identically.  A torn final record is dropped (and truncated
    /// away before the next append); a file whose header names a
    /// different fingerprint/agent/topology/dimension is rejected.
    pub fn open(
        dir: &Path,
        agent: usize,
        agents: usize,
        fingerprint: u64,
        dim: usize,
        compact_every: usize,
    ) -> Result<(AgentStateLog, Option<AgentSnapshot>)> {
        ensure!(compact_every >= 1, "compact_every must be >= 1");
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating agent state dir {}", dir.display()))?;
        let path = dir.join(format!("agent_{agent}.state"));
        let header = encode_header(fingerprint, agent, agents, dim);

        if path.is_file() {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let (payloads, valid_len) = read_records(&bytes);
            let Some(first) = payloads.first() else {
                bail!(
                    "agent state log {} exists but holds no valid records \
                     (not even a header) — refusing to guess; delete it to start fresh",
                    path.display()
                );
            };
            verify_header(first, fingerprint, agent, agents, dim)
                .with_context(|| format!("foreign agent state log {}", path.display()))?;
            let mut latest: Option<AgentSnapshot> = None;
            for payload in &payloads[1..] {
                latest = Some(decode_state(payload).with_context(|| {
                    format!("decoding state record in {}", path.display())
                })?);
            }
            let file = OpenOptions::new()
                .write(true)
                .open(&path)
                .with_context(|| format!("opening {} for append", path.display()))?;
            // Drop the torn tail so new records continue from a
            // checksummed prefix (no-op when the log ended cleanly).
            file.set_len(valid_len)?;
            use std::io::Seek;
            let mut file = file;
            file.seek(std::io::SeekFrom::End(0))?;
            if let Some(snap) = &latest {
                log::info!(
                    "agent {agent}: restored durable state through round {} from {}",
                    snap.round,
                    path.display()
                );
            }
            Ok((
                AgentStateLog {
                    file,
                    path,
                    header,
                    compact_every,
                    // Compact on a fresh cadence; the restored prefix is
                    // already as long as it is.
                    records_since_compact: payloads.len().saturating_sub(1),
                },
                latest,
            ))
        } else {
            let mut file = File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?;
            file.write_all(&frame(&header))?;
            file.flush()?;
            Ok((
                AgentStateLog { file, path, header, compact_every, records_since_compact: 0 },
                None,
            ))
        }
    }

    /// Durably record one completed round *before* its uplinks are sent
    /// (the ordering the module docs prove safe).  Compacts instead of
    /// appending when the cadence is due.
    pub fn append(&mut self, snap: &AgentSnapshot) -> Result<()> {
        if self.records_since_compact + 1 >= self.compact_every {
            return self.compact(snap);
        }
        self.file
            .write_all(&frame(&encode_state(snap)))
            .with_context(|| format!("appending to {}", self.path.display()))?;
        self.file.flush()?;
        self.records_since_compact += 1;
        Ok(())
    }

    /// Rewrite the log as header + `snap` only (temp file + atomic
    /// rename), resetting the compaction cadence.  Called on cadence by
    /// [`AgentStateLog::append`] and directly on clean shutdown.
    pub fn compact(&mut self, snap: &AgentSnapshot) -> Result<()> {
        let tmp = self.path.with_extension("state.tmp");
        let mut out = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        out.write_all(&frame(&self.header))?;
        out.write_all(&frame(&encode_state(snap)))?;
        out.flush()?;
        drop(out);
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("renaming {} over {}", tmp.display(), self.path.display()))?;
        self.file = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("reopening {} after compaction", self.path.display()))?;
        self.records_since_compact = 0;
        Ok(())
    }

    /// The on-disk path (tests peek at it).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Frame one payload exactly like a journal record:
/// `[len u32 le][crc32 u32 le][payload]`.
fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Split a file image into framed payloads, stopping (not erroring) at a
/// torn tail.  Returns the payloads and the byte length of the valid
/// prefix (everything past it is truncated before the next append).
fn read_records(bytes: &[u8]) -> (Vec<Vec<u8>>, u64) {
    let mut payloads = Vec::new();
    let mut pos = 0usize;
    while bytes.len() - pos >= 8 {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if bytes.len() - pos - 8 < len {
            break; // torn: payload shorter than the frame promises
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            break; // torn or corrupt: checksum mismatch
        }
        payloads.push(payload.to_vec());
        pos += 8 + len;
    }
    (payloads, pos as u64)
}

fn encode_header(fingerprint: u64, agent: usize, agents: usize, dim: usize) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u8(TAG_HEADER);
    w.put_u32(AGENT_STATE_VERSION);
    w.put_u64(fingerprint);
    w.put_u32(agent as u32);
    w.put_u32(agents as u32);
    w.put_u64(dim as u64);
    w.into_inner()
}

/// Record 0 must be a header matching this run's identity — anything
/// else means the directory holds state from a different run, a
/// different agent, or a different topology, and resuming from it would
/// silently break bit-identity.
fn verify_header(
    payload: &[u8],
    fingerprint: u64,
    agent: usize,
    agents: usize,
    dim: usize,
) -> Result<()> {
    let mut r = ByteReader::new(payload);
    let tag = r.take_u8()?;
    ensure!(tag == TAG_HEADER, "record 0 has tag {tag}, expected a header");
    let version = r.take_u32()?;
    ensure!(
        version == AGENT_STATE_VERSION,
        "state log format version {version} != supported {AGENT_STATE_VERSION}"
    );
    let fp = r.take_u64()?;
    ensure!(
        fp == fingerprint,
        "config fingerprint {fp:#018x} != this run's {fingerprint:#018x} \
         (a determinism-bearing knob differs)"
    );
    let a = r.take_u32()? as usize;
    ensure!(a == agent, "log belongs to agent {a}, this process is agent {agent}");
    let n = r.take_u32()? as usize;
    ensure!(n == agents, "log written under {n} agents, this run has {agents}");
    let d = r.take_u64()? as usize;
    ensure!(d == dim, "log written for model dim {d}, this model has {dim}");
    r.finish()?;
    Ok(())
}

fn encode_state(snap: &AgentSnapshot) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u8(TAG_STATE);
    w.put_u64(snap.round);
    w.put_bytes(&snap.algorithm);
    w.put_bytes(&snap.moments);
    w.put_usize(snap.frames.len());
    for f in &snap.frames {
        w.put_bytes(f);
    }
    w.into_inner()
}

fn decode_state(payload: &[u8]) -> Result<AgentSnapshot> {
    let mut r = ByteReader::new(payload);
    let tag = r.take_u8()?;
    ensure!(tag == TAG_STATE, "expected a state record, got tag {tag}");
    let round = r.take_u64()?;
    let algorithm = r.take_bytes()?;
    let moments = r.take_bytes()?;
    let n = r.take_usize()?;
    let mut frames = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        frames.push(r.take_bytes()?);
    }
    r.finish()?;
    Ok(AgentSnapshot { round, algorithm, moments, frames })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fedadam-agent-state-ut-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn snap(round: u64) -> AgentSnapshot {
        AgentSnapshot {
            round,
            algorithm: vec![round as u8; 9],
            moments: vec![0xAB, round as u8],
            frames: vec![vec![1, 2, 3], vec![round as u8; 5]],
        }
    }

    #[test]
    fn round_trips_and_restores_the_latest_record() {
        let dir = tmp("roundtrip");
        let (mut log, restored) =
            AgentStateLog::open(&dir, 1, 2, 0xFEED, 170, 100).unwrap();
        assert!(restored.is_none(), "fresh log has nothing to restore");
        log.append(&snap(0)).unwrap();
        log.append(&snap(1)).unwrap();
        log.append(&snap(2)).unwrap();
        drop(log);

        let (_log, restored) = AgentStateLog::open(&dir, 1, 2, 0xFEED, 170, 100).unwrap();
        assert_eq!(restored, Some(snap(2)), "latest record wins");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_falls_back_to_the_previous_record() {
        let dir = tmp("torn");
        let (mut log, _) = AgentStateLog::open(&dir, 0, 1, 7, 10, 100).unwrap();
        log.append(&snap(0)).unwrap();
        log.append(&snap(1)).unwrap();
        let path = log.path().to_path_buf();
        drop(log);

        // Tear the final record mid-payload — the crash window where the
        // round's frames were never sent, so falling back one round is
        // exactly the deterministic-retrain case.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let (mut log, restored) = AgentStateLog::open(&dir, 0, 1, 7, 10, 100).unwrap();
        assert_eq!(restored, Some(snap(0)), "torn record dropped, previous kept");

        // The torn tail was truncated: appending now yields a clean log.
        log.append(&snap(2)).unwrap();
        drop(log);
        let (_log, restored) = AgentStateLog::open(&dir, 0, 1, 7, 10, 100).unwrap();
        assert_eq!(restored, Some(snap(2)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_logs_are_rejected_by_name() {
        let dir = tmp("foreign");
        let (mut log, _) = AgentStateLog::open(&dir, 0, 2, 0xAAAA, 10, 100).unwrap();
        log.append(&snap(0)).unwrap();
        drop(log);

        for (agent, agents, fp, dim, needle) in [
            (0usize, 2usize, 0xBBBBu64, 10usize, "fingerprint"),
            (1, 2, 0xAAAA, 10, "agent"),
            (0, 3, 0xAAAA, 10, "agents"),
            (0, 2, 0xAAAA, 11, "dim"),
        ] {
            // Open the *same file* under a mismatched identity: agent 1
            // gets its own path, so point it at agent 0's file first.
            let err = if agent == 1 {
                std::fs::copy(dir.join("agent_0.state"), dir.join("agent_1.state")).unwrap();
                AgentStateLog::open(&dir, 1, 2, 0xAAAA, 10, 100)
            } else {
                AgentStateLog::open(&dir, agent, agents, fp, dim, 100)
            };
            let msg = format!("{:#}", err.err().expect("foreign log must be rejected"));
            assert!(msg.contains(needle), "error {msg:?} must mention {needle:?}");
            let _ = std::fs::remove_file(dir.join("agent_1.state"));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_keeps_only_header_plus_latest_and_preserves_restore() {
        let dir = tmp("compact");
        // compact_every = 3: appends 0,1 stay, the 3rd triggers a rewrite.
        let (mut log, _) = AgentStateLog::open(&dir, 0, 1, 9, 4, 3).unwrap();
        log.append(&snap(0)).unwrap();
        log.append(&snap(1)).unwrap();
        let before = std::fs::metadata(log.path()).unwrap().len();
        log.append(&snap(2)).unwrap(); // cadence due → compacted
        let after = std::fs::metadata(log.path()).unwrap().len();
        assert!(
            after < before,
            "compaction must shrink the log ({before} -> {after} bytes)"
        );
        let path = log.path().to_path_buf();
        drop(log);

        let bytes = std::fs::read(&path).unwrap();
        let (payloads, valid) = read_records(&bytes);
        assert_eq!(payloads.len(), 2, "header + latest only");
        assert_eq!(valid, bytes.len() as u64, "no torn tail after compaction");
        let (_log, restored) = AgentStateLog::open(&dir, 0, 1, 9, 4, 3).unwrap();
        assert_eq!(restored, Some(snap(2)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn explicit_compact_then_append_continues_cleanly() {
        let dir = tmp("shutdown");
        let (mut log, _) = AgentStateLog::open(&dir, 0, 1, 9, 4, 100).unwrap();
        log.append(&snap(0)).unwrap();
        log.compact(&snap(0)).unwrap(); // the clean-shutdown path
        log.append(&snap(1)).unwrap(); // and the log still appends after
        drop(log);
        let (_log, restored) = AgentStateLog::open(&dir, 0, 1, 9, 4, 100).unwrap();
        assert_eq!(restored, Some(snap(1)));
        std::fs::remove_dir_all(&dir).ok();
    }
}
