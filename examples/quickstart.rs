//! Quickstart: train FedAdam-SSM on the Fashion-MNIST-shaped workload and
//! print the round-by-round accuracy / communication trade-off.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.model = "cnn_small".into(); // the paper's Fashion-MNIST CNN (CPU scale)
    cfg.algorithm = "fedadam-ssm".into();
    cfg.rounds = 15;
    cfg.devices = 4;
    cfg.local_epochs = 2;
    cfg.train_samples = 1024;
    cfg.test_samples = 256;
    cfg.sparsity = 0.05; // α: upload 5% of coordinates per round
    cfg.num_workers = 0; // engine-pool: one PJRT worker per core (bit-identical to 1)
    cfg.agg_shards = 0; // server reduce: one lane shard per worker (bit-identical to 1)
    cfg.pipeline_depth = 2; // pipelined rounds: stream uploads into the server
                            // accumulator + overlap eval with next-round
                            // training (bit-identical to the barrier loop)

    println!("FedAdam-SSM quickstart: {} on {}", cfg.algorithm, cfg.model);
    let mut coord = Coordinator::new(cfg, "artifacts")?;
    println!(
        "{:>5} {:>12} {:>10} {:>14}",
        "round", "train loss", "test acc", "uplink (Mbit)"
    );
    let log = coord.run()?;
    for r in &log.rounds {
        println!(
            "{:>5} {:>12.4} {:>10.3} {:>14.2}",
            r.round,
            r.train_loss,
            r.test_accuracy,
            r.uplink_bits as f64 / 1e6
        );
    }
    println!("\n{}", log.summary());
    println!(
        "dense FedAdam would have used {:.2} Mbit for the same rounds \
         (3dq per device per round)",
        (log.rounds.len() as u64 * 4 * 3 * 54_314 * 32) as f64 / 1e6
    );
    Ok(())
}
