//! FedAdam-SSM-EF — extension: the SSM sparsifier with per-device
//! error-feedback memory (sparsified-SGD-with-memory, the paper's ref [31],
//! applied to the FedAdam-SSM triple).
//!
//! Coordinates dropped by the mask are not lost: their mass accumulates in
//! a per-device residual and is added back to the *next* round's deltas
//! before mask selection.  This is the natural "future work" composition of
//! the paper's SSM with the memory mechanism its related-work section
//! credits for sparse-SGD convergence; the ablation bench
//! (`examples/ablation_ef.rs`) measures what it buys on top of eq. 28.
//!
//! Wire cost is identical to FedAdam-SSM: `min{3kq + d, k(3q + log₂ d)}`.

use anyhow::{ensure, Result};

use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::sparse::codec::cost;
use crate::sparse::{top_k_indices, SparseVec};
use crate::util::bytes::{ByteReader, ByteWriter};

/// Per-device residual memories for the three vectors.
struct Memory {
    w: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
}

pub struct FedAdamSsmEf {
    dim: usize,
    k: usize,
    memory: Vec<Memory>,
}

impl FedAdamSsmEf {
    pub fn new(dim: usize, k: usize, devices: usize) -> Self {
        assert!(k >= 1 && k <= dim);
        FedAdamSsmEf {
            dim,
            k,
            memory: (0..devices)
                .map(|_| Memory {
                    w: vec![0.0; dim],
                    m: vec![0.0; dim],
                    v: vec![0.0; dim],
                })
                .collect(),
        }
    }
}

impl Algorithm for FedAdamSsmEf {
    fn name(&self) -> &'static str {
        "fedadam-ssm-ef"
    }

    fn compress(&mut self, _round: usize, device: usize, delta: LocalDelta) -> Upload {
        let mem = &mut self.memory[device];
        // Compensate: c = delta + residual.
        let cw: Vec<f32> = delta.dw.iter().zip(&mem.w).map(|(a, b)| a + b).collect();
        let cm: Vec<f32> = delta.dm.iter().zip(&mem.m).map(|(a, b)| a + b).collect();
        let cv: Vec<f32> = delta.dv.iter().zip(&mem.v).map(|(a, b)| a + b).collect();
        // SSM from the compensated ΔW (eq. 28 on c_w).
        let idx = top_k_indices(&cw, self.k);
        let sw = SparseVec::gather(&cw, &idx);
        let sm = SparseVec::gather(&cm, &idx);
        let sv = SparseVec::gather(&cv, &idx);
        // Residual = compensated − transmitted.
        mem.w.copy_from_slice(&cw);
        mem.m.copy_from_slice(&cm);
        mem.v.copy_from_slice(&cv);
        for (&i, (&vw, (&vm, &vv))) in idx
            .iter()
            .zip(sw.values.iter().zip(sm.values.iter().zip(sv.values.iter())))
        {
            mem.w[i as usize] -= vw;
            mem.m[i as usize] -= vm;
            mem.v[i as usize] -= vv;
        }
        Upload {
            dw: Recon::Sparse(sw),
            dm: Some(Recon::Sparse(sm)),
            dv: Some(Recon::Sparse(sv)),
            weight: delta.weight,
            bits: cost::fedadam_ssm(self.dim, self.k),
        }
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        // Union support carried through `Aggregate` (see ssm.rs: a recount
        // of non-zeros undercounts on exact-zero cancellation).
        cost::fedadam_ssm(self.dim, agg.dw_support)
    }

    fn save_state(&self, out: &mut ByteWriter) {
        out.put_usize(self.memory.len());
        for mem in &self.memory {
            out.put_f32s(&mem.w);
            out.put_f32s(&mem.m);
            out.put_f32s(&mem.v);
        }
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        let n = input.take_usize()?;
        ensure!(n == self.memory.len(), "snapshot has {n} EF memories, config builds {}", self.memory.len());
        for mem in &mut self.memory {
            mem.w = input.take_f32s()?;
            mem.m = input.take_f32s()?;
            mem.v = input.take_f32s()?;
            ensure!(mem.w.len() == self.dim, "EF memory dim mismatch");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(dw: Vec<f32>) -> LocalDelta {
        let d = dw.len();
        LocalDelta {
            dw,
            dm: vec![0.1; d],
            dv: vec![0.01; d],
            weight: 1.0,
        }
    }

    #[test]
    fn residual_accumulates_and_releases() {
        let mut a = FedAdamSsmEf::new(4, 1, 1);
        // Round 0: [4, 3, 0, 0] -> keep idx 0; residual w = [0, 3, 0, 0].
        let up0 = a.compress(0, 0, delta(vec![4.0, 3.0, 0.0, 0.0]));
        match &up0.dw {
            Recon::Sparse(sv) => {
                assert_eq!(sv.indices, vec![0]);
                assert_eq!(sv.values, vec![4.0]);
            }
            _ => panic!(),
        }
        assert_eq!(a.memory[0].w, vec![0.0, 3.0, 0.0, 0.0]);
        // Round 1: delta [2, 2, 0, 0]; compensated = [2, 5, 0, 0] -> keep 1.
        let up1 = a.compress(1, 0, delta(vec![2.0, 2.0, 0.0, 0.0]));
        match &up1.dw {
            Recon::Sparse(sv) => {
                assert_eq!(sv.indices, vec![1]);
                assert_eq!(sv.values, vec![5.0]);
            }
            _ => panic!(),
        }
        assert_eq!(a.memory[0].w, vec![2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn memories_are_per_device() {
        let mut a = FedAdamSsmEf::new(3, 1, 2);
        a.compress(0, 0, delta(vec![1.0, 2.0, 3.0]));
        assert_eq!(a.memory[0].w, vec![1.0, 2.0, 0.0]);
        assert_eq!(a.memory[1].w, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn same_wire_cost_as_plain_ssm() {
        let mut a = FedAdamSsmEf::new(1000, 50, 1);
        let up = a.compress(0, 0, delta(vec![1.0; 1000]));
        assert_eq!(up.bits, cost::fedadam_ssm(1000, 50));
    }

    #[test]
    fn moment_residuals_tracked_too() {
        let mut a = FedAdamSsmEf::new(2, 1, 1);
        a.compress(0, 0, delta(vec![5.0, 1.0]));
        // dm = [0.1, 0.1]; kept lane 0 -> residual m = [0, 0.1].
        assert!((a.memory[0].m[0]).abs() < 1e-6);
        assert!((a.memory[0].m[1] - 0.1).abs() < 1e-6);
    }
}
