"""Layer-1 Pallas kernels for FedAdam-SSM.

Every kernel here is authored with ``jax.experimental.pallas`` and lowered
with ``interpret=True`` so the resulting HLO runs on any PJRT backend
(including the rust CPU client).  Real-TPU lowering would emit Mosaic
custom-calls that the CPU plugin cannot execute; on TPU these kernels are
compile-only targets and their numerics are validated through the interpret
path against the pure-jnp oracles in :mod:`compile.kernels.ref`.

Kernels
-------
- :func:`adam_update`       fused Adam moment + parameter update (paper eq. 3-5)
- :func:`ssm_sparsify3`     shared-sparse-mask application to (dW, dM, dV) (eq. 10-12)
- :func:`topk_threshold`    k-th largest |x| (the SSM selection rule, eq. 28)
- :func:`onebit_quantize`   sign quantization with error feedback (1-bit Adam baseline)
- :func:`uniform_quantize`  s-level uniform quantization (Efficient-Adam baseline)
"""

from compile.kernels.adam_update import adam_update
from compile.kernels.ssm_sparsify import ssm_sparsify3, apply_mask
from compile.kernels.topk import topk_threshold, topk_mask
from compile.kernels.quantize import onebit_quantize, uniform_quantize

__all__ = [
    "adam_update",
    "ssm_sparsify3",
    "apply_mask",
    "topk_threshold",
    "topk_mask",
    "onebit_quantize",
    "uniform_quantize",
]
