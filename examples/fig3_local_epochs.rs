//! Fig. 3 reproduction: FedAdam-SSM accuracy for different local epochs L.
//!
//! The paper's finding (and Remark 6): accuracy first improves with L
//! (more local progress per round) then degrades (device drift) — a
//! non-monotone trade-off.
//!
//! ```text
//! cargo run --release --example fig3_local_epochs -- [--quick]
//! ```

use anyhow::Result;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    let quick = cli.flag("quick");

    let sweep: Vec<usize> = match cli.opt("epochs") {
        Some(s) => s.split(',').map(|x| x.trim().parse().unwrap()).collect(),
        None => {
            if quick {
                vec![1, 4]
            } else {
                vec![1, 2, 4, 8, 16]
            }
        }
    };

    let mut base = ExperimentConfig::default();
    base.model = cli.opt_or("model", "cnn_small").to_string();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 5 } else { 15 });
    base.devices = if quick { 3 } else { 6 };
    base.train_samples = if quick { 512 } else { 2048 };
    base.test_samples = if quick { 128 } else { 512 };
    base.iid = false;
    base.max_batches_per_epoch = 2;

    std::fs::create_dir_all("results")?;
    let mut csv = String::from("local_epochs,best_acc,final_loss,uplink_mbit\n");
    println!("{:>8} {:>10} {:>12} {:>14}", "L", "best acc", "final loss", "uplink Mbit");
    for &l in &sweep {
        let mut cfg = base.clone();
        cfg.local_epochs = l;
        cfg.name = format!("fig3_L{l}");
        let mut coord = Coordinator::new(cfg, artifacts)?;
        let log = coord.run()?;
        let final_loss = log.rounds.last().unwrap().train_loss;
        let uplink = log.rounds.last().unwrap().uplink_bits as f64 / 1e6;
        println!(
            "{:>8} {:>10.3} {:>12.4} {:>14.2}",
            l,
            log.best_accuracy(),
            final_loss,
            uplink
        );
        csv.push_str(&format!(
            "{l},{:.4},{final_loss:.4},{uplink:.2}\n",
            log.best_accuracy()
        ));
        log.write_csv(format!("results/fig3_L{l}.csv"))?;
    }
    std::fs::write("results/fig3_summary.csv", csv)?;
    println!("\nwrote results/fig3_summary.csv");
    Ok(())
}
