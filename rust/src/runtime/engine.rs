//! The execution engine: a dedicated thread owning the PJRT CPU client and
//! every compiled executable for one model, driven through channels.
//!
//! Why an actor: the `xla` crate's `PjRtClient` / `PjRtLoadedExecutable`
//! wrap raw C pointers (`!Send`), while the coordinator runs device workers
//! on multiple threads.  A single engine thread serializes compute — honest
//! on one CPU — and [`EngineHandle`] is `Clone + Send` so any worker can
//! call into it.  Requests carry a response channel; calls are synchronous
//! from the caller's perspective.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, ModelMeta};

/// Programs a model bundle may expose (mirrors `compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Prog {
    Init,
    Train,
    Epoch,
    Eval,
    Sgd,
    Grads,
    Sparsify,
}

impl Prog {
    pub fn name(self) -> &'static str {
        match self {
            Prog::Init => "init",
            Prog::Train => "train",
            Prog::Epoch => "epoch",
            Prog::Eval => "eval",
            Prog::Sgd => "sgd",
            Prog::Grads => "grads",
            Prog::Sparsify => "sparsify",
        }
    }

    pub const ALL: [Prog; 7] = [
        Prog::Init,
        Prog::Train,
        Prog::Epoch,
        Prog::Eval,
        Prog::Sgd,
        Prog::Grads,
        Prog::Sparsify,
    ];
}

/// One input buffer for a program call.
#[derive(Clone, Debug)]
pub enum Arg {
    /// f32 tensor with explicit dims.
    F32(Vec<f32>, Vec<i64>),
    /// i32 tensor with explicit dims.
    I32(Vec<i32>, Vec<i64>),
    /// f32 scalar.
    ScalarF32(f32),
    /// i32 scalar.
    ScalarI32(i32),
}

impl Arg {
    /// Flat f32 vector (rank 1).
    pub fn vec(v: Vec<f32>) -> Arg {
        let d = v.len() as i64;
        Arg::F32(v, vec![d])
    }
}

type Reply = mpsc::Sender<Result<Vec<Vec<f32>>>>;

enum Request {
    Exec(Prog, Vec<Arg>, Reply),
    Shutdown,
}

/// Handle to the engine thread; cheap to clone, safe to share.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
    meta: ModelMeta,
}

/// Owns the engine thread; dropping shuts it down.
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Load + compile every artifact of `model` from `manifest`.
    ///
    /// Compilation happens on the engine thread before this returns (the
    /// first message is the load result), so errors surface here.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Engine> {
        let meta = manifest.model(model)?.clone();
        let dir = manifest.dir.clone();
        let paths: Vec<(Prog, PathBuf)> = Prog::ALL
            .iter()
            .filter_map(|&p| meta.artifact_path(&dir, p.name()).ok().map(|f| (p, f)))
            .collect();
        if paths.is_empty() {
            return Err(anyhow!("model {model:?} has no artifacts"));
        }

        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("pjrt-engine-{model}"))
            .spawn(move || engine_main(paths, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Engine {
            handle: EngineHandle { tx, meta },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.handle.meta
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(
    paths: Vec<(Prog, PathBuf)>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<Prog, xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut exes = BTreeMap::new();
        for (prog, path) in &paths {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
            exes.insert(*prog, exe);
        }
        Ok((client, exes))
    })();

    let (_client, exes) = match setup {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Exec(prog, args, reply) => {
                let result = run_one(&exes, prog, args);
                let _ = reply.send(result);
            }
        }
    }
}

fn run_one(
    exes: &BTreeMap<Prog, xla::PjRtLoadedExecutable>,
    prog: Prog,
    args: Vec<Arg>,
) -> Result<Vec<Vec<f32>>> {
    let exe = exes
        .get(&prog)
        .ok_or_else(|| anyhow!("program {:?} not loaded", prog.name()))?;
    let literals: Vec<xla::Literal> = args
        .into_iter()
        .map(|a| -> Result<xla::Literal> {
            Ok(match a {
                Arg::F32(v, dims) => xla::Literal::vec1(&v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape f32 {dims:?}: {e}"))?,
                Arg::I32(v, dims) => xla::Literal::vec1(&v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape i32 {dims:?}: {e}"))?,
                Arg::ScalarF32(x) => xla::Literal::scalar(x),
                Arg::ScalarI32(x) => xla::Literal::scalar(x),
            })
        })
        .collect::<Result<_>>()?;
    let out = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("execute {:?}: {e}", prog.name()))?;
    let tuple = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch result: {e}"))?;
    // aot.py lowers with return_tuple=True: always a tuple, even of one.
    let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
    parts
        .into_iter()
        .map(|lit| {
            lit.to_vec::<f32>()
                .map_err(|e| anyhow!("output to f32 vec: {e}"))
        })
        .collect()
}

impl EngineHandle {
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Execute `prog` with `args`; blocks until the engine replies.
    pub fn call(&self, prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request::Exec(prog, args, tx))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    // ---- typed wrappers -------------------------------------------------

    /// `init(seed) -> w0`.
    pub fn init(&self, seed: i32) -> Result<Vec<f32>> {
        let mut out = self.call(Prog::Init, vec![Arg::ScalarI32(seed)])?;
        Ok(out.remove(0))
    }

    /// One minibatch Adam step.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        w: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let b = self.meta.batch as i64;
        let mut dims = vec![b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Train,
            vec![
                Arg::vec(w),
                Arg::vec(m),
                Arg::vec(v),
                Arg::F32(x, dims),
                Arg::I32(y, vec![b]),
                Arg::ScalarF32(eta),
            ],
        )?;
        let loss = out[3][0];
        let v_out = out.remove(2);
        let m_out = out.remove(1);
        let w_out = out.remove(0);
        Ok((w_out, m_out, v_out, loss))
    }

    /// One full epoch (`epoch_batches` scanned batches) in one dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn epoch_step(
        &self,
        w: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let nb = self.meta.epoch_batches as i64;
        let b = self.meta.batch as i64;
        let mut dims = vec![nb, b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Epoch,
            vec![
                Arg::vec(w),
                Arg::vec(m),
                Arg::vec(v),
                Arg::F32(x, dims),
                Arg::I32(y, vec![nb, b]),
                Arg::ScalarF32(eta),
            ],
        )?;
        let loss = out[3][0];
        let v_out = out.remove(2);
        let m_out = out.remove(1);
        let w_out = out.remove(0);
        Ok((w_out, m_out, v_out, loss))
    }

    /// Weighted eval batch: returns `(loss_sum, correct, weight_sum)`.
    pub fn eval_batch(
        &self,
        w: &[f32],
        x: Vec<f32>,
        y: Vec<i32>,
        wt: Vec<f32>,
    ) -> Result<(f64, f64, f64)> {
        let e = self.meta.eval_batch as i64;
        let mut dims = vec![e];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let out = self.call(
            Prog::Eval,
            vec![
                Arg::vec(w.to_vec()),
                Arg::F32(x, dims),
                Arg::I32(y, vec![e]),
                Arg::F32(wt, vec![e]),
            ],
        )?;
        Ok((out[0][0] as f64, out[1][0] as f64, out[2][0] as f64))
    }

    /// FedSGD step.
    pub fn sgd_step(
        &self,
        w: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        eta: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let b = self.meta.batch as i64;
        let mut dims = vec![b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Sgd,
            vec![
                Arg::vec(w),
                Arg::F32(x, dims),
                Arg::I32(y, vec![b]),
                Arg::ScalarF32(eta),
            ],
        )?;
        let loss = out[1][0];
        Ok((out.remove(0), loss))
    }

    /// Minibatch gradient.
    pub fn grads(&self, w: &[f32], x: Vec<f32>, y: Vec<i32>) -> Result<(Vec<f32>, f32)> {
        let b = self.meta.batch as i64;
        let mut dims = vec![b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Grads,
            vec![Arg::vec(w.to_vec()), Arg::F32(x, dims), Arg::I32(y, vec![b])],
        )?;
        let loss = out[1][0];
        Ok((out.remove(0), loss))
    }

    /// The Layer-1 SSM sparsifier (XLA-side alternative to `sparse::topk`).
    pub fn sparsify(
        &self,
        dw: Vec<f32>,
        dm: Vec<f32>,
        dv: Vec<f32>,
        k: i32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut out = self.call(
            Prog::Sparsify,
            vec![Arg::vec(dw), Arg::vec(dm), Arg::vec(dv), Arg::ScalarI32(k)],
        )?;
        let dv_out = out.remove(2);
        let dm_out = out.remove(1);
        let dw_out = out.remove(0);
        Ok((dw_out, dm_out, dv_out))
    }
}
