//! Fig. 1 reproduction: probability density of `log10 |ΔW|, |ΔM|, |ΔV|`.
//!
//! Runs one communication round of local Adam on each available model and
//! prints histogram series of the log-magnitudes of the three update
//! vectors.  The paper's claim this figure supports: `ΔW ≫ ΔM ≫ ΔV`
//! (separated log-normal-looking humps) — the premise for choosing the SSM
//! from `|ΔW|` (eq. 28).
//!
//! ```text
//! cargo run --release --example fig1_density [-- --model cnn_small]
//! ```

use anyhow::Result;
use fedadam_ssm::algorithms::LocalMode;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::coordinator::device::{Device, LocalRunConfig};
use fedadam_ssm::data::{partition, synthetic, Partition, Shard};
use fedadam_ssm::runtime::{Engine, Manifest};
use fedadam_ssm::tensor;

const BINS: usize = 30;

fn histogram(name: &str, deltas: &[f32]) -> (Vec<f64>, f64, f64) {
    let logs: Vec<f64> = deltas
        .iter()
        .filter(|&&x| x != 0.0)
        .map(|&x| (x.abs() as f64).log10())
        .collect();
    let lo = logs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut h = vec![0.0f64; BINS];
    let width = ((hi - lo) / BINS as f64).max(1e-12);
    for &l in &logs {
        let b = (((l - lo) / width) as usize).min(BINS - 1);
        h[b] += 1.0;
    }
    let n: f64 = h.iter().sum();
    for v in &mut h {
        *v /= n * width; // density
    }
    let mean = logs.iter().sum::<f64>() / logs.len() as f64;
    println!(
        "{name}: log10 range [{lo:.2}, {hi:.2}], mean {mean:.2}, n={}",
        logs.len()
    );
    (h, lo, width)
}

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let manifest = Manifest::load(cli.opt_or("artifacts", "artifacts"))?;
    let model = cli.opt_or("model", "cnn_small").to_string();
    let local_epochs: usize = cli.opt_parse("local-epochs")?.unwrap_or(3);

    let engine = Engine::load(&manifest, &model)?;
    let h = engine.handle();
    let meta = h.meta().clone();

    let spec = synthetic::SyntheticSpec::for_input_shape(&meta.input_shape, 2048, 1);
    let task = synthetic::generate(&spec, 7);
    let shards = partition(&task.train, 1, Partition::Iid, 7);
    let mut device = Device::new(0, Shard { data: shards.into_iter().next().unwrap() }, h.clone());

    let w0 = h.init(7)?;
    let zeros = vec![0.0f32; meta.dim];
    let run = LocalRunConfig {
        local_epochs,
        max_batches_per_epoch: 8,
        lr: 0.001,
        use_epoch_program: true,
    };
    // A few rounds of burn-in so moments are warm (the paper plots a
    // mid-training round).
    let mut w = w0.clone();
    let mut m = zeros.clone();
    let mut v = zeros.clone();
    for _ in 0..3 {
        let r = device.train_round(LocalMode::Adam, w.clone(), m.clone(), v.clone(), &run)?;
        w = r.w;
        m = r.m;
        v = r.v;
    }
    let before = (w.clone(), m.clone(), v.clone());
    let r = device.train_round(LocalMode::Adam, w, m, v, &run)?;
    let dw = tensor::sub(&r.w, &before.0);
    let dm = tensor::sub(&r.m, &before.1);
    let dv = tensor::sub(&r.v, &before.2);

    println!("=== Fig. 1 ({model}): density of log10 |Δ| ===");
    let (hw, lw, ww) = histogram("ΔW", &dw);
    let (hm, lm, wm) = histogram("ΔM", &dm);
    let (hv, lv, wv) = histogram("ΔV", &dv);

    println!("\nbin_center_w,density_w,bin_center_m,density_m,bin_center_v,density_v");
    for i in 0..BINS {
        println!(
            "{:.3},{:.4},{:.3},{:.4},{:.3},{:.4}",
            lw + ww * (i as f64 + 0.5),
            hw[i],
            lm + wm * (i as f64 + 0.5),
            hm[i],
            lv + wv * (i as f64 + 0.5),
            hv[i]
        );
    }

    // The figure's claim, checked numerically on medians.
    let med = |x: &[f32]| {
        let mut logs: Vec<f64> = x
            .iter()
            .filter(|&&v| v != 0.0)
            .map(|&v| (v.abs() as f64).log10())
            .collect();
        logs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        logs[logs.len() / 2]
    };
    let (mw, mm, mv) = (med(&dw), med(&dm), med(&dv));
    println!("\nmedians: log10|ΔW| = {mw:.2}, log10|ΔM| = {mm:.2}, log10|ΔV| = {mv:.2}");
    anyhow::ensure!(mw > mm && mm > mv, "expected ΔW ≫ ΔM ≫ ΔV ordering");
    println!("Fig. 1 ordering ΔW > ΔM > ΔV confirmed");
    Ok(())
}
