//! The engine pool: `num_workers` executor threads behind one work queue.
//!
//! Why a pool of actors: the `xla` crate's `PjRtClient` /
//! `PjRtLoadedExecutable` wrap raw C pointers (`!Send`), so compute state
//! can never migrate between threads.  Instead each worker thread builds
//! its **own** client + compiled executables (via an [`Executor`] factory
//! run on the worker thread) and the threads compete over a shared
//! two-class work queue.  [`PoolHandle`] is `Clone + Send`; any caller
//! thread can submit a [`Prog`] call and block on its private reply
//! channel, so the coordinator's per-device training dispatches naturally
//! load-balance across workers.
//!
//! Work classes: every request carries a [`WorkClass`].  Workers always
//! drain `Train` requests before `Eval` requests, so the pipelined round
//! loop can fan an entire eval pass out through the pool *concurrently*
//! with the next round's local-training dispatch without the eval batches
//! starving training.  Within a class, requests are served FIFO.  Priority
//! affects scheduling only — every request is a pure function of its
//! arguments (each worker holds an identical set of compiled executables),
//! so results are bitwise independent of which worker serves a request or
//! in what order requests are queued.  `num_workers = 1` degenerates to
//! the original single-engine actor.
//!
//! Failure model — a call NEVER hangs:
//! - a panic inside an executor is caught on the worker, returned to the
//!   caller as `Err`, and the worker keeps serving;
//! - if every worker dies, the last one to exit closes the queue and drops
//!   the pending requests (closing each reply channel), so both in-flight
//!   and future calls observe `Err` rather than blocking forever.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::engine::{Arg, Prog, XlaExecutor};
use super::manifest::{Manifest, ModelMeta};

/// One worker's compute backend, built on — and confined to — its thread.
///
/// The factory handed to [`EnginePool::with_factory`] runs once per worker
/// thread, so implementations may own `!Send` state (PJRT handles).
pub trait Executor {
    fn execute(&mut self, prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>>;
}

/// Scheduling class of a pool request.
///
/// Two classes are enough for the pipelined round loop: local-training
/// dispatches are latency-critical (the round barrier waits on them),
/// while an overlapped eval pass is throughput work that may only use
/// capacity training leaves idle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkClass {
    /// Latency-critical requests (local training, init, sparsify).
    /// Always served before queued `Eval` work.
    Train,
    /// Overlappable background work (the eval fan-out).  Served FIFO
    /// whenever no `Train` request is queued.
    Eval,
}

type Reply = mpsc::Sender<Result<Vec<Vec<f32>>>>;

struct Job {
    prog: Prog,
    args: Vec<Arg>,
    reply: Reply,
}

/// The shared two-class queue.  Workers pop `train` first, then `eval`;
/// shutdown tokens (one per worker) outrank both.
struct QueueState {
    train: VecDeque<Job>,
    eval: VecDeque<Job>,
    shutdown_tokens: usize,
    /// Cleared by the last exiting worker: no request can ever be served
    /// again, so submissions must fail fast instead of queueing forever.
    open: bool,
    workers_alive: usize,
}

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Queue {
    fn new(workers: usize) -> Queue {
        Queue {
            state: Mutex::new(QueueState {
                train: VecDeque::new(),
                eval: VecDeque::new(),
                shutdown_tokens: 0,
                open: true,
                workers_alive: workers,
            }),
            cv: Condvar::new(),
        }
    }

    /// Lock the queue; a poisoned lock is recovered rather than
    /// propagated (queue state is a pair of deques — always consistent
    /// between operations, and no user code ever runs under the lock).
    fn lock(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn submit(&self, class: WorkClass, job: Job) -> Result<()> {
        {
            let mut q = self.lock();
            if !q.open {
                return Err(anyhow!("engine pool is down"));
            }
            match class {
                WorkClass::Train => q.train.push_back(job),
                WorkClass::Eval => q.eval.push_back(job),
            }
        }
        self.cv.notify_one();
        Ok(())
    }

    /// Block until a job (or a shutdown token, returned as `None`) is
    /// available.  Train outranks eval; shutdown outranks both.
    fn next_job(&self) -> Option<Job> {
        let mut q = self.lock();
        loop {
            if q.shutdown_tokens > 0 {
                q.shutdown_tokens -= 1;
                return None;
            }
            if let Some(job) = q.train.pop_front() {
                return Some(job);
            }
            if let Some(job) = q.eval.pop_front() {
                return Some(job);
            }
            q = self.cv.wait(q).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Called by every worker on exit (shutdown or death).  The last one
    /// out closes the queue and drops pending jobs — each drop closes its
    /// reply channel, so blocked callers observe `Err`, never a hang.
    fn worker_exited(&self) {
        let mut q = self.lock();
        q.workers_alive = q.workers_alive.saturating_sub(1);
        if q.workers_alive == 0 {
            q.open = false;
            q.train.clear();
            q.eval.clear();
        }
    }

    fn request_shutdown(&self, tokens: usize) {
        {
            let mut q = self.lock();
            q.shutdown_tokens += tokens;
        }
        self.cv.notify_all();
    }
}

/// Handle to the pool; cheap to clone, safe to share across threads.
#[derive(Clone)]
pub struct PoolHandle {
    queue: Arc<Queue>,
    meta: ModelMeta,
    /// Worker threads serving the pool (resolved, not the raw request).
    workers: usize,
}

/// Owns the worker threads; dropping shuts the pool down.
pub struct EnginePool {
    handle: PoolHandle,
    workers: Vec<JoinHandle<()>>,
}

/// `0` means auto-detect (one worker per available core).
pub(crate) fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

impl EnginePool {
    /// Load + compile every artifact of `model` on `num_workers` worker
    /// threads (each compiles its own copy — xla handles are `!Send`).
    ///
    /// Compilation happens on the worker threads before this returns, so
    /// errors surface here.  `num_workers = 0` auto-detects core count.
    pub fn load(manifest: &Manifest, model: &str, num_workers: usize) -> Result<EnginePool> {
        let meta = manifest.model(model)?.clone();
        let dir = manifest.dir.clone();
        let paths: Vec<(Prog, PathBuf)> = Prog::ALL
            .iter()
            .filter_map(|&p| meta.artifact_path(&dir, p.name()).ok().map(|f| (p, f)))
            .collect();
        if paths.is_empty() {
            return Err(anyhow!("model {model:?} has no artifacts"));
        }
        Self::with_factory(meta, num_workers, move |_worker| XlaExecutor::load(&paths))
    }

    /// Build a pool from an arbitrary executor factory.
    ///
    /// The factory runs on each worker thread (receiving the worker index),
    /// so executors may own thread-confined state.  If any factory fails,
    /// the pool is torn down and the first error is returned.
    pub fn with_factory<E, F>(meta: ModelMeta, num_workers: usize, factory: F) -> Result<EnginePool>
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let num_workers = resolve_workers(num_workers);
        let factory = Arc::new(factory);
        let queue = Arc::new(Queue::new(num_workers));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        // Build the pool shell before spawning so EVERY failure path below
        // can `drop(pool)` — which shutdown-tokens and joins exactly the
        // workers spawned so far.  (An early `?` instead would leave them
        // parked in `cv.wait` forever: unlike an mpsc queue, a shared
        // Condvar queue has no receiver-drop to wake them.)
        let mut pool = EnginePool {
            handle: PoolHandle {
                queue: Arc::clone(&queue),
                meta,
                workers: num_workers,
            },
            workers: Vec::with_capacity(num_workers),
        };
        for index in 0..num_workers {
            let factory = Arc::clone(&factory);
            let queue = Arc::clone(&queue);
            let ready = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("engine-worker-{index}"))
                .spawn(move || worker_main(index, factory, queue, ready))
                .context("spawning engine worker thread");
            match spawned {
                Ok(join) => pool.workers.push(join),
                Err(e) => {
                    drop(pool);
                    return Err(e);
                }
            }
        }
        drop(ready_tx);

        let mut startup: Result<()> = Ok(());
        for _ in 0..num_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup = Err(e);
                    break;
                }
                Err(_) => {
                    startup = Err(anyhow!("engine worker died during startup"));
                    break;
                }
            }
        }

        match startup {
            Ok(()) => Ok(pool),
            // Dropping tears down the healthy workers before reporting.
            Err(e) => {
                drop(pool);
                Err(e)
            }
        }
    }

    pub fn handle(&self) -> PoolHandle {
        self.handle.clone()
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.handle.meta
    }

    /// Worker threads serving this pool.
    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // One shutdown token per worker; each worker consumes exactly one.
        self.handle.queue.request_shutdown(self.workers.len());
        for join in self.workers.drain(..) {
            let _ = join.join();
        }
    }
}

fn worker_main<E, F>(
    index: usize,
    factory: Arc<F>,
    queue: Arc<Queue>,
    ready: mpsc::Sender<Result<()>>,
) where
    E: Executor + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    serve(index, &factory, &queue, ready);
    // Every exit path (shutdown token, startup failure, rebuild failure)
    // funnels through here so the last worker out can close the queue.
    queue.worker_exited();
}

fn serve<E, F>(index: usize, factory: &F, queue: &Queue, ready: mpsc::Sender<Result<()>>)
where
    E: Executor + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync,
{
    let mut exec = match factory(index) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Some(Job { prog, args, reply }) = queue.next_job() {
        match catch_unwind(AssertUnwindSafe(|| exec.execute(prog, args))) {
            Ok(result) => {
                let _ = reply.send(result);
            }
            Err(payload) => {
                let _ = reply.send(Err(anyhow!(
                    "engine worker {index} panicked in {:?}: {}",
                    prog.name(),
                    panic_message(payload.as_ref())
                )));
                // The executor may hold partially-mutated state after an
                // unwound execute; reusing it could return silently wrong
                // results.  Retire it and rebuild from the factory; if
                // that fails, let this worker die — siblings keep serving,
                // and with no workers left callers observe `Err`, never a
                // hang.
                match factory(index) {
                    Ok(fresh) => exec = fresh,
                    Err(e) => {
                        log::error!(
                            "engine worker {index} exiting: executor rebuild \
                             after panic failed: {e:#}"
                        );
                        return;
                    }
                }
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl PoolHandle {
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Worker threads serving the pool behind this handle — the natural
    /// concurrency bound for callers fanning work out (e.g. parallel eval).
    pub fn num_workers(&self) -> usize {
        self.workers
    }

    /// Execute `prog` with `args` on some worker at `Train` priority;
    /// blocks until the reply.
    pub fn call(&self, prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        self.call_class(WorkClass::Train, prog, args)
    }

    /// Execute `prog` with `args` at an explicit [`WorkClass`]; blocks
    /// until the reply.  Priority changes scheduling only, never bits.
    pub fn call_class(
        &self,
        class: WorkClass,
        prog: Prog,
        args: Vec<Arg>,
    ) -> Result<Vec<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        self.queue.submit(
            class,
            Job {
                prog,
                args,
                reply: tx,
            },
        )?;
        rx.recv()
            .map_err(|_| anyhow!("engine pool dropped the reply (all workers gone)"))?
    }

    // ---- typed wrappers -------------------------------------------------

    /// `init(seed) -> w0`.
    pub fn init(&self, seed: i32) -> Result<Vec<f32>> {
        let mut out = self.call(Prog::Init, vec![Arg::ScalarI32(seed)])?;
        Ok(out.remove(0))
    }

    /// One minibatch Adam step.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        w: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let b = self.meta.batch as i64;
        let mut dims = vec![b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Train,
            vec![
                Arg::vec(w),
                Arg::vec(m),
                Arg::vec(v),
                Arg::F32(x, dims),
                Arg::I32(y, vec![b]),
                Arg::ScalarF32(eta),
            ],
        )?;
        let loss = out[3][0];
        let v_out = out.remove(2);
        let m_out = out.remove(1);
        let w_out = out.remove(0);
        Ok((w_out, m_out, v_out, loss))
    }

    /// One full epoch (`epoch_batches` scanned batches) in one dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn epoch_step(
        &self,
        w: Vec<f32>,
        m: Vec<f32>,
        v: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        eta: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let nb = self.meta.epoch_batches as i64;
        let b = self.meta.batch as i64;
        let mut dims = vec![nb, b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Epoch,
            vec![
                Arg::vec(w),
                Arg::vec(m),
                Arg::vec(v),
                Arg::F32(x, dims),
                Arg::I32(y, vec![nb, b]),
                Arg::ScalarF32(eta),
            ],
        )?;
        let loss = out[3][0];
        let v_out = out.remove(2);
        let m_out = out.remove(1);
        let w_out = out.remove(0);
        Ok((w_out, m_out, v_out, loss))
    }

    /// Weighted eval batch: returns `(loss_sum, correct, weight_sum)`.
    ///
    /// Dispatched at `Eval` priority so a pipelined eval fan-out only uses
    /// pool capacity that training leaves idle.
    pub fn eval_batch(
        &self,
        w: &[f32],
        x: Vec<f32>,
        y: Vec<i32>,
        wt: Vec<f32>,
    ) -> Result<(f64, f64, f64)> {
        let e = self.meta.eval_batch as i64;
        let mut dims = vec![e];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let out = self.call_class(
            WorkClass::Eval,
            Prog::Eval,
            vec![
                Arg::vec(w.to_vec()),
                Arg::F32(x, dims),
                Arg::I32(y, vec![e]),
                Arg::F32(wt, vec![e]),
            ],
        )?;
        Ok((out[0][0] as f64, out[1][0] as f64, out[2][0] as f64))
    }

    /// FedSGD step.
    pub fn sgd_step(
        &self,
        w: Vec<f32>,
        x: Vec<f32>,
        y: Vec<i32>,
        eta: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let b = self.meta.batch as i64;
        let mut dims = vec![b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Sgd,
            vec![
                Arg::vec(w),
                Arg::F32(x, dims),
                Arg::I32(y, vec![b]),
                Arg::ScalarF32(eta),
            ],
        )?;
        let loss = out[1][0];
        Ok((out.remove(0), loss))
    }

    /// Minibatch gradient.
    pub fn grads(&self, w: &[f32], x: Vec<f32>, y: Vec<i32>) -> Result<(Vec<f32>, f32)> {
        let b = self.meta.batch as i64;
        let mut dims = vec![b];
        dims.extend(self.meta.input_shape.iter().map(|&d| d as i64));
        let mut out = self.call(
            Prog::Grads,
            vec![Arg::vec(w.to_vec()), Arg::F32(x, dims), Arg::I32(y, vec![b])],
        )?;
        let loss = out[1][0];
        Ok((out.remove(0), loss))
    }

    /// The Layer-1 SSM sparsifier (XLA-side alternative to `sparse::topk`).
    pub fn sparsify(
        &self,
        dw: Vec<f32>,
        dm: Vec<f32>,
        dv: Vec<f32>,
        k: i32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut out = self.call(
            Prog::Sparsify,
            vec![Arg::vec(dw), Arg::vec(dm), Arg::vec(dv), Arg::ScalarI32(k)],
        )?;
        let dv_out = out.remove(2);
        let dm_out = out.remove(1);
        let dw_out = out.remove(0);
        Ok((dw_out, dm_out, dv_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    fn test_meta() -> ModelMeta {
        ModelMeta {
            name: "mock".into(),
            dim: 4,
            input_shape: vec![2, 2, 1],
            num_classes: 2,
            batch: 1,
            eval_batch: 1,
            epoch_batches: 1,
            artifacts: BTreeMap::new(),
        }
    }

    fn scalar(args: &[Arg]) -> f32 {
        match args[0] {
            Arg::ScalarF32(x) => x,
            _ => panic!("expected scalar arg"),
        }
    }

    /// Doubles its scalar input; panics on negative input.
    struct MockExec;

    impl Executor for MockExec {
        fn execute(&mut self, _prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
            let x = scalar(&args);
            if x < 0.0 {
                panic!("negative input {x}");
            }
            Ok(vec![vec![x * 2.0]])
        }
    }

    #[test]
    fn calls_round_trip_across_workers() {
        let pool = EnginePool::with_factory(test_meta(), 4, |_| Ok(MockExec)).unwrap();
        assert_eq!(pool.num_workers(), 4);
        let handle = pool.handle();
        let joins: Vec<_> = (0..16)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let out = h
                        .call(Prog::Init, vec![Arg::ScalarF32(i as f32)])
                        .unwrap();
                    assert_eq!(out, vec![vec![i as f32 * 2.0]]);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn worker_panic_surfaces_as_err_not_hang() {
        let pool = EnginePool::with_factory(test_meta(), 2, |_| Ok(MockExec)).unwrap();
        let h = pool.handle();
        let err = h
            .call(Prog::Init, vec![Arg::ScalarF32(-1.0)])
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "unexpected error: {msg}");
        assert!(msg.contains("negative input"), "payload lost: {msg}");
        // The worker survives the panic and keeps serving.
        let ok = h.call(Prog::Init, vec![Arg::ScalarF32(3.0)]).unwrap();
        assert_eq!(ok, vec![vec![6.0]]);
    }

    #[test]
    fn factory_failure_fails_load() {
        let result = EnginePool::with_factory(test_meta(), 3, |worker| {
            if worker == 1 {
                Err(anyhow!("no backend on worker {worker}"))
            } else {
                Ok(MockExec)
            }
        });
        let msg = format!("{:#}", result.err().unwrap());
        assert!(msg.contains("no backend"), "unexpected error: {msg}");
    }

    /// Blocks until a sibling call is in flight, proving parallel execution.
    struct OverlapExec {
        in_flight: Arc<AtomicUsize>,
    }

    impl Executor for OverlapExec {
        fn execute(&mut self, _prog: Prog, _args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
            self.in_flight.fetch_add(1, Ordering::SeqCst);
            let deadline = Instant::now() + Duration::from_secs(5);
            let overlapped = loop {
                if self.in_flight.load(Ordering::SeqCst) >= 2 {
                    break true;
                }
                if Instant::now() > deadline {
                    break false;
                }
                std::thread::yield_now();
            };
            // Leave the counter high so the sibling also observes >= 2.
            if overlapped {
                Ok(vec![vec![1.0]])
            } else {
                Err(anyhow!("no overlap: pool executed serially"))
            }
        }
    }

    #[test]
    fn workers_execute_concurrently() {
        let in_flight = Arc::new(AtomicUsize::new(0));
        let flag = Arc::clone(&in_flight);
        let pool = EnginePool::with_factory(test_meta(), 2, move |_| {
            Ok(OverlapExec {
                in_flight: Arc::clone(&flag),
            })
        })
        .unwrap();
        let h = pool.handle();
        let joins: Vec<_> = (0..2)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || h.call(Prog::Init, vec![Arg::ScalarF32(0.0)]))
            })
            .collect();
        for j in joins {
            j.join().unwrap().unwrap();
        }
    }

    #[test]
    fn zero_workers_auto_detects() {
        let pool = EnginePool::with_factory(test_meta(), 0, |_| Ok(MockExec)).unwrap();
        assert!(pool.num_workers() >= 1);
    }

    /// Records execution order; a job whose scalar is `0.0` blocks until
    /// `gate` releases it (used to pin the single worker while the test
    /// enqueues competing work).
    struct OrderExec {
        order: Arc<Mutex<Vec<i32>>>,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl Executor for OrderExec {
        fn execute(&mut self, _prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
            let tag = scalar(&args) as i32;
            if tag == 0 {
                let (lock, cv) = &*self.gate;
                let mut released = lock.lock().unwrap();
                while !*released {
                    released = cv.wait(released).unwrap();
                }
            }
            self.order.lock().unwrap().push(tag);
            Ok(vec![vec![tag as f32]])
        }
    }

    #[test]
    fn train_class_outranks_queued_eval() {
        let order: Arc<Mutex<Vec<i32>>> = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let (order_f, gate_f) = (Arc::clone(&order), Arc::clone(&gate));
        let pool = EnginePool::with_factory(test_meta(), 1, move |_| {
            Ok(OrderExec {
                order: Arc::clone(&order_f),
                gate: Arc::clone(&gate_f),
            })
        })
        .unwrap();
        let h = pool.handle();

        // Pin the single worker on the gate job, then queue an eval-class
        // job BEFORE a train-class job.  Once the gate opens, the worker
        // must serve the train job first despite its later arrival.
        let gate_job = {
            let h = h.clone();
            std::thread::spawn(move || h.call(Prog::Init, vec![Arg::ScalarF32(0.0)]))
        };
        std::thread::sleep(Duration::from_millis(100));
        let eval_job = {
            let h = h.clone();
            std::thread::spawn(move || {
                h.call_class(WorkClass::Eval, Prog::Eval, vec![Arg::ScalarF32(2.0)])
            })
        };
        std::thread::sleep(Duration::from_millis(100));
        let train_job = {
            let h = h.clone();
            std::thread::spawn(move || h.call(Prog::Train, vec![Arg::ScalarF32(1.0)]))
        };
        std::thread::sleep(Duration::from_millis(100));
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        gate_job.join().unwrap().unwrap();
        eval_job.join().unwrap().unwrap();
        train_job.join().unwrap().unwrap();
        assert_eq!(
            *order.lock().unwrap(),
            vec![0, 1, 2],
            "train-class job must be served before the earlier eval-class job"
        );
    }

    #[test]
    fn pool_drop_then_call_errors_not_hangs() {
        let pool = EnginePool::with_factory(test_meta(), 2, |_| Ok(MockExec)).unwrap();
        let h = pool.handle();
        drop(pool);
        let err = h.call(Prog::Init, vec![Arg::ScalarF32(1.0)]).unwrap_err();
        assert!(
            format!("{err:#}").contains("down"),
            "want fail-fast submit error, got: {err:#}"
        );
    }
}
