"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; tolerances follow the
f32 analysis in DESIGN.md (the `w` output of the Adam kernel divides by
`sqrt(v+eps)` which amplifies rounding near v ~ 0, hence the looser bound
there; moments and masks are tight).
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref as R

# Keep hypothesis deadlines off: interpret-mode pallas is slow per call.
SET = settings(max_examples=20, deadline=None)


def vec(rng, d, scale=1.0):
    return jnp.asarray(rng.normal(size=d) * scale, jnp.float32)


dims = st.sampled_from([1, 7, 128, 1000, 65536, 70001])
seeds = st.integers(0, 2**31 - 1)


class TestAdamUpdate:
    @SET
    @given(d=dims, seed=seeds, eta=st.sampled_from([1e-4, 1e-3, 1e-2, 0.1]))
    def test_matches_ref(self, d, seed, eta):
        rng = np.random.default_rng(seed)
        w, m, g = vec(rng, d), vec(rng, d), vec(rng, d)
        v = jnp.abs(vec(rng, d))  # v is a running mean of squares: >= 0
        kw, km, kv = K.adam_update(w, m, v, g, eta)
        rw, rm, rv = R.adam_update_ref(w, m, v, g, eta)
        np.testing.assert_allclose(km, rm, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(kv, rv, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(kw, rw, rtol=5e-4, atol=5e-4)

    def test_zero_gradient_decays_moments_only(self):
        d = 256
        rng = np.random.default_rng(0)
        w, m = vec(rng, d), vec(rng, d)
        v = jnp.abs(vec(rng, d))
        g = jnp.zeros(d, jnp.float32)
        kw, km, kv = K.adam_update(w, m, v, g, 0.0)
        np.testing.assert_allclose(km, 0.9 * m, rtol=1e-6)
        np.testing.assert_allclose(kv, 0.999 * v, rtol=1e-6)
        np.testing.assert_allclose(kw, w, rtol=1e-6)

    def test_custom_betas(self):
        d = 100
        rng = np.random.default_rng(1)
        w, m, g = vec(rng, d), vec(rng, d), vec(rng, d)
        v = jnp.abs(vec(rng, d))
        kw, km, kv = K.adam_update(w, m, v, g, 1e-3, beta1=0.5, beta2=0.9, eps=1e-4)
        rw, rm, rv = R.adam_update_ref(w, m, v, g, 1e-3, beta1=0.5, beta2=0.9, eps=1e-4)
        np.testing.assert_allclose(km, rm, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(kv, rv, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(kw, rw, rtol=5e-4, atol=5e-4)

    def test_non_multiple_block_padding(self):
        # d deliberately not a multiple of the 64Ki block.
        d = 64 * 1024 + 3
        rng = np.random.default_rng(2)
        w, m, g = vec(rng, d), vec(rng, d), vec(rng, d)
        v = jnp.abs(vec(rng, d))
        kw, _, _ = K.adam_update(w, m, v, g, 1e-3)
        rw, _, _ = R.adam_update_ref(w, m, v, g, 1e-3)
        np.testing.assert_allclose(kw, rw, rtol=5e-4, atol=5e-4)


class TestTopK:
    @SET
    @given(d=dims, seed=seeds)
    def test_threshold_matches_ref(self, d, seed):
        rng = np.random.default_rng(seed)
        x = vec(rng, d)
        k = max(1, d // 7)
        tau_k = K.topk_threshold(x, k)
        tau_r = R.topk_threshold_ref(x, k)
        np.testing.assert_allclose(tau_k, tau_r, rtol=1e-6)

    @SET
    @given(d=st.sampled_from([16, 1000, 65536]), seed=seeds,
           frac=st.sampled_from([0.01, 0.1, 0.5, 1.0]))
    def test_mask_matches_ref(self, d, seed, frac):
        rng = np.random.default_rng(seed)
        x = vec(rng, d)
        k = max(1, int(d * frac))
        mk = K.topk_mask(x, k)
        mr = R.topk_mask_ref(x, k)
        np.testing.assert_array_equal(np.asarray(mk), np.asarray(mr))
        # Continuous input: ties have measure zero, so exactly k kept.
        assert int(mk.sum()) == k

    def test_k_boundaries(self):
        x = jnp.asarray([3.0, -1.0, 2.0], jnp.float32)
        assert int(K.topk_mask(x, 1).sum()) == 1
        assert int(K.topk_mask(x, 3).sum()) == 3
        # k out of range is clamped
        assert int(K.topk_mask(x, 100).sum()) == 3


class TestSsmSparsify:
    @SET
    @given(d=dims, seed=seeds)
    def test_matches_ref(self, d, seed):
        rng = np.random.default_rng(seed)
        dw, dm, dv = vec(rng, d), vec(rng, d, 0.01), vec(rng, d, 1e-4)
        k = max(1, d // 20)
        kk = K.ssm_sparsify3(dw, dm, dv, k)
        rr = R.ssm_sparsify3_ref(dw, dm, dv, k)
        for a, b in zip(kk, rr):
            np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_shared_mask_property(self):
        # Kept lanes of dm/dv are exactly where dw survives (eq. 10-12).
        rng = np.random.default_rng(3)
        d = 4096
        dw, dm, dv = vec(rng, d), vec(rng, d), vec(rng, d)
        sw, sm, sv = K.ssm_sparsify3(dw, dm, dv, 100)
        keep = np.asarray(sw) != 0.0
        assert keep.sum() == 100
        assert ((np.asarray(sm) != 0.0) == keep).all()
        assert ((np.asarray(sv) != 0.0) == keep).all()
        # and the kept values are unmodified
        np.testing.assert_array_equal(np.asarray(sm)[keep], np.asarray(dm)[keep])

    def test_apply_mask(self):
        rng = np.random.default_rng(4)
        x = vec(rng, 1000)
        mask = R.topk_mask_ref(x, 50)
        np.testing.assert_allclose(K.apply_mask(x, mask), x * mask, rtol=1e-7)


class TestQuantizers:
    @SET
    @given(d=dims, seed=seeds)
    def test_onebit_matches_ref(self, d, seed):
        rng = np.random.default_rng(seed)
        x, e = vec(rng, d), vec(rng, d, 0.1)
        kq, ke = K.onebit_quantize(x, e)
        rq, re = R.onebit_quantize_ref(x, e)
        np.testing.assert_allclose(kq, rq, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ke, re, rtol=1e-4, atol=1e-5)

    @SET
    @given(d=dims, seed=seeds, s=st.sampled_from([2, 3, 16, 256]))
    def test_uniform_matches_ref(self, d, seed, s):
        rng = np.random.default_rng(seed)
        x = vec(rng, d)
        np.testing.assert_allclose(
            K.uniform_quantize(x, s), R.uniform_quantize_ref(x, s), rtol=1e-5, atol=1e-6
        )

    def test_uniform_error_bounded(self):
        rng = np.random.default_rng(5)
        x = vec(rng, 4096)
        for s in (2, 16, 256):
            q = np.asarray(K.uniform_quantize(x, s))
            bin_w = 2 * float(jnp.max(jnp.abs(x))) / (s - 1)
            assert np.max(np.abs(q - np.asarray(x))) <= bin_w / 2 + 1e-5

    def test_onebit_zero_input(self):
        z = jnp.zeros(64, jnp.float32)
        q, e = K.onebit_quantize(z, z)
        np.testing.assert_array_equal(np.asarray(q), 0.0)
        np.testing.assert_array_equal(np.asarray(e), 0.0)


@pytest.mark.parametrize("d", [1, 63, 64 * 1024, 64 * 1024 + 1])
def test_all_kernels_handle_block_edges(d):
    """Every kernel must survive block-boundary dims (padding paths)."""
    rng = np.random.default_rng(6)
    x = vec(rng, d)
    K.adam_update(x, x, jnp.abs(x), x, 1e-3)
    K.ssm_sparsify3(x, x, x, max(1, d // 2))
    K.onebit_quantize(x, jnp.zeros_like(x))
    K.uniform_quantize(x, 16)
