//! 1-bit Adam baseline [29], adapted to the round-based FL setting.
//!
//! Two phases, as in the original:
//! 1. **Warmup** (`warmup_rounds` rounds): vanilla dense FedAdam — full
//!    precision (ΔW, ΔM, ΔV) at `3dq` per device.
//! 2. **Compression**: the second-moment estimate is *frozen* as a
//!    precondition — the server stops aggregating V (and M), devices keep
//!    their own moments (the staleness §II-B criticizes) — and the model
//!    update ΔW travels as error-compensated 1-bit sign quantization at
//!    `d + 32` bits.
//!
//! Adaptation note (DESIGN.md): the original communicates per-step
//! momentum in a data-parallel all-reduce; with `L` local epochs per round
//! the round-level carrier of the same information is ΔW computed under
//! the frozen precondition.  The phase structure, EF compressor and wire
//! cost match [29]; Table I's "∞" behaviour reproduces because the frozen,
//! never-aggregated moments degrade exactly as the paper argues.

use anyhow::Result;

use super::residual_store::ResidualStore;
use super::wire::{WireBody, WireUpload};
use super::{Aggregate, Algorithm, LocalDelta, MomentumPolicy, Recon, Upload};
use crate::quant::{onebit_compress, onebit_decompress, ErrorFeedback, OneBitPacket};
use crate::sparse::codec::cost;
use crate::util::bytes::{ByteReader, ByteWriter};

pub struct OneBitAdam {
    dim: usize,
    warmup_rounds: usize,
    /// Per-device error-feedback residuals (compression phase), one
    /// `dim`-wide entry per *touched* device (see [`ResidualStore`]).
    ef: ResidualStore,
}

impl OneBitAdam {
    pub fn new(dim: usize, warmup_rounds: usize, resident_cap: usize, spill_dir: &str) -> Self {
        OneBitAdam {
            dim,
            warmup_rounds,
            ef: ResidualStore::new(dim, resident_cap, spill_dir),
        }
    }

    fn warm(&self, round: usize) -> bool {
        round < self.warmup_rounds
    }

    /// Compression-phase core shared by [`Algorithm::compress`] and
    /// [`Algorithm::compress_wire`] — the per-device EF memory mutates
    /// exactly once per call.
    fn compress_inner(&mut self, device: usize, delta: &LocalDelta) -> (OneBitPacket, Upload) {
        // The quantizer works on an `ErrorFeedback`; round-trip the store
        // entry through a scratch one (plain f32 copies — bit-exact).
        let entry = self.ef.get_mut(device as u64);
        let mut scratch = ErrorFeedback::new(entry.len());
        scratch.residual.copy_from_slice(entry);
        let packet = onebit_compress(&delta.dw, &mut scratch);
        entry.copy_from_slice(&scratch.residual);
        let bits = packet.wire_bits();
        debug_assert_eq!(bits, cost::onebit(self.dim));
        let up = Upload {
            dw: Recon::Dense(onebit_decompress(&packet)),
            dm: None,
            dv: None,
            weight: delta.weight,
            bits,
        };
        (packet, up)
    }
}

impl Algorithm for OneBitAdam {
    fn name(&self) -> &'static str {
        "onebit-adam"
    }

    fn momentum_policy(&self, round: usize) -> MomentumPolicy {
        if self.warm(round) {
            MomentumPolicy::Aggregated
        } else {
            MomentumPolicy::DeviceLocal
        }
    }

    fn compress(&mut self, round: usize, device: usize, delta: LocalDelta) -> Upload {
        if self.warm(round) {
            Upload {
                dw: Recon::Dense(delta.dw),
                dm: Some(Recon::Dense(delta.dm)),
                dv: Some(Recon::Dense(delta.dv)),
                weight: delta.weight,
                bits: cost::fedadam_dense(self.dim),
            }
        } else {
            self.compress_inner(device, &delta).1
        }
    }

    fn compress_wire(
        &mut self,
        round: usize,
        device: usize,
        delta: LocalDelta,
    ) -> Result<WireUpload> {
        if self.warm(round) {
            // Warmup uploads are plain dense f32 — the default derivation
            // is already the wire form.
            WireUpload::from_upload(self.compress(round, device, delta))
        } else {
            let (packet, up) = self.compress_inner(device, &delta);
            Ok(WireUpload {
                body: WireBody::OneBit(packet),
                weight: up.weight,
                bits: up.bits,
            })
        }
    }

    fn downlink_bits(&self, agg: &Aggregate) -> u64 {
        if agg.dm.is_some() {
            cost::fedadam_dense(self.dim) // warmup broadcast
        } else {
            // Compression phase: the original broadcasts the compressed
            // aggregate (two-way 1-bit); one sign vector + scale.
            cost::onebit(self.dim)
        }
    }

    fn postprocess(&mut self, agg: &mut Aggregate) {
        if agg.dm.is_none() {
            // Two-way compression: re-quantize the aggregate for broadcast
            // (server-side EF-free sign quantization, as in [29]'s
            // compressed all-reduce).
            let scale = agg.dw.iter().map(|v| v.abs() as f64).sum::<f64>() as f32
                / agg.dw.len().max(1) as f32;
            for v in agg.dw.iter_mut() {
                *v = if *v >= 0.0 { scale } else { -scale };
            }
        }
    }

    fn save_state(&self, out: &mut ByteWriter) {
        self.ef.save_state(out);
    }

    fn load_state(&mut self, input: &mut ByteReader) -> Result<()> {
        self.ef.load_state(input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(dim: usize) -> LocalDelta {
        LocalDelta {
            dw: (0..dim).map(|i| (i as f32 - 2.0) * 0.1).collect(),
            dm: vec![0.5; dim],
            dv: vec![0.25; dim],
            weight: 1.0,
        }
    }

    #[test]
    fn warmup_is_dense_then_onebit() {
        let mut a = OneBitAdam::new(8, 2, 0, "");
        let up0 = a.compress(0, 0, delta(8));
        assert_eq!(up0.bits, cost::fedadam_dense(8));
        assert!(up0.dm.is_some());
        assert_eq!(a.momentum_policy(0), MomentumPolicy::Aggregated);

        let up2 = a.compress(2, 0, delta(8));
        assert_eq!(up2.bits, 8 + 32);
        assert!(up2.dm.is_none());
        assert_eq!(a.momentum_policy(2), MomentumPolicy::DeviceLocal);
        // Dequantized payload has constant magnitude.
        match &up2.dw {
            Recon::Dense(v) => {
                let mag = v[0].abs();
                assert!(v.iter().all(|x| (x.abs() - mag).abs() < 1e-6));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn per_device_error_feedback_is_independent() {
        let mut a = OneBitAdam::new(4, 0, 0, "");
        let d0 = delta(4);
        a.compress(0, 0, d0.clone());
        let r0 = a.ef.peek(0).unwrap();
        assert_eq!(a.ef.peek(1), None, "device 1 untouched so far");
        a.compress(0, 1, d0);
        assert_eq!(a.ef.peek(1).unwrap(), r0);
    }

    #[test]
    fn postprocess_requantizes_broadcast() {
        let mut a = OneBitAdam::new(4, 0, 0, "");
        let mut agg = Aggregate {
            dw: vec![0.4, -0.2, 0.1, -0.5],
            dm: None,
            dv: None,
            dw_support: 4,
            dm_support: 0,
            dv_support: 0,
        };
        a.postprocess(&mut agg);
        let mag = agg.dw[0].abs();
        assert!((mag - 0.3).abs() < 1e-6);
        assert_eq!(agg.dw.iter().map(|v| v.signum()).collect::<Vec<_>>(), vec![1.0, -1.0, 1.0, -1.0]);
    }
}
