//! Standard FedAdam (paper Algorithm 1): dense uplink of all three vectors.
//!
//! The `α = 1` special case of FedAdam-SSM — full-fidelity aggregation of
//! (ΔW, ΔM, ΔV) at cost `3dq` up / `3dq` down per device.

use super::{Aggregate, Algorithm, LocalDelta, Recon, Upload};
use crate::sparse::codec::cost;

pub struct FedAdam {
    dim: usize,
}

impl FedAdam {
    pub fn new(dim: usize) -> Self {
        FedAdam { dim }
    }
}

impl Algorithm for FedAdam {
    fn name(&self) -> &'static str {
        "fedadam"
    }

    fn compress(&mut self, _round: usize, _device: usize, delta: LocalDelta) -> Upload {
        Upload {
            dw: Recon::Dense(delta.dw),
            dm: Some(Recon::Dense(delta.dm)),
            dv: Some(Recon::Dense(delta.dv)),
            weight: delta.weight,
            bits: cost::fedadam_dense(self.dim),
        }
    }

    fn downlink_bits(&self, _agg: &Aggregate) -> u64 {
        cost::fedadam_dense(self.dim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_payload_and_cost() {
        let mut a = FedAdam::new(100);
        let delta = LocalDelta {
            dw: vec![1.0; 100],
            dm: vec![2.0; 100],
            dv: vec![3.0; 100],
            weight: 5.0,
        };
        let up = a.compress(0, 0, delta);
        assert_eq!(up.bits, 3 * 100 * 32);
        assert_eq!(up.dw.nnz(), 100);
        assert!(up.dm.is_some() && up.dv.is_some());
    }
}
