//! The XLA execution backend: program identifiers, argument encoding, and
//! the per-thread [`XlaExecutor`] that owns a PJRT CPU client plus every
//! compiled executable for one model.
//!
//! The `xla` crate's `PjRtClient` / `PjRtLoadedExecutable` wrap raw C
//! pointers (`!Send`), so an executor is built *on* the thread that will
//! drive it — the [`super::pool::EnginePool`] runs one executor per worker
//! thread behind a shared work queue.  [`Engine`] is the single-worker
//! convenience wrapper (the original actor API): `Engine::load` ≡ an
//! [`EnginePool`] with `num_workers = 1`.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use super::manifest::{Manifest, ModelMeta};
use super::pool::{EnginePool, Executor, PoolHandle};

/// Programs a model bundle may expose (mirrors `compile/aot.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Prog {
    Init,
    Train,
    Epoch,
    Eval,
    Sgd,
    Grads,
    Sparsify,
}

impl Prog {
    pub fn name(self) -> &'static str {
        match self {
            Prog::Init => "init",
            Prog::Train => "train",
            Prog::Epoch => "epoch",
            Prog::Eval => "eval",
            Prog::Sgd => "sgd",
            Prog::Grads => "grads",
            Prog::Sparsify => "sparsify",
        }
    }

    pub const ALL: [Prog; 7] = [
        Prog::Init,
        Prog::Train,
        Prog::Epoch,
        Prog::Eval,
        Prog::Sgd,
        Prog::Grads,
        Prog::Sparsify,
    ];
}

/// One input buffer for a program call.
#[derive(Clone, Debug)]
pub enum Arg {
    /// f32 tensor with explicit dims.
    F32(Vec<f32>, Vec<i64>),
    /// i32 tensor with explicit dims.
    I32(Vec<i32>, Vec<i64>),
    /// f32 scalar.
    ScalarF32(f32),
    /// i32 scalar.
    ScalarI32(i32),
}

impl Arg {
    /// Flat f32 vector (rank 1).
    pub fn vec(v: Vec<f32>) -> Arg {
        let d = v.len() as i64;
        Arg::F32(v, vec![d])
    }
}

/// Handle to the (single-worker) engine; kept as an alias so existing
/// callers and signatures keep compiling against the pool-backed runtime.
pub type EngineHandle = PoolHandle;

/// A PJRT client plus one compiled executable per program, owned by (and
/// confined to) a single worker thread.
pub struct XlaExecutor {
    // Kept alive for the executables' sake.
    _client: xla::PjRtClient,
    exes: BTreeMap<Prog, xla::PjRtLoadedExecutable>,
}

impl XlaExecutor {
    /// Create the CPU client and compile every artifact in `paths`.
    pub fn load(paths: &[(Prog, PathBuf)]) -> Result<XlaExecutor> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut exes = BTreeMap::new();
        for (prog, path) in paths {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
            exes.insert(*prog, exe);
        }
        Ok(XlaExecutor {
            _client: client,
            exes,
        })
    }
}

impl Executor for XlaExecutor {
    fn execute(&mut self, prog: Prog, args: Vec<Arg>) -> Result<Vec<Vec<f32>>> {
        run_one(&self.exes, prog, args)
    }
}

pub(crate) fn run_one(
    exes: &BTreeMap<Prog, xla::PjRtLoadedExecutable>,
    prog: Prog,
    args: Vec<Arg>,
) -> Result<Vec<Vec<f32>>> {
    let exe = exes
        .get(&prog)
        .ok_or_else(|| anyhow!("program {:?} not loaded", prog.name()))?;
    let literals: Vec<xla::Literal> = args
        .into_iter()
        .map(|a| -> Result<xla::Literal> {
            Ok(match a {
                Arg::F32(v, dims) => xla::Literal::vec1(&v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape f32 {dims:?}: {e}"))?,
                Arg::I32(v, dims) => xla::Literal::vec1(&v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape i32 {dims:?}: {e}"))?,
                Arg::ScalarF32(x) => xla::Literal::scalar(x),
                Arg::ScalarI32(x) => xla::Literal::scalar(x),
            })
        })
        .collect::<Result<_>>()?;
    let out = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("execute {:?}: {e}", prog.name()))?;
    let tuple = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch result: {e}"))?;
    // aot.py lowers with return_tuple=True: always a tuple, even of one.
    let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
    parts
        .into_iter()
        .map(|lit| {
            lit.to_vec::<f32>()
                .map_err(|e| anyhow!("output to f32 vec: {e}"))
        })
        .collect()
}

/// Single-worker engine: the original actor API, backed by the pool.
pub struct Engine {
    pool: EnginePool,
}

impl Engine {
    /// Load + compile every artifact of `model` from `manifest` on one
    /// dedicated worker thread.  Errors surface here.
    pub fn load(manifest: &Manifest, model: &str) -> Result<Engine> {
        Ok(Engine {
            pool: EnginePool::load(manifest, model, 1)?,
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.pool.handle()
    }

    pub fn meta(&self) -> &ModelMeta {
        self.pool.meta()
    }
}
