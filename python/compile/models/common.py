"""Shared model machinery: flat-parameter ABI, layers, losses.

The rust coordinator only ever sees ``f32[d]`` buffers, so a model here is:

- ``specs``: ordered list of :class:`ParamSpec` (name, shape, init kind);
- ``apply(flat, x)``: pure forward pass that unflattens internally;
- ``input_shape`` / ``num_classes``: workload metadata for the manifest.

Initialization follows He-normal for conv/dense kernels, zeros for biases,
ones for norm scales — deterministic given a PRNG key, and exported as its
own HLO program so the *rust* side owns the seed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter tensor inside the flat vector."""

    name: str
    shape: tuple
    init: str  # "he" | "zeros" | "ones"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class Model:
    """A functional model over a single flat parameter vector."""

    name: str
    specs: tuple
    apply: Callable  # (flat f32[d], x f32[B,...]) -> logits f32[B,C]
    input_shape: tuple
    num_classes: int

    @property
    def dim(self) -> int:
        """Total parameter count ``d``."""
        return sum(s.size for s in self.specs)

    def unflatten(self, flat):
        """Split ``f32[d]`` into the per-parameter tensors."""
        out = []
        off = 0
        for s in self.specs:
            out.append(lax.dynamic_slice_in_dim(flat, off, s.size).reshape(s.shape))
            off += s.size
        return out

    def init_flat(self, key):
        """Deterministic flat initialization (He / zeros / ones)."""
        chunks = []
        for i, s in enumerate(self.specs):
            k = jax.random.fold_in(key, i)
            if s.init == "he":
                fan_in = int(math.prod(s.shape[:-1])) or 1
                std = math.sqrt(2.0 / fan_in)
                chunks.append(jax.random.normal(k, s.shape, jnp.float32).reshape(-1) * std)
            elif s.init == "zeros":
                chunks.append(jnp.zeros((s.size,), jnp.float32))
            elif s.init == "ones":
                chunks.append(jnp.ones((s.size,), jnp.float32))
            else:  # pragma: no cover - registry is static
                raise ValueError(f"unknown init {s.init}")
        return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Layers (NHWC, HWIO kernels)
# ---------------------------------------------------------------------------

DIMNUMS = ("NHWC", "HWIO", "NHWC")


def conv2d(x, kernel, bias, stride=1, padding="SAME"):
    """3/5-wide conv + bias, NHWC."""
    y = lax.conv_general_dilated(
        x,
        kernel,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=DIMNUMS,
    )
    return y + bias


def max_pool(x, window=2, stride=2):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "VALID",
    )


def avg_pool_global(x):
    """Global average pool NHWC -> NC."""
    return jnp.mean(x, axis=(1, 2))


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    """GroupNorm (running-stats-free BatchNorm substitute, DESIGN.md).

    BatchNorm's running statistics are extra cross-device state that the
    paper's algorithms never aggregate; GroupNorm is a pure function of the
    parameters, which keeps the FL state exactly (W, M, V) as in the paper.
    """
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + eps)
    return xg.reshape(n, h, w, c) * scale + bias


def dense(x, kernel, bias):
    return x @ kernel + bias


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy; ``labels`` int32 class ids."""
    logz = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logz, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def weighted_xent_and_correct(logits, labels, weights):
    """(weighted loss sum, weighted correct count) for padded eval batches."""
    logz = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logz, labels[:, None], axis=1)[:, 0]
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    correct = (pred == labels).astype(jnp.float32)
    return jnp.sum(nll * weights), jnp.sum(correct * weights)
