//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire runtime bridge.  [`manifest`] describes what was exported;
//! [`engine`] holds the XLA backend (program ids, argument encoding, the
//! per-thread [`engine::XlaExecutor`]); [`pool`] is the execution engine
//! proper — an [`pool::EnginePool`] of `num_workers` worker threads, each
//! owning its own PJRT CPU client and compiled executables (the `xla`
//! crate's handles wrap raw pointers and are not `Send`), fronted by a
//! work queue.  The cloneable, thread-safe [`pool::PoolHandle`] (aliased
//! as [`engine::EngineHandle`]) load-balances calls across workers; at
//! `num_workers = 1` it degenerates to the original single-engine actor,
//! and results are bitwise identical at any worker count.

pub mod engine;
pub mod manifest;
pub mod pool;
pub mod reference;

pub use engine::{Arg, Engine, EngineHandle, Prog};
pub use manifest::{AdamConfig, Manifest, ModelMeta};
pub use pool::{EnginePool, Executor, PoolHandle, WorkClass};
pub use reference::{
    reference_meta, reference_pool, reference_pool_with_mode, KernelMode, ReferenceExecutor,
};
