//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire runtime bridge.  [`manifest`] describes what was exported;
//! [`engine`] owns a PJRT CPU client plus the compiled executables on a
//! dedicated thread (the `xla` crate's handles wrap raw pointers and are
//! not `Send`), exposing a cloneable, thread-safe [`engine::EngineHandle`]
//! that device workers call concurrently.

pub mod engine;
pub mod manifest;

pub use engine::{Arg, Engine, EngineHandle, Prog};
pub use manifest::{AdamConfig, Manifest, ModelMeta};
