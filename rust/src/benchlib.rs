//! Minimal benchmarking harness (the offline build has no criterion).
//!
//! `cargo bench` targets use [`Bench`] for wall-clock micro/mesobenchmarks:
//! warmup, auto-calibrated iteration counts, and robust summary stats
//! (mean / p50 / p95 / min).  Results print in a fixed-width table and can
//! be appended to a CSV for the EXPERIMENTS.md §Perf log.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// `name, mean, p50, p95, min` row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A group of benchmark cases sharing a target time budget.
pub struct Bench {
    /// Per-case measurement budget.
    pub budget: Duration,
    /// Hard cap on iterations.
    pub max_iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(500),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness for CI: tiny budget.
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(60),
            max_iters: 200,
            results: Vec::new(),
        }
    }

    /// Measure `f`, auto-calibrating the iteration count.
    pub fn run<F: FnMut()>(&mut self, name: impl Into<String>, mut f: F) -> &BenchResult {
        // Warmup + calibration: time a single call.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.budget.as_nanos() / once.as_nanos().max(1)) as usize)
            .clamp(3, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        self.results.push(summarize(name.into(), iters, samples));
        self.results.last().unwrap()
    }

    /// Print the group as a table.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "p95", "min"
        );
        for r in &self.results {
            println!("{}", r.row());
        }
    }

    /// CSV rows (`case,iters,mean_ns,p50_ns,p95_ns,min_ns`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("case,iters,mean_ns,p50_ns,p95_ns,min_ns\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{:.1},{:.1},{:.1},{:.1}\n",
                r.name, r.iters, r.mean_ns, r.p50_ns, r.p95_ns, r.min_ns
            ));
        }
        out
    }
}

/// Summarize raw per-iteration samples (ns) into a [`BenchResult`].
///
/// Sorts with [`f64::total_cmp`] so a poisoned sample (NaN from a clock
/// hiccup or a downstream subtraction) sorts above every finite sample
/// instead of panicking the whole harness mid-sweep; the percentiles of
/// a mostly-finite run stay finite, and the mean stays honest (NaN) so
/// the poisoned case is visible in the table rather than fabricated.
fn summarize(name: String, iters: usize, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchResult {
        name,
        iters,
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        min_ns: samples[0],
    }
}

/// Shared machinery for the benches' `--json` perf-pin modes: flag
/// parsing, pin-document assembly and the warn-only baseline diff that
/// `scripts/ci_local.sh` (and the CI perf step) run against the
/// checked-in `BENCH_*.json` files.  Absolute medians are
/// host-dependent, so the diff WARNS on >10% regressions and never
/// fails the build.
pub mod pin {
    use std::collections::BTreeMap;

    use crate::util::json::{self, Value};

    /// Value of a `--flag PATH` style bench argument.
    pub fn opt(args: &[String], flag: &str) -> Option<String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .cloned()
    }

    /// One `cases[]` entry: the case name, its pinned metric, plus any
    /// bench-specific fields.
    pub fn case(name: &str, metric: &str, value: f64, extra: BTreeMap<String, Value>) -> Value {
        let mut obj = BTreeMap::new();
        obj.insert("name".into(), Value::Str(name.into()));
        obj.insert(metric.into(), Value::Num(value));
        for (k, v) in extra {
            obj.insert(k, v);
        }
        Value::Obj(obj)
    }

    /// Write the pin document `{bench, note, <extra...>, cases}` to
    /// `out_path`.  The note travels with regenerated files so a
    /// copy-over re-pin keeps the provenance line intact.
    pub fn write(
        bench_name: &str,
        note: &str,
        out_path: &str,
        cases: Vec<Value>,
        extra: BTreeMap<String, Value>,
    ) {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Value::Str(bench_name.into()));
        root.insert("note".into(), Value::Str(note.into()));
        for (k, v) in extra {
            root.insert(k, v);
        }
        root.insert("cases".into(), Value::Arr(cases));
        std::fs::write(out_path, Value::Obj(root).render() + "\n").expect("writing bench json");
        println!("wrote {out_path}");
    }

    /// Warn (never fail) when a fresh median regresses >10% against the
    /// `metric` field of the baseline pin's `cases` at `path`.
    pub fn compare_with_baseline(path: &str, metric: &str, medians: &BTreeMap<String, f64>) {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("no baseline at {path}: {e}");
                return;
            }
        };
        let base = match json::parse(&text) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("unparseable baseline {path}: {e}");
                return;
            }
        };
        let Some(base_cases) = base.get("cases").and_then(|c| c.as_arr()) else {
            eprintln!("baseline {path} has no cases array");
            return;
        };
        let mut warned = false;
        for c in base_cases {
            let name = c.get("name").and_then(|v| v.as_str());
            let old = c.get(metric).and_then(|v| v.as_f64());
            let (Some(name), Some(old)) = (name, old) else {
                continue;
            };
            let Some(&new) = medians.get(name) else {
                continue;
            };
            let ratio = new / old.max(1.0);
            if ratio > 1.10 {
                warned = true;
                println!(
                    "WARN: {name}: median {:.3} ms vs baseline {:.3} ms (+{:.0}%)",
                    new / 1e6,
                    old / 1e6,
                    (ratio - 1.0) * 100.0
                );
            } else {
                println!("ok: {name}: {ratio:.2}x baseline");
            }
        }
        if !warned {
            println!("no >10% wall-clock regressions vs {path}");
        }
    }
}

/// `FEDADAM_BENCH_QUICK=1` switches every bench binary to quick mode.
pub fn from_env() -> Bench {
    if std::env::var("FEDADAM_BENCH_QUICK").is_ok() {
        Bench::quick()
    } else {
        Bench::new()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick();
        let mut acc = 0u64;
        b.run("sum", || {
            acc = black_box((0..1000u64).sum());
        });
        let r = &b.results[0];
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns * 1.5);
        assert!(b.to_csv().lines().count() == 2);
    }

    #[test]
    fn nan_sample_does_not_panic_the_summary() {
        // `partial_cmp(..).unwrap()` would panic here; `total_cmp` sorts
        // the NaN above every finite sample, keeping percentiles finite
        // and leaving the mean NaN as an honest poisoned-run marker.
        let r = summarize("nan".into(), 4, vec![3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(r.min_ns, 1.0);
        assert_eq!(r.p50_ns, 2.0);
        assert_eq!(r.p95_ns, 3.0);
        assert!(r.mean_ns.is_nan());
    }
}
