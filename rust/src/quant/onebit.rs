//! Error-compensated 1-bit (sign) quantization — the 1-bit Adam compressor.
//!
//! Wire format: `d` sign bits + one f32 scale, where
//! `scale = mean(|x + e|)` and the error memory absorbs the residual.
//! Matches `compile/kernels/quantize.py::onebit_quantize` bit-for-bit
//! (sign(0) := +1).

use super::ErrorFeedback;
use crate::sparse::codec::{BitPacker, BitUnpacker, DecodeError};

/// Packed 1-bit payload.
#[derive(Clone, Debug)]
pub struct OneBitPacket {
    pub dim: usize,
    pub scale: f32,
    pub signs: Vec<u8>,
}

impl OneBitPacket {
    /// Wire size: d sign bits + 32-bit scale.
    pub fn wire_bits(&self) -> u64 {
        self.dim as u64 + 32
    }
}

/// Compress `x` with error feedback; updates `ef` in place.
pub fn onebit_compress(x: &[f32], ef: &mut ErrorFeedback) -> OneBitPacket {
    let c = ef.compensate(x);
    let scale = if c.is_empty() {
        0.0
    } else {
        c.iter().map(|v| v.abs() as f64).sum::<f64>() as f32 / c.len() as f32
    };
    let mut packer = BitPacker::with_capacity(c.len());
    let mut dequant = Vec::with_capacity(c.len());
    for &v in &c {
        let positive = v >= 0.0;
        packer.push(positive as u64, 1);
        dequant.push(if positive { scale } else { -scale });
    }
    ef.update(&c, &dequant);
    OneBitPacket {
        dim: x.len(),
        scale,
        signs: packer.finish(),
    }
}

/// Reconstruct the dequantized vector the server sees.
///
/// Trusted in-process path (the packet came from [`onebit_compress`] in
/// this address space); transport-facing callers must use
/// [`try_onebit_decompress`].
pub fn onebit_decompress(p: &OneBitPacket) -> Vec<f32> {
    let mut u = BitUnpacker::new(&p.signs);
    (0..p.dim)
        .map(|_| if u.pull(1) == 1 { p.scale } else { -p.scale })
        .collect()
}

/// Fallible [`onebit_decompress`] for untrusted bytes: never panics, and
/// only accepts the canonical output of [`onebit_compress`] — exactly
/// `ceil(d/8)` sign bytes, zero padding bits, and a finite non-negative
/// scale.
pub fn try_onebit_decompress(p: &OneBitPacket) -> Result<Vec<f32>, DecodeError> {
    if !p.scale.is_finite() || p.scale < 0.0 {
        return Err(DecodeError::BadValue("non-finite or negative sign scale"));
    }
    let expected = p.dim.div_ceil(8);
    if p.signs.len() != expected {
        return Err(DecodeError::PayloadSize {
            expected,
            got: p.signs.len(),
        });
    }
    let mut u = BitUnpacker::new(&p.signs);
    let mut out = Vec::with_capacity(p.dim);
    for _ in 0..p.dim {
        out.push(if u.try_pull(1)? == 1 { p.scale } else { -p.scale });
    }
    let pad = (expected * 8 - p.dim) as u64;
    if pad > 0 && u.try_pull(pad)? != 0 {
        return Err(DecodeError::BadValue("nonzero sign padding bits"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_and_scale() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(x.len());
        let p = onebit_compress(&x, &mut ef);
        let y = onebit_decompress(&p);
        let mean_abs: f32 = x.iter().map(|v| v.abs()).sum::<f32>() / x.len() as f32;
        assert!((p.scale - mean_abs).abs() < 1e-4);
        for (xi, yi) in x.iter().zip(&y) {
            assert_eq!(yi.abs(), p.scale);
            // zero-error first round: sign matches input sign
            assert_eq!(*xi >= 0.0, *yi >= 0.0);
        }
    }

    #[test]
    fn error_feedback_reduces_bias_over_rounds() {
        // Repeatedly compressing the same vector with EF should converge the
        // *cumulative* transmitted mass toward the true vector.
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let mut ef = ErrorFeedback::new(x.len());
        let mut sent = vec![0.0f32; x.len()];
        let rounds = 200;
        for _ in 0..rounds {
            let p = onebit_compress(&x, &mut ef);
            let y = onebit_decompress(&p);
            for (s, v) in sent.iter_mut().zip(&y) {
                *s += v;
            }
        }
        let mut err = 0.0f64;
        for (s, xv) in sent.iter().zip(&x) {
            err += ((s / rounds as f32 - xv) as f64).powi(2);
        }
        let rmse = (err / x.len() as f64).sqrt();
        // Residuals stay bounded (~scale), so the mean error decays ~1/T.
        // With scale ≈ E|N(0,1)| ≈ 0.8 and T = 200, rmse well under the
        // one-shot (no-EF) error of ≈ 0.6 proves the feedback works.
        assert!(rmse < 0.1, "EF should drive mean sent toward x; rmse={rmse}");
        // And compare against no-EF: repeated independent compression keeps
        // a constant bias per lane.
        let mut no_ef = vec![0.0f32; x.len()];
        for _ in 0..rounds {
            let mut fresh = ErrorFeedback::new(x.len());
            let p = onebit_compress(&x, &mut fresh);
            let y = onebit_decompress(&p);
            for (s, v) in no_ef.iter_mut().zip(&y) {
                *s += v;
            }
        }
        let mut err0 = 0.0f64;
        for (s, xv) in no_ef.iter().zip(&x) {
            err0 += ((s / rounds as f32 - xv) as f64).powi(2);
        }
        let rmse0 = (err0 / x.len() as f64).sqrt();
        assert!(rmse < rmse0 / 3.0, "EF ({rmse}) should beat no-EF ({rmse0})");
    }

    #[test]
    fn wire_bits() {
        let p = OneBitPacket {
            dim: 100,
            scale: 1.0,
            signs: vec![0; 13],
        };
        assert_eq!(p.wire_bits(), 132);
    }

    #[test]
    fn empty_input() {
        let mut ef = ErrorFeedback::new(0);
        let p = onebit_compress(&[], &mut ef);
        assert_eq!(p.scale, 0.0);
        assert!(onebit_decompress(&p).is_empty());
    }
}
