//! Server aggregation microbench (DESIGN.md §Perf L3).
//!
//! FedAvg reduce over N device uploads: sparse accumulation (`O(Σ nnz)`)
//! vs densified accumulation (`O(N·d)`) — the win that keeps the server
//! out of the critical path at low α.
//!
//! Run: `cargo bench --bench sparse_agg`.

use fedadam_ssm::algorithms::{Recon, Upload};
use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::coordinator::server::aggregate;
use fedadam_ssm::rng::Rng;
use fedadam_ssm::sparse::{top_k_indices, SparseVec};

fn make_uploads(d: usize, n: usize, k: usize, rng: &mut Rng, dense: bool) -> Vec<Upload> {
    (0..n)
        .map(|_| {
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let dw = if dense {
                let idx = top_k_indices(&x, k);
                Recon::Dense(SparseVec::gather(&x, &idx).to_dense())
            } else {
                let idx = top_k_indices(&x, k);
                Recon::Sparse(SparseVec::gather(&x, &idx))
            };
            Upload {
                dw,
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            }
        })
        .collect()
}

fn main() {
    let mut bench = from_env();
    let mut rng = Rng::new(7);
    let d = 176_778; // resnet_mini
    let n = 20; // paper's device count

    for &alpha in &[0.01f64, 0.05, 0.2] {
        let k = (d as f64 * alpha) as usize;
        let sparse = make_uploads(d, n, k, &mut rng, false);
        let dense = make_uploads(d, n, k, &mut rng, true);
        bench.run(format!("sparse reduce N={n} d={d} alpha={alpha}"), || {
            black_box(aggregate(&sparse, d));
        });
        bench.run(format!("dense reduce  N={n} d={d} alpha={alpha}"), || {
            black_box(aggregate(&dense, d));
        });
    }

    bench.report("server FedAvg aggregation");
    println!("\n{}", bench.to_csv());
}
