//! Aggregation + eval scaling bench: sharded-vs-sequential server reduce
//! and pool-parallel-vs-sequential eval, across shard/worker counts.
//!
//! Both paths carry a bit-identity guarantee (aggregation is
//! shard-order-fixed, eval is batch-order-fixed); this bench measures the
//! wall-clock side of that contract and re-asserts the bits outside the
//! timed region.  Runs fully offline: the eval half drives the pure-Rust
//! reference executor, no PJRT artifacts needed.
//!
//! Run: `cargo bench --bench agg_scaling`.
//!
//! **JSON mode** (`-- --json`) — the CI perf pin: the sequential and
//! widest-parallel points of each half (1 vs 8 shards, 1 vs 4 workers),
//! emitting per-case `median_ns` plus the derived parallel speedups as
//! `BENCH_agg_scaling.json` (`--json-out PATH` to redirect).  With
//! `--baseline PATH` any >10% regression against the checked-in pin
//! prints a `WARN:` line (informational — absolute numbers are
//! host-dependent).

use std::collections::BTreeMap;

use fedadam_ssm::algorithms::{Recon, Upload};
use fedadam_ssm::benchlib::{black_box, from_env, pin};
use fedadam_ssm::coordinator::{aggregate_sharded, evaluate_model};
use fedadam_ssm::data::synthetic;
use fedadam_ssm::rng::Rng;
use fedadam_ssm::runtime::{reference_meta, reference_pool};
use fedadam_ssm::sparse::{top_k_indices, SparseVec};
use fedadam_ssm::util::json::Value;

/// 100-device cohort: mostly sparse uploads (the SSM regime) plus a few
/// dense stragglers, at ResNet-ish lane counts.
fn make_uploads(d: usize, k: usize, devices: usize) -> Vec<Upload> {
    let mut rng = Rng::new(42);
    let mut uploads = Vec::with_capacity(devices);
    for dev in 0..devices {
        let dw: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let recon = if dev % 25 == 24 {
            Recon::Dense(dw)
        } else {
            let idx = top_k_indices(&dw, k);
            Recon::Sparse(SparseVec::gather(&dw, &idx))
        };
        uploads.push(Upload {
            dw: recon,
            dm: None,
            dv: None,
            weight: 1.0 + (dev % 7) as f64,
            bits: 0,
        });
    }
    uploads
}

/// `--json` mode: the machine-readable perf pin (see the module docs).
fn json_mode(args: &[String]) {
    let out_path =
        pin::opt(args, "--json-out").unwrap_or_else(|| "BENCH_agg_scaling.json".into());
    let baseline = pin::opt(args, "--baseline");

    let mut bench = from_env();
    bench.max_iters = 30;
    let mut cases: Vec<Value> = Vec::new();
    let mut medians: BTreeMap<String, f64> = BTreeMap::new();

    // Sharded aggregate: sequential vs widest point.
    let d = 200_000;
    let k = 10_000;
    let uploads = make_uploads(d, k, 100);
    let agg_base = aggregate_sharded(&uploads, d, 1);
    for shards in [1usize, 8] {
        let name = format!("aggregate-{shards}shards");
        let med = bench
            .run(name.clone(), || {
                black_box(aggregate_sharded(&uploads, d, shards));
            })
            .p50_ns;
        // Bit-identity re-check outside the timed region.
        let agg = aggregate_sharded(&uploads, d, shards);
        assert!(
            agg.dw
                .iter()
                .zip(&agg_base.dw)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "{shards} shards diverged from the sequential reduce"
        );
        medians.insert(name.clone(), med);
        let mut extra = BTreeMap::new();
        extra.insert("dim".into(), Value::Num(d as f64));
        extra.insert("devices".into(), Value::Num(uploads.len() as f64));
        extra.insert("shards".into(), Value::Num(shards as f64));
        cases.push(pin::case(&name, "median_ns", med, extra));
    }

    // Pool-parallel eval: sequential vs widest point.
    let meta = reference_meta(&[8, 8, 1], 10, 8, 32, 1);
    let spec = synthetic::SyntheticSpec::for_input_shape(&meta.input_shape, 64, 4000);
    let task = synthetic::generate(&spec, 3);
    let data = task.test;
    let mut eval_base: Option<(f64, f64)> = None;
    for workers in [1usize, 4] {
        let pool = reference_pool(meta.clone(), workers).expect("reference pool");
        let h = pool.handle();
        let w = h.init(1).expect("init");
        let name = format!("eval-{workers}workers");
        let med = bench
            .run(name.clone(), || {
                black_box(evaluate_model(&h, &w, &data, workers).unwrap());
            })
            .p50_ns;
        let result = evaluate_model(&h, &w, &data, workers).unwrap();
        match eval_base {
            None => eval_base = Some(result),
            Some((l, a)) => assert_eq!(
                (l.to_bits(), a.to_bits()),
                (result.0.to_bits(), result.1.to_bits()),
                "{workers}-worker eval diverged from sequential"
            ),
        }
        medians.insert(name.clone(), med);
        let mut extra = BTreeMap::new();
        extra.insert("samples".into(), Value::Num(data.len() as f64));
        extra.insert("workers".into(), Value::Num(workers as f64));
        cases.push(pin::case(&name, "median_ns", med, extra));
    }

    let mut speedups = BTreeMap::new();
    speedups.insert(
        "aggregate_8shards".into(),
        Value::Num(medians["aggregate-1shards"] / medians["aggregate-8shards"].max(1.0)),
    );
    speedups.insert(
        "eval_4workers".into(),
        Value::Num(medians["eval-1workers"] / medians["eval-4workers"].max(1.0)),
    );
    let mut extra = BTreeMap::new();
    extra.insert("parallel_speedup".into(), Value::Obj(speedups));
    pin::write(
        "agg_scaling",
        "maintainer-machine pin; regenerate with: cargo bench --bench agg_scaling -- --json \
         --json-out BENCH_agg_scaling.json (both halves stay bit-identical to their \
         sequential baselines at any width — the pin tracks wall-clock only; medians are \
         host-dependent, so ci_local.sh only WARNS on >10% regressions)",
        &out_path,
        cases,
        extra,
    );

    if let Some(bp) = baseline {
        pin::compare_with_baseline(&bp, "median_ns", &medians);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--json") {
        json_mode(&args);
        return;
    }
    let mut bench = from_env();
    bench.max_iters = 30;

    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);

    // ---- Part 1: sharded server aggregate --------------------------------
    let d = 200_000;
    let k = 10_000;
    let uploads = make_uploads(d, k, 100);
    let baseline = aggregate_sharded(&uploads, d, 1);
    for shards in [1usize, 2, 4, 8, 16] {
        bench.run(
            format!("aggregate: 100 dev, d={d}, {shards} shards ({cores} cores)"),
            || {
                black_box(aggregate_sharded(&uploads, d, shards));
            },
        );
        // Bit-identity re-check outside the timed region.
        let agg = aggregate_sharded(&uploads, d, shards);
        assert!(
            agg.dw
                .iter()
                .zip(&baseline.dw)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "{shards} shards diverged from the sequential reduce"
        );
        assert_eq!(agg.dw_support, baseline.dw_support);
    }

    // ---- Part 2: pool-parallel eval --------------------------------------
    let meta = reference_meta(&[8, 8, 1], 10, 8, 32, 1);
    let spec = synthetic::SyntheticSpec::for_input_shape(&meta.input_shape, 64, 4000);
    let task = synthetic::generate(&spec, 3);
    let data = task.test; // 4000 samples → 125 eval batches of 32
    let mut eval_baseline: Option<(f64, f64)> = None;
    for workers in [1usize, 2, 4, 8] {
        let pool = match reference_pool(meta.clone(), workers) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("skipping eval bench: {e}");
                break;
            }
        };
        let h = pool.handle();
        let w = h.init(1).expect("init");
        bench.run(
            format!("eval: 4000 samples, {workers} workers ({cores} cores)"),
            || {
                black_box(evaluate_model(&h, &w, &data, workers).unwrap());
            },
        );
        let result = evaluate_model(&h, &w, &data, workers).unwrap();
        match eval_baseline {
            None => eval_baseline = Some(result),
            Some((l, a)) => {
                assert_eq!(
                    (l.to_bits(), a.to_bits()),
                    (result.0.to_bits(), result.1.to_bits()),
                    "{workers}-worker eval diverged from sequential"
                );
            }
        }
    }

    bench.report("sharded aggregation + pool-parallel eval");
    println!("\n{}", bench.to_csv());
    println!("bit-identity verified at every shard/worker count");
}
