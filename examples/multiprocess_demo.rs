//! Multi-process federated run: one coordinator + N device-agent
//! processes over a localhost TCP socket, checked bit-for-bit against
//! the in-process run of the same experiment.
//!
//! ```text
//! cargo run --release --example multiprocess_demo
//! ```
//!
//! The demo runs on the pure-Rust reference backend so it works without
//! AOT artifacts (CI runs it headless).  To get real OS process
//! boundaries without artifacts, the example re-execs *itself* as each
//! agent: the parent spawns `multiprocess_demo --agent-worker <i>
//! --connect <addr>` children, which connect back over TCP and run the
//! exact [`fedadam_ssm::transport::run_agent`] loop the `device-agent`
//! binary runs.  (With artifacts present, the standalone binary does the
//! same against `fedadam-ssm run --set transport_listen=...` — see the
//! README quickstart.)
//!
//! Exit status is the verdict: non-zero if any byte differs.

use std::process::{Child, Command};

use anyhow::{bail, Context, Result};

use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::runtime::{reference_meta, reference_pool, ModelMeta};
use fedadam_ssm::transport::run_agent;

const AGENTS: usize = 2;

fn meta() -> ModelMeta {
    // A small linear model: dim = 10 * (8*8*1 + 1) = 650.
    reference_meta(&[8, 8, 1], 10, 8, 32, 1)
}

/// The one experiment both runs (and every agent process) must agree on.
fn demo_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "multiprocess-demo".into();
    cfg.model = "reference-linear".into();
    cfg.algorithm = "fedadam-ssm-qef".into(); // quantized + error feedback:
                                              // the most state to get wrong
    cfg.rounds = 3;
    cfg.devices = 4;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 2;
    cfg.train_samples = 128;
    cfg.test_samples = 64;
    cfg.seed = 11;
    cfg.quant_levels = 16;
    cfg.num_workers = 2;
    cfg
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--agent-worker") {
        return agent_child(&args);
    }
    parent()
}

/// Child mode: `multiprocess_demo --agent-worker <i> --connect <addr>`.
fn agent_child(args: &[String]) -> Result<()> {
    let arg_after = |flag: &str| -> Result<&str> {
        let at = args.iter().position(|a| a == flag).context(flag)?;
        args.get(at + 1).map(|s| s.as_str()).context(flag)
    };
    let index: usize = arg_after("--agent-worker")?.parse()?;
    let addr = arg_after("--connect")?;
    let mut cfg = demo_cfg();
    cfg.transport_listen = addr.into();
    cfg.transport_agents = AGENTS;
    let pool = reference_pool(meta(), 1)?;
    run_agent(&cfg, &pool, addr, index)
}

fn parent() -> Result<()> {
    println!(
        "multiprocess demo: {} — {} devices, {} rounds, 1 coordinator + {AGENTS} agent processes",
        demo_cfg().algorithm,
        demo_cfg().devices,
        demo_cfg().rounds
    );

    // Reference run: the ordinary in-process coordinator.
    let cfg = demo_cfg();
    let pool = reference_pool(meta(), cfg.num_workers)?;
    let mut coord = Coordinator::with_pool(cfg, pool)?;
    let log_local = coord.run()?;
    let w_local = coord.global().w.clone();
    println!("in-process run done ({} rounds)", log_local.rounds.len());

    // Remote run: same experiment, but every device trains inside one of
    // the agent processes; only framed bytes cross the process boundary.
    let mut cfg = demo_cfg();
    cfg.transport_listen = "127.0.0.1:0".into();
    cfg.transport_agents = AGENTS;
    cfg.transport_timeout_secs = 30.0;
    let pool = reference_pool(meta(), cfg.num_workers)?;
    let mut coord = Coordinator::with_pool(cfg, pool)?;
    let addr = coord.transport_addr().context("transport not bound")?;
    println!("coordinator listening on {addr}");

    let exe = std::env::current_exe()?;
    let mut children: Vec<Child> = (0..AGENTS)
        .map(|i| {
            Command::new(&exe)
                .args(["--agent-worker", &i.to_string(), "--connect", &addr])
                .spawn()
                .with_context(|| format!("spawning agent process {i}"))
        })
        .collect::<Result<_>>()?;
    let log_remote = coord.run()?;
    for (i, child) in children.iter_mut().enumerate() {
        let status = child.wait()?;
        if !status.success() {
            bail!("agent process {i} exited with {status}");
        }
        println!("agent process {i} exited cleanly");
    }
    let w_remote = coord.global().w.clone();

    // The verdict: every logged number and the final model, bit for bit.
    println!(
        "\n{:>5} {:>14} {:>14} {:>12} {:>14}",
        "round", "train loss", "test acc", "uplink bits", "byte-identical"
    );
    let mut identical = w_local == w_remote;
    for (a, b) in log_local.rounds.iter().zip(&log_remote.rounds) {
        let same = a.train_loss.to_bits() == b.train_loss.to_bits()
            && a.test_accuracy.to_bits() == b.test_accuracy.to_bits()
            && a.uplink_bits == b.uplink_bits
            && a.downlink_bits == b.downlink_bits
            && a.update_norm.to_bits() == b.update_norm.to_bits();
        identical &= same;
        println!(
            "{:>5} {:>14.6} {:>14.4} {:>12} {:>14}",
            a.round,
            b.train_loss,
            b.test_accuracy,
            b.uplink_bits,
            if same { "yes" } else { "NO" }
        );
    }
    let uplink = log_remote.rounds.last().map(|r| r.uplink_bits).unwrap_or(0);
    println!(
        "\ntotal uplink priced at {uplink} bits = {} framed bytes on the wire",
        uplink.div_ceil(8)
    );
    if identical {
        println!("PASS: multi-process run is byte-identical to the in-process run");
        Ok(())
    } else {
        bail!("FAIL: multi-process run diverged from the in-process run");
    }
}
