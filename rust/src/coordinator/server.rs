//! Server-side aggregation (FedAvg over possibly-sparse uploads) and
//! global state management (Algorithm 2, server lines).

use crate::algorithms::{Aggregate, Recon, Upload};
use crate::tensor;

/// The server's global model + moment estimates.
#[derive(Clone, Debug)]
pub struct GlobalState {
    pub w: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl GlobalState {
    pub fn new(w0: Vec<f32>) -> Self {
        let d = w0.len();
        GlobalState {
            w: w0,
            m: vec![0.0; d],
            v: vec![0.0; d],
        }
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Apply the aggregated round update (`W += ΔŴ` etc.; moments only
    /// when the algorithm aggregated them).
    pub fn apply(&mut self, agg: &Aggregate) {
        tensor::add_assign(&mut self.w, &agg.dw);
        if let Some(dm) = &agg.dm {
            tensor::add_assign(&mut self.m, dm);
        }
        if let Some(dv) = &agg.dv {
            tensor::add_assign(&mut self.v, dv);
        }
    }
}

/// Size of the union of the given payloads' supports.
///
/// A dense payload covers every lane.  A sparse payload's support is its
/// **stored index set** — including lanes whose stored value is exactly
/// `0.0`, because those lanes were transmitted (and priced) on the wire.
fn union_support<'a>(dim: usize, recons: impl Iterator<Item = &'a Recon>) -> usize {
    let mut seen = vec![false; dim];
    let mut count = 0usize;
    for r in recons {
        match r {
            Recon::Dense(_) => return dim,
            Recon::Sparse(sv) => {
                for &i in &sv.indices {
                    if !seen[i as usize] {
                        seen[i as usize] = true;
                        count += 1;
                    }
                }
            }
        }
    }
    count
}

/// Weighted FedAvg over uploads (sparse uploads accumulate sparsely —
/// the reduce is `O(Σ nnz)` not `O(N·d)`).
///
/// The returned [`Aggregate`] also carries the union support size of each
/// vector so downlink pricing survives exact-zero cancellations.
pub fn aggregate(uploads: &[Upload], dim: usize) -> Aggregate {
    let total: f64 = uploads.iter().map(|u| u.weight).sum();
    let mut dw = vec![0.0f32; dim];
    let any_m = uploads.iter().any(|u| u.dm.is_some());
    let any_v = uploads.iter().any(|u| u.dv.is_some());
    let mut dm = if any_m { Some(vec![0.0f32; dim]) } else { None };
    let mut dv = if any_v { Some(vec![0.0f32; dim]) } else { None };

    for u in uploads {
        let coef = if total > 0.0 { (u.weight / total) as f32 } else { 0.0 };
        u.dw.axpy_into(&mut dw, coef);
        if let (Some(acc), Some(r)) = (dm.as_deref_mut(), u.dm.as_ref()) {
            r.axpy_into(acc, coef);
        }
        if let (Some(acc), Some(r)) = (dv.as_deref_mut(), u.dv.as_ref()) {
            r.axpy_into(acc, coef);
        }
    }
    let dw_support = union_support(dim, uploads.iter().map(|u| &u.dw));
    let dm_support = union_support(dim, uploads.iter().filter_map(|u| u.dm.as_ref()));
    let dv_support = union_support(dim, uploads.iter().filter_map(|u| u.dv.as_ref()));
    Aggregate {
        dw,
        dm,
        dv,
        dw_support,
        dm_support,
        dv_support,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Recon;
    use crate::sparse::SparseVec;

    #[test]
    fn weighted_fedavg_dense() {
        let uploads = vec![
            Upload {
                dw: Recon::Dense(vec![1.0, 1.0]),
                dm: Some(Recon::Dense(vec![2.0, 0.0])),
                dv: None,
                weight: 3.0,
                bits: 0,
            },
            Upload {
                dw: Recon::Dense(vec![0.0, 2.0]),
                dm: Some(Recon::Dense(vec![0.0, 2.0])),
                dv: None,
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 2);
        assert!((agg.dw[0] - 0.75).abs() < 1e-6);
        assert!((agg.dw[1] - 1.25).abs() < 1e-6);
        let dm = agg.dm.as_ref().unwrap();
        assert!((dm[0] - 1.5).abs() < 1e-6);
        assert!((dm[1] - 0.5).abs() < 1e-6);
        assert!(agg.dv.is_none());
        // Dense uploads cover every lane; no ΔV was uploaded at all.
        assert_eq!(agg.dw_support, 2);
        assert_eq!(agg.dm_support, 2);
        assert_eq!(agg.dv_support, 0);
    }

    #[test]
    fn sparse_uploads_aggregate() {
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 4,
                indices: i,
                values: v,
            })
        };
        let uploads = vec![
            Upload {
                dw: sv(vec![0], vec![4.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![0, 3], vec![2.0, 2.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 4);
        assert_eq!(agg.dw, vec![3.0, 0.0, 0.0, 1.0]);
        assert_eq!(agg.dw_support, 2); // union {0, 3}
    }

    #[test]
    fn support_survives_exact_cancellation() {
        // Two devices upload lane 1 with values that cancel exactly, and
        // device 0 stores a true-zero payload at lane 2.  The summed vector
        // is non-zero only at lane 0, but THREE lanes went over the wire —
        // the broadcast support must price all of them.
        let sv = |i: Vec<u32>, v: Vec<f32>| {
            Recon::Sparse(SparseVec {
                dim: 4,
                indices: i,
                values: v,
            })
        };
        let uploads = vec![
            Upload {
                dw: sv(vec![0, 1, 2], vec![1.0, 1.0, 0.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
            Upload {
                dw: sv(vec![1], vec![-1.0]),
                dm: None,
                dv: None,
                weight: 1.0,
                bits: 0,
            },
        ];
        let agg = aggregate(&uploads, 4);
        assert_eq!(agg.dw, vec![0.5, 0.0, 0.0, 0.0]);
        let recount = agg.dw.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(recount, 1, "cancellation collapses the naive recount");
        assert_eq!(agg.dw_support, 3, "wire support must survive it");
    }

    #[test]
    fn apply_updates_state() {
        let mut gs = GlobalState::new(vec![1.0, 1.0]);
        gs.apply(&Aggregate {
            dw: vec![0.5, -0.5],
            dm: Some(vec![1.0, 0.0]),
            dv: None,
            dw_support: 2,
            dm_support: 2,
            dv_support: 0,
        });
        assert_eq!(gs.w, vec![1.5, 0.5]);
        assert_eq!(gs.m, vec![1.0, 0.0]);
        assert_eq!(gs.v, vec![0.0, 0.0]);
    }

    #[test]
    fn zero_total_weight_is_safe() {
        let uploads = vec![Upload {
            dw: Recon::Dense(vec![1.0]),
            dm: None,
            dv: None,
            weight: 0.0,
            bits: 0,
        }];
        let agg = aggregate(&uploads, 1);
        assert_eq!(agg.dw, vec![0.0]);
        assert_eq!(agg.dw_support, 1);
    }
}
