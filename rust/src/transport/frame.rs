//! Length-prefixed, CRC-framed message boundaries over a byte stream.
//!
//! Every transport message travels as one frame:
//!
//! ```text
//! [len: u32 le][crc32: u32 le][payload: len bytes]
//! ```
//!
//! — the same layout the coordinator's event journal uses on disk
//! ([`crate::coordinator::journal`]), with the same table-driven CRC-32
//! ([`crate::util::bytes::crc32`], IEEE 802.3).  The checksum makes a
//! torn or bit-flipped frame a typed [`FrameError`] instead of a
//! desynchronized stream: any mutation of the length, checksum or payload
//! bytes is caught before a single payload byte reaches [`super::msg`]'s
//! decoder (the CRC detects *all* burst errors up to 32 bits, so a
//! single-byte corruption can never slip through).
//!
//! Reading never panics and never allocates more than [`MAX_FRAME_LEN`]
//! from untrusted bytes: an oversized length prefix is rejected before
//! the allocation it would have driven.
//!
//! Two read paths share the format:
//! - [`read_frame`] — blocking, for the device agent's command loop;
//! - [`FrameBuffer`] — incremental, for the server's non-blocking poll
//!   loop, where a read may surface half a frame (the tail arrives on a
//!   later poll, and a mid-frame timeout must not lose stream sync).

use std::io::{Read, Write};

use crate::util::bytes::crc32;

/// Upper bound on one frame's payload (256 MiB — a dense `Dense3` round
/// start for a 20M-parameter model is ~240 MB; anything larger is a
/// corrupt or hostile length prefix, refused before allocation).
pub const MAX_FRAME_LEN: usize = 1 << 28;

/// Bytes of the `[len][crc]` preamble.
pub const FRAME_HEADER_LEN: usize = 8;

/// Why a frame could not be read.  `Closed` is the one benign variant —
/// the peer shut the stream down cleanly *between* frames.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end-of-stream at a frame boundary.
    Closed,
    /// Length prefix exceeds [`MAX_FRAME_LEN`] — corrupt or hostile.
    TooLong { len: usize },
    /// Payload checksum mismatch — the bytes were damaged in flight.
    Corrupt { expected: u32, got: u32 },
    /// Underlying socket error (including EOF mid-frame).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "stream closed at a frame boundary"),
            FrameError::TooLong { len } => {
                write!(f, "frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap")
            }
            FrameError::Corrupt { expected, got } => write!(
                f,
                "frame checksum mismatch: header says {expected:#010x}, payload hashes to {got:#010x}"
            ),
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

/// Write one frame: preamble + payload, then flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), FrameError> {
    assert!(
        payload.len() <= MAX_FRAME_LEN,
        "refusing to send a {}-byte frame (cap {MAX_FRAME_LEN})",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Blocking read of one frame.  Distinguishes a clean close before any
/// header byte ([`FrameError::Closed`]) from a mid-frame EOF (an
/// [`FrameError::Io`] — the peer died with a frame in flight).
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let mut header = [0u8; FRAME_HEADER_LEN];
    let mut got = 0usize;
    while got < FRAME_HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Err(FrameError::Closed),
            Ok(0) => {
                return Err(FrameError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "stream closed mid-frame-header",
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let (len, expected) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    check_crc(expected, &payload)?;
    Ok(payload)
}

fn parse_header(header: &[u8; FRAME_HEADER_LEN]) -> Result<(usize, u32), FrameError> {
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN {
        return Err(FrameError::TooLong { len });
    }
    let expected = u32::from_le_bytes(header[4..8].try_into().unwrap());
    Ok((len, expected))
}

fn check_crc(expected: u32, payload: &[u8]) -> Result<(), FrameError> {
    let got = crc32(payload);
    if got != expected {
        return Err(FrameError::Corrupt { expected, got });
    }
    Ok(())
}

/// Incremental frame reassembly for a non-blocking stream: bytes go in
/// as they arrive, complete frames come out.  A partial frame simply
/// waits in the buffer for its tail — stream sync is never lost to a
/// short read.
#[derive(Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
}

impl FrameBuffer {
    pub fn new() -> FrameBuffer {
        FrameBuffer::default()
    }

    /// Append freshly-read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, if one has fully arrived.
    /// `Ok(None)` means "keep reading"; an error means the stream is
    /// unrecoverable (hostile length or damaged payload) and the
    /// connection should be dropped.
    pub fn pop(&mut self) -> Result<Option<Vec<u8>>, FrameError> {
        if self.buf.len() < FRAME_HEADER_LEN {
            return Ok(None);
        }
        let header: [u8; FRAME_HEADER_LEN] = self.buf[..FRAME_HEADER_LEN].try_into().unwrap();
        let (len, expected) = parse_header(&header)?;
        if self.buf.len() < FRAME_HEADER_LEN + len {
            return Ok(None);
        }
        check_crc(expected, &self.buf[FRAME_HEADER_LEN..FRAME_HEADER_LEN + len])?;
        let payload = self.buf[FRAME_HEADER_LEN..FRAME_HEADER_LEN + len].to_vec();
        self.buf.drain(..FRAME_HEADER_LEN + len);
        Ok(Some(payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, payload).unwrap();
        out
    }

    #[test]
    fn roundtrip_and_layout() {
        let bytes = framed(b"hello");
        assert_eq!(bytes.len(), FRAME_HEADER_LEN + 5);
        assert_eq!(&bytes[0..4], &5u32.to_le_bytes());
        assert_eq!(&bytes[4..8], &crc32(b"hello").to_le_bytes());
        let back = read_frame(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(back, b"hello");
        // Two frames back to back parse independently.
        let mut two = framed(b"a");
        two.extend(framed(b""));
        let mut cur = Cursor::new(&two);
        assert_eq!(read_frame(&mut cur).unwrap(), b"a");
        assert_eq!(read_frame(&mut cur).unwrap(), b"");
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Closed)));
    }

    #[test]
    fn any_single_byte_mutation_is_caught() {
        let bytes = framed(b"payload under test");
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            let err = read_frame(&mut Cursor::new(&bad));
            assert!(err.is_err(), "mutation at byte {i} slipped through");
        }
    }

    #[test]
    fn truncation_at_every_boundary_errors() {
        let bytes = framed(b"abcdef");
        for cut in 0..bytes.len() {
            assert!(
                read_frame(&mut Cursor::new(&bytes[..cut])).is_err(),
                "truncation to {cut} bytes slipped through"
            );
        }
    }

    #[test]
    fn oversized_length_is_refused_before_allocation() {
        let mut bytes = vec![0u8; FRAME_HEADER_LEN];
        bytes[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(&bytes)),
            Err(FrameError::TooLong { .. })
        ));
        let mut fb = FrameBuffer::new();
        fb.extend(&bytes);
        assert!(matches!(fb.pop(), Err(FrameError::TooLong { .. })));
    }

    #[test]
    fn frame_buffer_reassembles_byte_by_byte() {
        let mut stream = framed(b"first");
        stream.extend(framed(b"second frame"));
        let mut fb = FrameBuffer::new();
        let mut out = Vec::new();
        for &b in &stream {
            fb.extend(&[b]);
            while let Some(p) = fb.pop().unwrap() {
                out.push(p);
            }
        }
        assert_eq!(out, vec![b"first".to_vec(), b"second frame".to_vec()]);
        assert!(fb.pop().unwrap().is_none());
    }
}
