//! Fig. 2 reproduction: model accuracy vs cumulative uplink communication
//! for FedAdam-SSM and all baselines, IID and non-IID.
//!
//! Emits one CSV per (algorithm, setting) under `results/fig2/` plus a
//! joint summary table.  The paper's claim: at equal uplink budget
//! FedAdam-SSM reaches the highest accuracy, the sparse family beats the
//! dense/quantized family, and everything degrades non-IID.
//!
//! ```text
//! cargo run --release --example fig2_accuracy_vs_comm -- \
//!     [--model cnn_small] [--rounds 25] [--quick]
//! ```

use anyhow::Result;
use fedadam_ssm::algorithms::ALL_ALGORITHMS;
use fedadam_ssm::cli::Cli;
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;

fn run_one(base: &ExperimentConfig, algo: &str, iid: bool, artifacts: &str) -> Result<ExperimentLog> {
    let mut cfg = base.clone();
    cfg.algorithm = algo.into();
    cfg.iid = iid;
    cfg.name = format!("fig2_{}_{}", if iid { "iid" } else { "noniid" }, algo);
    let mut coord = Coordinator::new(cfg, artifacts)?;
    coord.run()
}

fn main() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    let artifacts = cli.opt_or("artifacts", "artifacts");
    let quick = cli.flag("quick");

    let mut base = ExperimentConfig::default();
    base.model = cli.opt_or("model", "cnn_small").to_string();
    base.rounds = cli.opt_parse("rounds")?.unwrap_or(if quick { 6 } else { 25 });
    base.devices = cli.opt_parse("devices")?.unwrap_or(if quick { 3 } else { 8 });
    base.local_epochs = 2;
    base.train_samples = if quick { 512 } else { 2048 };
    base.test_samples = if quick { 128 } else { 512 };
    base.sparsity = 0.05;

    // The paper's nine ids plus the quantized-SSM composition pair — the
    // Fig. 2 axis is accuracy vs uplink bits, exactly the frontier
    // fedadam-ssm-q/-qef trace between the sparse and quantized families
    // (swept in depth by `cargo bench --bench frontier`).
    let algos: Vec<String> = match cli.opt("algorithms") {
        Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
        None => ALL_ALGORITHMS
            .iter()
            .map(|s| s.to_string())
            .chain(["fedadam-ssm-q".to_string(), "fedadam-ssm-qef".to_string()])
            .collect(),
    };

    std::fs::create_dir_all("results/fig2")?;
    println!(
        "{:<9} {:<18} {:>9} {:>13} {:>18}",
        "setting", "algorithm", "best acc", "final acc", "uplink Mbit"
    );
    for &iid in &[true, false] {
        for algo in &algos {
            let log = run_one(&base, algo, iid, artifacts)?;
            let setting = if iid { "IID" } else { "Non-IID" };
            let final_acc = log
                .rounds
                .iter()
                .rev()
                .find(|r| r.test_accuracy.is_finite())
                .map(|r| r.test_accuracy)
                .unwrap_or(f64::NAN);
            let uplink = log.rounds.last().map(|r| r.uplink_bits as f64 / 1e6).unwrap_or(0.0);
            println!(
                "{:<9} {:<18} {:>9.3} {:>13.3} {:>18.2}",
                setting,
                algo,
                log.best_accuracy(),
                final_acc,
                uplink
            );
            log.write_csv(format!("results/fig2/{}.csv", log.name))?;
        }
    }
    println!("\nper-round curves in results/fig2/*.csv (x = uplink_bits, y = test_accuracy)");
    Ok(())
}
