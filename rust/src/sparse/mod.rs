//! Sparse transport: top-k selection, sparse vectors and wire encodings.
//!
//! The paper's uplink is either a dense vector (`FedAdam`), three sparse
//! vectors with three masks (`FedAdam-Top`), or three sparse vectors under
//! one shared mask (`FedAdam-SSM` and the other SSM variants).  This module
//! provides the shared substrate:
//!
//! - [`topk`] — exact-k selection via quickselect with by-index tie break;
//! - [`SparseVec`] — indices + values with dense round-trips;
//! - [`codec`] — the paper's bit-cost model (`§IV`, `§VII-A`), including
//!   the `min{bitmask, index-list}` encoding rule.

pub mod codec;
pub mod topk;

pub use topk::{top_k_indices, top_k_threshold};

/// A sparse view of an `f32[dim]` vector: sorted unique indices + values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Gather `values = dense[indices]`; `indices` must be sorted unique.
    pub fn gather(dense: &[f32], indices: &[u32]) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        SparseVec {
            dim: dense.len(),
            values: indices.iter().map(|&i| dense[i as usize]).collect(),
            indices: indices.to_vec(),
        }
    }

    /// Build from a dense vector by keeping its non-zeros.
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        SparseVec {
            dim: dense.len(),
            indices,
            values,
        }
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Scatter back to a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        self.scatter_into(&mut out);
        out
    }

    /// `out[indices] = values` without clearing other lanes.
    pub fn scatter_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
    }

    /// `out[indices] += w * values` — the server's sparse accumulate.
    pub fn axpy_into(&self, out: &mut [f32], w: f32) {
        debug_assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] += w * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let dense = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let sv = SparseVec::from_dense(&dense);
        assert_eq!(sv.nnz(), 2);
        assert_eq!(sv.indices, vec![1, 3]);
        assert_eq!(sv.to_dense(), dense);
    }

    #[test]
    fn gather_matches_dense() {
        let dense = vec![5.0, 6.0, 7.0, 8.0];
        let sv = SparseVec::gather(&dense, &[0, 2]);
        assert_eq!(sv.values, vec![5.0, 7.0]);
        assert_eq!(sv.to_dense(), vec![5.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn axpy_accumulates_sparse() {
        let sv = SparseVec {
            dim: 4,
            indices: vec![1, 3],
            values: vec![2.0, 4.0],
        };
        let mut out = vec![1.0; 4];
        sv.axpy_into(&mut out, 0.5);
        assert_eq!(out, vec![1.0, 2.0, 1.0, 3.0]);
    }
}
