//! Little-endian binary codec + CRC-32 for the coordinator's event
//! journal and state snapshots.
//!
//! The sandbox builds fully offline against the vendored crate set (no
//! serde/bincode), so the journal's wire format is hand-rolled here:
//! a [`ByteWriter`]/[`ByteReader`] pair over flat little-endian scalars,
//! with floats stored via `to_bits`/`from_bits` so snapshot/restore is
//! exact at the bit level (NaN payloads and `-0.0` included), plus the
//! table-driven CRC-32 (IEEE 802.3 polynomial) every journal record and
//! snapshot blob is checksummed with.

use std::sync::OnceLock;

use anyhow::{bail, Result};

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_inner(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, x: u8) {
        self.buf.push(x);
    }

    pub fn put_bool(&mut self, x: bool) {
        self.put_u8(x as u8);
    }

    pub fn put_u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn put_u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }

    pub fn put_usize(&mut self, x: usize) {
        self.put_u64(x as u64);
    }

    /// Bit-exact f32 (`to_bits`).
    pub fn put_f32(&mut self, x: f32) {
        self.put_u32(x.to_bits());
    }

    /// Bit-exact f64 (`to_bits`).
    pub fn put_f64(&mut self, x: f64) {
        self.put_u64(x.to_bits());
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed raw byte slice.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Length-prefixed f32 slice (bit-exact).
    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f32(x);
        }
    }

    /// Length-prefixed f64 slice (bit-exact).
    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f64(x);
        }
    }

    /// Length-prefixed u64 slice.
    pub fn put_u64s(&mut self, v: &[u64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_u64(x);
        }
    }
}

/// Cursor-based decoder over a byte slice; every `take_*` errors (never
/// panics) on truncated input so a torn journal record surfaces as a
/// recoverable `Result`.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Error unless every byte has been consumed — catches schema drift
    /// between `save_state` and `load_state` pairs.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("{} trailing bytes after decode", self.remaining());
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated input: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn take_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn take_bool(&mut self) -> Result<bool> {
        match self.take_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b}"),
        }
    }

    pub fn take_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn take_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn take_usize(&mut self) -> Result<usize> {
        Ok(self.take_u64()? as usize)
    }

    pub fn take_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.take_u32()?))
    }

    pub fn take_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    fn take_len(&mut self) -> Result<usize> {
        self.take_count(1)
    }

    /// Read an element count whose payload is `elem_size` bytes each.
    /// A count can never exceed the bytes actually present — reject
    /// early so a corrupt (or hostile) prefix cannot drive a huge
    /// allocation before the per-element reads hit end-of-input.
    fn take_count(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.take_u64()?;
        let fits = n
            .checked_mul(elem_size as u64)
            .is_some_and(|total| total <= self.remaining() as u64);
        if !fits {
            bail!("corrupt length prefix {n} with {} bytes left", self.remaining());
        }
        Ok(n as usize)
    }

    pub fn take_str(&mut self) -> Result<String> {
        let n = self.take_len()?;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    pub fn take_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.take_len()?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn take_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.take_count(4)?;
        (0..n).map(|_| self.take_f32()).collect()
    }

    pub fn take_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.take_count(8)?;
        (0..n).map(|_| self.take_f64()).collect()
    }

    pub fn take_u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.take_count(8)?;
        (0..n).map(|_| self.take_u64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // The classic check value of CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn scalars_roundtrip_bit_exact() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX);
        w.put_f32(-0.0);
        w.put_f64(f64::NAN);
        w.put_str("journal");
        w.put_bytes(&[0xAB, 0x00, 0xCD]);
        w.put_f32s(&[1.5, f32::NEG_INFINITY]);
        w.put_f64s(&[0.1]);
        w.put_u64s(&[3, 4]);
        let bytes = w.into_inner();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.take_u8().unwrap(), 7);
        assert!(r.take_bool().unwrap());
        assert_eq!(r.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_u64().unwrap(), u64::MAX);
        assert_eq!(r.take_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.take_f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.take_str().unwrap(), "journal");
        assert_eq!(r.take_bytes().unwrap(), vec![0xAB, 0x00, 0xCD]);
        let f32s = r.take_f32s().unwrap();
        assert_eq!(f32s.len(), 2);
        assert_eq!(f32s[1], f32::NEG_INFINITY);
        assert_eq!(r.take_f64s().unwrap(), vec![0.1]);
        assert_eq!(r.take_u64s().unwrap(), vec![3, 4]);
        r.finish().unwrap();
    }

    #[test]
    fn truncated_input_errors_not_panics() {
        let mut w = ByteWriter::new();
        w.put_u64(42);
        let bytes = w.into_inner();
        let mut r = ByteReader::new(&bytes[..5]);
        assert!(r.take_u64().is_err());
        // Corrupt length prefix must not drive a huge allocation.
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX);
        let bytes = w.into_inner();
        assert!(ByteReader::new(&bytes).take_str().is_err());
        // The same guard covers typed slices (4-/8-byte elements) —
        // element count × size is checked against the bytes present,
        // with overflow-safe multiplication.
        assert!(ByteReader::new(&bytes).take_bytes().is_err());
        assert!(ByteReader::new(&bytes).take_f32s().is_err());
        assert!(ByteReader::new(&bytes).take_f64s().is_err());
        assert!(ByteReader::new(&bytes).take_u64s().is_err());
    }

    #[test]
    fn finish_flags_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.put_u32(1);
        w.put_u32(2);
        let bytes = w.into_inner();
        let mut r = ByteReader::new(&bytes);
        r.take_u32().unwrap();
        assert!(r.finish().is_err());
        r.take_u32().unwrap();
        r.finish().unwrap();
    }
}
