//! PJRT dispatch bench (DESIGN.md §Perf L2): per-program latency of the
//! AOT artifacts, including the per-batch `train` vs fused `epoch`
//! comparison that motivates the scan variant.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench runtime_exec`.

use fedadam_ssm::benchlib::{black_box, from_env};
use fedadam_ssm::rng::Rng;
use fedadam_ssm::runtime::{Engine, Manifest};

fn main() {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime bench: {e}");
            return;
        }
    };
    let mut bench = from_env();
    let mut rng = Rng::new(5);

    for model in ["mlp_tiny", "cnn_small"] {
        if !manifest.models.contains_key(model) {
            continue;
        }
        let engine = Engine::load(&manifest, model).unwrap();
        let h = engine.handle();
        let meta = h.meta().clone();
        let d = meta.dim;
        let row = meta.row();
        let b = meta.batch;
        let nb = meta.epoch_batches;

        let w = h.init(0).unwrap();
        let zeros = vec![0.0f32; d];
        let x: Vec<f32> = (0..b * row).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
        let xs: Vec<f32> = (0..nb).flat_map(|_| x.clone()).collect();
        let ys: Vec<i32> = (0..nb).flat_map(|_| y.clone()).collect();

        bench.run(format!("{model}: init"), || {
            black_box(h.init(1).unwrap());
        });
        bench.run(format!("{model}: train step (B={b})"), || {
            black_box(
                h.train_step(w.clone(), zeros.clone(), zeros.clone(), x.clone(), y.clone(), 1e-3)
                    .unwrap(),
            );
        });
        bench.run(format!("{model}: epoch ({nb} batches, 1 dispatch)"), || {
            black_box(
                h.epoch_step(w.clone(), zeros.clone(), zeros.clone(), xs.clone(), ys.clone(), 1e-3)
                    .unwrap(),
            );
        });
        bench.run(format!("{model}: {nb}x train ({nb} dispatches)"), || {
            let mut s = (w.clone(), zeros.clone(), zeros.clone());
            for _ in 0..nb {
                let r = h
                    .train_step(s.0, s.1, s.2, x.clone(), y.clone(), 1e-3)
                    .unwrap();
                s = (r.0, r.1, r.2);
            }
            black_box(s);
        });

        let e = meta.eval_batch;
        let ex: Vec<f32> = (0..e * row).map(|_| rng.normal() as f32).collect();
        let ey: Vec<i32> = (0..e).map(|i| (i % 10) as i32).collect();
        let wt = vec![1.0f32; e];
        bench.run(format!("{model}: eval batch (E={e})"), || {
            black_box(h.eval_batch(&w, ex.clone(), ey.clone(), wt.clone()).unwrap());
        });

        let dw: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        bench.run(format!("{model}: xla sparsify k=d/20"), || {
            black_box(
                h.sparsify(dw.clone(), dw.clone(), dw.clone(), (d / 20) as i32)
                    .unwrap(),
            );
        });
    }

    bench.report("PJRT program dispatch");
    println!("\n{}", bench.to_csv());
}
