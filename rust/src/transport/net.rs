//! One socket abstraction over TCP and Unix-domain transports.
//!
//! The `transport_listen` knob selects the family by prefix:
//! `"host:port"` binds TCP (port `0` picks a free port — the
//! multi-process demo uses this), `"unix:/path"` binds a Unix-domain
//! socket.  [`Stream`] and [`Listener`] erase the difference for the
//! server's poll loop and the agent's command loop; everything above
//! this module is family-agnostic.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Address prefix selecting the Unix-domain family.
pub const UNIX_PREFIX: &str = "unix:";

/// A connected byte stream of either family.
pub enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    /// Connect to `addr` (with the [`UNIX_PREFIX`] convention).
    pub fn connect(addr: &str) -> Result<Stream> {
        if let Some(path) = addr.strip_prefix(UNIX_PREFIX) {
            Ok(Stream::Unix(
                UnixStream::connect(path).with_context(|| format!("connecting to {addr}"))?,
            ))
        } else {
            Ok(Stream::Tcp(
                TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?,
            ))
        }
    }

    pub fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            Stream::Unix(s) => s.set_read_timeout(t),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A bound accept socket of either family.  Non-blocking: [`Listener::poll_accept`]
/// returns `Ok(None)` when nothing is waiting.
pub enum Listener {
    Tcp(TcpListener),
    Unix {
        listener: UnixListener,
        /// Socket file, unlinked on drop.
        path: std::path::PathBuf,
    },
}

impl Listener {
    /// Bind `listen` and put the listener in non-blocking mode.  A stale
    /// Unix socket file from a dead earlier server is unlinked first.
    pub fn bind(listen: &str) -> Result<Listener> {
        if let Some(path) = listen.strip_prefix(UNIX_PREFIX) {
            let path = std::path::PathBuf::from(path);
            // Stale socket files persist after a crash; binding over one
            // fails, so clear it.  A live server would still hold the
            // listener — two servers on one path is a config error the
            // second bind reports.
            let _ = std::fs::remove_file(&path);
            let listener =
                UnixListener::bind(&path).with_context(|| format!("binding {listen}"))?;
            listener.set_nonblocking(true)?;
            Ok(Listener::Unix { listener, path })
        } else {
            let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
            listener.set_nonblocking(true)?;
            Ok(Listener::Tcp(listener))
        }
    }

    /// The connectable address — for TCP the *resolved* one, so binding
    /// port `0` yields the real port the OS picked.
    pub fn local_addr(&self) -> Result<String> {
        match self {
            Listener::Tcp(l) => Ok(l.local_addr()?.to_string()),
            Listener::Unix { path, .. } => Ok(format!("{UNIX_PREFIX}{}", path.display())),
        }
    }

    /// Accept one pending connection, if any.  The accepted stream is in
    /// blocking mode regardless of the listener (Linux does not inherit
    /// the non-blocking flag through `accept`; set it explicitly either
    /// way so both families behave identically).
    pub fn poll_accept(&self) -> Result<Option<Stream>> {
        let stream = match self {
            Listener::Tcp(l) => match l.accept() {
                Ok((s, _)) => Stream::Tcp(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
                Err(e) => return Err(e.into()),
            },
            Listener::Unix { listener, .. } => match listener.accept() {
                Ok((s, _)) => Stream::Unix(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
                Err(e) => return Err(e.into()),
            },
        };
        stream.set_nonblocking(false)?;
        Ok(Some(stream))
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// `write_all` against a non-blocking stream: spin-with-sleep through
/// `WouldBlock` until `deadline`.  Keeps the server's poll loop single-
/// threaded — a slow reader stalls only its own connection's send, and a
/// peer that never drains its receive buffer errors out instead of
/// wedging the round forever.
pub fn write_all_deadline(
    stream: &mut Stream,
    mut bytes: &[u8],
    deadline: Instant,
) -> Result<()> {
    while !bytes.is_empty() {
        match stream.write(bytes) {
            Ok(0) => bail!("connection closed mid-write"),
            Ok(n) => bytes = &bytes[n..],
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                if Instant::now() >= deadline {
                    bail!("write stalled past the transport deadline");
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => return Err(e.into()),
        }
    }
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_port_zero_resolves_and_accepts() {
        let listener = Listener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        assert!(!addr.ends_with(":0"), "port 0 must resolve, got {addr}");
        assert!(listener.poll_accept().unwrap().is_none(), "nothing pending");
        let mut client = Stream::connect(&addr).unwrap();
        // Accept may need a beat on a loaded machine.
        let mut server = None;
        for _ in 0..500 {
            if let Some(s) = listener.poll_accept().unwrap() {
                server = Some(s);
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut server = server.expect("pending connection accepted");
        client.write_all(b"ping").unwrap();
        let mut buf = [0u8; 4];
        server.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
    }

    #[test]
    fn unix_socket_binds_cleans_up_and_rebinds() {
        let dir = std::env::temp_dir().join(format!("fedadam-uds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("t.sock");
        let addr = format!("{UNIX_PREFIX}{}", sock.display());
        {
            let listener = Listener::bind(&addr).unwrap();
            assert_eq!(listener.local_addr().unwrap(), addr);
            assert!(sock.exists());
        }
        assert!(!sock.exists(), "drop unlinks the socket file");
        // A stale file (crash leftover) must not block a rebind.
        std::fs::write(&sock, b"").unwrap();
        let listener = Listener::bind(&addr).unwrap();
        let mut client = Stream::connect(&addr).unwrap();
        let mut server = None;
        for _ in 0..500 {
            if let Some(s) = listener.poll_accept().unwrap() {
                server = Some(s);
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut server = server.expect("uds connection accepted");
        client.write_all(b"hi").unwrap();
        let mut buf = [0u8; 2];
        server.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"hi");
        drop(listener);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
