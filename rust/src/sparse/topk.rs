//! Exact top-k selection by magnitude (paper Definition 1).
//!
//! The SSM is `1_{Top_k}(ΔW)` (eq. 28), so top-k selection sits on the
//! device hot path once per round per device.  This module uses a chunked
//! **MSB-radix select** over a monotone integer key of `|x|`: for any f32,
//! `to_bits(x) & 0x7FFF_FFFF` orders non-negative magnitudes exactly as
//! the values do (zeros, subnormals and infinities included; NaN payloads
//! sort above `+inf`, matching `total_cmp` on the absolute value).  Four
//! byte-granularity passes narrow the candidate pool to the threshold key,
//! then one ascending scan emits the selected indices — `O(d)` worst case
//! (quickselect's adversarial `O(d²)` is gone) and the output is produced
//! already sorted, so no post-hoc sort is needed.  Ties at the threshold
//! are broken by lower-index-first so the mask always has *exactly* `k`
//! ones — `Definition 1`'s permutation tie-break — which keeps the wire
//! cost model exact (the python kernel keeps ties instead; the cross-layer
//! tests use tie-free inputs).

/// Monotone sort key: integer order of `key(x)` == value order of `|x|`.
#[inline]
fn key(v: f32) -> u32 {
    v.to_bits() & 0x7FFF_FFFF
}

/// Indices of the `k` largest `|x|`, returned sorted ascending.
///
/// `k` is clamped to `[0, d]`.  Exactly `min(k, d)` indices are returned.
/// Tie-break: magnitude descending, then index ascending — identical to a
/// stable full sort on `(|x| desc, index asc)`.
pub fn top_k_indices(x: &[f32], k: usize) -> Vec<u32> {
    let d = x.len();
    let k = k.min(d);
    if k == 0 {
        return Vec::new();
    }
    if k == d {
        return (0..d as u32).collect();
    }
    let keys: Vec<u32> = x.iter().map(|&v| key(v)).collect();

    // MSB-radix refinement: after each level we know the top bytes of the
    // threshold key `t` (the k-th largest key) and hold the candidate pool
    // of indices whose key matches that prefix.  `need` counts how many of
    // the pool must still be selected.
    let mut prefix: u32 = 0;
    let mut pool: Vec<u32> = Vec::new();
    let mut need = k;
    let mut take_all_shift: Option<u32> = None;
    for (level, shift) in [24u32, 16, 8, 0].into_iter().enumerate() {
        let mut hist = [0usize; 256];
        if level == 0 {
            for &ky in &keys {
                hist[((ky >> shift) & 0xFF) as usize] += 1;
            }
        } else {
            for &i in &pool {
                hist[((keys[i as usize] >> shift) & 0xFF) as usize] += 1;
            }
        }
        // Walk buckets high→low to the one containing the need-th largest.
        let mut b = 255usize;
        loop {
            let c = hist[b];
            if need <= c {
                break;
            }
            need -= c;
            b -= 1;
        }
        prefix |= (b as u32) << shift;
        if need == hist[b] {
            // The whole bucket is selected: every key whose top bits are
            // >= the prefix (at this granularity) is in the top-k, and
            // nothing else is.  No finer refinement can change the set.
            take_all_shift = Some(shift);
            break;
        }
        if level == 0 {
            pool = (0..d as u32)
                .filter(|&i| ((keys[i as usize] >> shift) & 0xFF) as usize == b)
                .collect();
        } else {
            pool.retain(|&i| ((keys[i as usize] >> shift) & 0xFF) as usize == b);
        }
    }

    // One ascending scan emits exactly k indices, already sorted.  The
    // ascending order *is* the smallest-index tie-break at the threshold.
    let mut out = Vec::with_capacity(k);
    match take_all_shift {
        Some(shift) => {
            let p = prefix >> shift;
            for i in 0..d as u32 {
                if keys[i as usize] >> shift >= p {
                    out.push(i);
                }
            }
        }
        None => {
            // All four levels ran: `prefix` is the exact threshold key and
            // `need` of its ties are taken, lowest index first.
            let mut eq_left = need;
            for i in 0..d as u32 {
                let ky = keys[i as usize];
                if ky > prefix {
                    out.push(i);
                } else if ky == prefix && eq_left > 0 {
                    out.push(i);
                    eq_left -= 1;
                }
            }
        }
    }
    debug_assert_eq!(out.len(), k);
    out
}

/// The k-th largest magnitude (the Pallas kernel's `tau`).
///
/// Contract: the keep rule is `|x| >= tau`, so an empty selection must
/// keep *nothing* — `k == 0` and empty input both return `f32::INFINITY`
/// (no finite magnitude passes).  This is also the `fold(min)` identity,
/// so the two cases need no special-casing downstream.  `k > len` clamps
/// to `len` (the threshold is the smallest magnitude present).
///
/// The Pallas kernel (`compile/kernels/topk.py`) cannot represent `k == 0`
/// at all — it clips `k` into `[1, d]` — so the ∞ convention here is the
/// rust-side extension of the same `|x| >= tau` rule, not a divergence.
pub fn top_k_threshold(x: &[f32], k: usize) -> f32 {
    if k == 0 || x.is_empty() {
        return f32::INFINITY;
    }
    let idx = top_k_indices(x, k);
    idx.iter().map(|&i| x[i as usize].abs()).fold(f32::INFINITY, f32::min)
}

/// Dense 0/1 mask of the top-k (exactly k ones).
pub fn top_k_mask(x: &[f32], k: usize) -> Vec<bool> {
    let mut mask = vec![false; x.len()];
    for i in top_k_indices(x, k) {
        mask[i as usize] = true;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn brute_force(x: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..x.len() as u32).collect();
        // total_cmp, not partial_cmp().unwrap(): a stray NaN input should
        // fail the equality assert honestly, not panic the comparator.
        idx.sort_by(|&a, &b| {
            x[b as usize]
                .abs()
                .total_cmp(&x[a as usize].abs())
                .then(a.cmp(&b))
        });
        let mut out: Vec<u32> = idx[..k.min(x.len())].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_brute_force_randomized() {
        let mut rng = Rng::new(99);
        for trial in 0..50 {
            let d = 1 + rng.below(300);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let k = rng.below(d + 1);
            assert_eq!(top_k_indices(&x, k), brute_force(&x, k), "trial {trial} d={d} k={k}");
        }
    }

    #[test]
    fn handles_ties_by_index() {
        let x = vec![1.0, -1.0, 1.0, 1.0];
        assert_eq!(top_k_indices(&x, 2), vec![0, 1]);
        assert_eq!(top_k_indices(&x, 3), vec![0, 1, 2]);
    }

    #[test]
    fn edge_cases() {
        assert!(top_k_indices(&[], 3).is_empty());
        assert!(top_k_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k_indices(&[1.0, 2.0], 5), vec![0, 1]);
    }

    #[test]
    fn signed_zeros_and_subnormals() {
        // -0.0 and +0.0 share magnitude 0 (key 0): index tie-break applies.
        let x = vec![-0.0f32, 0.0, 1.0e-42, -1.0e-44, 0.0];
        assert_eq!(top_k_indices(&x, 1), vec![2]); // largest subnormal
        assert_eq!(top_k_indices(&x, 2), vec![2, 3]);
        assert_eq!(top_k_indices(&x, 3), vec![0, 2, 3]); // first zero by index
        assert_eq!(top_k_indices(&x, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn threshold_is_kth_magnitude() {
        let x = vec![0.1, -5.0, 3.0, -2.0, 4.0];
        assert_eq!(top_k_threshold(&x, 1), 5.0);
        assert_eq!(top_k_threshold(&x, 3), 3.0);
        assert_eq!(top_k_threshold(&x, 5), 0.1);
    }

    #[test]
    fn threshold_empty_selection_keeps_nothing() {
        // Contract: keep rule is |x| >= tau, so k == 0 and empty input both
        // yield +inf — no finite element passes.
        let x = vec![0.1, -5.0, 3.0];
        assert_eq!(top_k_threshold(&x, 0), f32::INFINITY);
        assert_eq!(top_k_threshold(&[], 3), f32::INFINITY);
        assert_eq!(top_k_threshold(&[], 0), f32::INFINITY);
        assert_eq!(x.iter().filter(|v| v.abs() >= f32::INFINITY).count(), 0);
        // k > len clamps: threshold is the smallest magnitude present.
        assert_eq!(top_k_threshold(&x, 99), 0.1);
    }

    #[test]
    fn mask_has_exactly_k_ones() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        for &k in &[0usize, 1, 50, 999, 1000] {
            let ones = top_k_mask(&x, k).iter().filter(|&&b| b).count();
            assert_eq!(ones, k);
        }
    }

    #[test]
    fn all_equal_input() {
        let x = vec![2.0f32; 64];
        let idx = top_k_indices(&x, 10);
        assert_eq!(idx, (0..10).collect::<Vec<u32>>());
    }
}
