//! End-to-end FL round bench, two parts:
//!
//! **Part 1 (offline, always runs)** — barrier vs pipelined round loop on
//! the pure-Rust reference backend: full multi-round runs with eval every
//! round at `pipeline_depth` 0 (legacy barrier) vs 2 (streaming
//! aggregation + train/eval overlap), across worker counts.  Outside the
//! timed region the two modes' final weights and logged metrics are
//! re-asserted byte-identical — the pipeline may only move wall-clock.
//! At `workers >= 2` the pipelined loop should be at or below the barrier
//! loop (eval batches fill pool capacity the next round's training leaves
//! idle); the summary prints the ratio per worker count.
//!
//! **Part 2 (artifact-gated)** — one full communication round per
//! algorithm (local training + compression + aggregation + apply) on the
//! PJRT backend, the number the §Perf pass optimizes.  Requires
//! `make artifacts`; skipped with a message otherwise.
//!
//! Run: `cargo bench --bench e2e_round`.
//!
//! **JSON mode** (`-- --json`) — the CI perf pin: reference-backend runs
//! at `pipeline_depth ∈ {0, 2}` × journaling {off, on}, emitting median
//! wall-clock per round, final uplink bits and the journal on/off
//! overhead ratio as `BENCH_e2e_round.json` (`--json-out PATH` to
//! redirect).  With `--baseline PATH` the fresh medians are compared
//! against a checked-in file and any >10% wall-clock regression prints a
//! `WARN:` line (informational — absolute numbers are host-dependent, so
//! the comparison never fails the build).

use std::collections::BTreeMap;
use std::path::Path;

use fedadam_ssm::benchlib::{black_box, from_env, pin};
use fedadam_ssm::config::ExperimentConfig;
use fedadam_ssm::coordinator::Coordinator;
use fedadam_ssm::metrics::ExperimentLog;
use fedadam_ssm::runtime::{reference_meta, reference_pool};
use fedadam_ssm::util::json::Value;

const PIPE_INPUT: [usize; 3] = [8, 8, 1]; // row 64
const PIPE_CLASSES: usize = 10; // matches SyntheticSpec::for_input_shape

/// An eval-heavy workload (eval every round, 2048 test samples = 64 eval
/// batches) with fewer devices than the widest pool, so the barrier loop
/// leaves worker capacity idle that the pipelined loop can fill.
fn pipeline_cfg(depth: usize, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "pipeline-bench".into();
    cfg.model = "reference-linear".into();
    cfg.algorithm = "fedadam-ssm".into();
    cfg.rounds = 4;
    cfg.devices = 2;
    cfg.local_epochs = 1;
    cfg.max_batches_per_epoch = 4;
    cfg.train_samples = 512;
    cfg.test_samples = 2048;
    cfg.eval_every = 1;
    cfg.seed = 23;
    cfg.num_workers = workers;
    cfg.agg_shards = 0;
    cfg.pipeline_depth = depth;
    cfg
}

fn run_reference(depth: usize, workers: usize) -> (ExperimentLog, Vec<f32>) {
    run_journaled(depth, workers, None)
}

fn run_journaled(
    depth: usize,
    workers: usize,
    journal: Option<&Path>,
) -> (ExperimentLog, Vec<f32>) {
    let mut cfg = pipeline_cfg(depth, workers);
    if let Some(dir) = journal {
        cfg.journal = dir.to_string_lossy().into_owned();
    }
    let meta = reference_meta(&PIPE_INPUT, PIPE_CLASSES, 8, 32, 1);
    let pool = reference_pool(meta, cfg.num_workers).expect("reference pool");
    let mut coord = Coordinator::with_pool(cfg, pool).expect("coordinator");
    let log = coord.run().expect("run");
    let w = coord.global().w.clone();
    (log, w)
}

/// `--json` mode: the machine-readable perf pin (see the module docs).
fn json_mode(args: &[String]) {
    let out_path = pin::opt(args, "--json-out").unwrap_or_else(|| "BENCH_e2e_round.json".into());
    let baseline = pin::opt(args, "--baseline");

    let mut bench = from_env();
    bench.max_iters = 5; // a full 4-round run per iteration
    let workers = 2;
    let rounds = pipeline_cfg(0, workers).rounds;

    let mut cases: Vec<Value> = Vec::new();
    let mut medians: BTreeMap<String, f64> = BTreeMap::new();
    for depth in [0usize, 2] {
        for journal_on in [false, true] {
            let name = format!(
                "depth{depth}-journal-{}",
                if journal_on { "on" } else { "off" }
            );
            let dir = std::env::temp_dir()
                .join(format!("fedadam-bench-journal-{}", std::process::id()));
            let journal = journal_on.then(|| dir.clone());
            let result = bench.run(name.clone(), || {
                black_box(run_journaled(depth, workers, journal.as_deref()));
            });
            let median_round_ns = result.p50_ns / rounds as f64;
            // One more (untimed) run for the deterministic wire totals.
            let (log, _) = run_journaled(depth, workers, journal.as_deref());
            let uplink_bits = log.rounds.last().map(|r| r.uplink_bits).unwrap_or(0);
            let _ = std::fs::remove_dir_all(&dir);
            medians.insert(name.clone(), median_round_ns);
            let mut obj = BTreeMap::new();
            obj.insert("name".into(), Value::Str(name));
            obj.insert("pipeline_depth".into(), Value::Num(depth as f64));
            obj.insert("journal".into(), Value::Bool(journal_on));
            obj.insert("median_round_ns".into(), Value::Num(median_round_ns));
            obj.insert("uplink_bits".into(), Value::Num(uplink_bits as f64));
            cases.push(Value::Obj(obj));
        }
    }

    let mut overhead = BTreeMap::new();
    for depth in [0usize, 2] {
        let off = medians[&format!("depth{depth}-journal-off")];
        let on = medians[&format!("depth{depth}-journal-on")];
        overhead.insert(format!("depth{depth}"), Value::Num(on / off.max(1.0)));
    }

    let mut extra = BTreeMap::new();
    extra.insert("backend".into(), Value::Str("reference-linear".into()));
    extra.insert("rounds_per_run".into(), Value::Num(rounds as f64));
    extra.insert("workers".into(), Value::Num(workers as f64));
    extra.insert("journal_overhead".into(), Value::Obj(overhead));
    pin::write(
        "e2e_round",
        "maintainer-machine pin; regenerate with: cargo bench --bench e2e_round -- --json \
         --json-out BENCH_e2e_round.json (re-pinned for PR 10's blocked reference kernels \
         + fused wire encode + radix select, ~1.4x below the previous pin; uplink_bits is \
         informational and host-independent; medians are host-dependent, so ci_local.sh \
         only WARNS on >10% regressions)",
        &out_path,
        cases,
        extra,
    );

    if let Some(bp) = baseline {
        pin::compare_with_baseline(&bp, "median_round_ns", &medians);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--json") {
        json_mode(&args);
        return;
    }
    let mut bench = from_env();
    // One full run is already ~100ms-scale; cap iterations regardless of
    // budget.
    bench.max_iters = 6;

    // ---- Part 1: barrier vs pipelined round loop (reference backend) ----
    let workers_grid = [1usize, 2, 4];
    for &workers in &workers_grid {
        for depth in [0usize, 2] {
            bench.run(
                format!("round-loop: {workers}w depth={depth} (4 rounds, eval/round)"),
                || {
                    black_box(run_reference(depth, workers));
                },
            );
        }
        // Bit-identity re-check outside the timed region: the pipeline may
        // change wall-clock only.
        let (log0, w0) = run_reference(0, workers);
        let (log2, w2) = run_reference(2, workers);
        assert_eq!(w0, w2, "{workers}w: pipelined weights diverged");
        assert_eq!(log0.rounds.len(), log2.rounds.len());
        for (a, b) in log0.rounds.iter().zip(&log2.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
            assert_eq!(a.test_accuracy.to_bits(), b.test_accuracy.to_bits());
            assert_eq!(a.uplink_bits, b.uplink_bits);
            assert_eq!(a.downlink_bits, b.downlink_bits);
        }
    }
    println!("\n-- pipelined / barrier wall-clock (reference backend) --");
    for (i, &workers) in workers_grid.iter().enumerate() {
        let barrier = &bench.results[2 * i];
        let pipelined = &bench.results[2 * i + 1];
        let ratio = pipelined.mean_ns / barrier.mean_ns.max(1.0);
        println!(
            "{workers} workers: {:.2}x {}",
            ratio,
            if workers >= 2 && ratio > 1.05 {
                "(EXPECTED <= 1.0x at workers >= 2 — investigate)"
            } else {
                ""
            }
        );
    }

    // ---- Part 2: per-algorithm round cost (PJRT backend, artifact-gated) -
    for algo in [
        "fedadam-ssm",
        "fedadam-top",
        "fairness-top",
        "fedadam",
        "onebit-adam",
        "efficient-adam",
        "fedsgd",
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "cnn_small".into();
        cfg.algorithm = algo.into();
        cfg.rounds = usize::MAX; // stepped manually
        cfg.devices = 4;
        cfg.local_epochs = 1;
        cfg.max_batches_per_epoch = 2;
        cfg.train_samples = 512;
        cfg.test_samples = 64;
        cfg.eval_every = usize::MAX - 1; // exclude eval from the round cost
        cfg.warmup_rounds = 0; // bench the compression phase of onebit
        let mut coord = match Coordinator::new(cfg, "artifacts") {
            Ok(c) => c,
            Err(e) => {
                eprintln!("skipping PJRT e2e bench: {e}");
                break;
            }
        };
        bench.run(format!("round: {algo} (cnn_small, 4 dev, 2 batches)"), || {
            black_box(coord.step_round().unwrap());
        });
    }

    bench.report("end-to-end FL round");
    println!("\n{}", bench.to_csv());
}
