//! Offline stand-in for the `log` crate facade: the subset this repository
//! uses (`Log`, `Record`, `Metadata`, `Level`, `LevelFilter`, `set_logger`,
//! `set_max_level`, `max_level`, and the level macros).
//!
//! Vendored because the build container has no crates.io access; swap for
//! upstream `log` by editing the path dependency in the root `Cargo.toml`.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

/// Global verbosity ceiling.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Record metadata a logger can filter on.
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log message plus its metadata.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The logging backend interface.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when a logger is installed twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (at most once per process).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink when none is installed.
pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: dispatch one record to the global logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level <= max_level() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        let l = logger();
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error <= LevelFilter::Trace);
    }

    #[test]
    fn macros_are_safe_without_logger() {
        set_max_level(LevelFilter::Trace);
        info!("hello {}", 1);
        debug!("world");
        set_max_level(LevelFilter::Off);
    }
}
